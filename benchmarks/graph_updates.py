"""Dynamic-graph maintenance bench: incremental label updates vs rebuild.

Replays an interleaved update+query trace against one ``QbSIndex``:
single-edge inserts and deletes alternate (each advancing the epoch
through ``QbSIndex.apply_update``), with a query batch resolved between
updates so the measured index is always serving-warm.  Every few updates
the same event is also applied through the forced full-rebuild branch
(``churn_threshold=0``) — the honest baseline, since it pays everything a
servable index needs (labelling BFS, landmark-distance table, repacking)
and produces a bit-identical ``PackedLabels``.

The acceptance metric is ``update_speedup`` = rebuild-median over
update-median; the bench gate holds it above an absolute floor
(``--update-speedup-floor``, default 5) rather than a relative threshold
— the ratio normalizes machine speed out, like ``roofline_frac``.

Warmup discipline matters here: the first update doubles the CSR edge
capacity (build packs slots exactly), and the incremental path pads its
affected-landmark recomputes to the ``pad_width`` shape ladder — so the
bench stabilizes capacity first, then warms every ladder width the churn
threshold admits, and only then starts the clock.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import QbSIndex, barabasi_albert_graph
from repro.core.graph import edge_set
from repro.core.packing import pad_width

REPO = Path(__file__).resolve().parents[1]
BENCH_PATH = REPO / "BENCH.json"

N_UPDATES = 16        # timed single-edge updates (inserts/deletes alternate)
REBUILD_EVERY = 3     # every third event also times the full-rebuild branch
R_LANDMARKS = 64
CHURN = 0.5


def _block(index: QbSIndex) -> None:
    import jax

    jax.block_until_ready(index.packed.label_dist)


def _warm_ladder(index: QbSIndex) -> None:
    """Compile every shape the incremental path can hit: the affected-root
    BFS, the packed patch scatter and the label-table scatter, at each
    ``pad_width`` ladder width the churn threshold admits."""
    import jax
    import jax.numpy as jnp

    from repro.core.frontier import make_relay
    from repro.core.labelling import _build_labelling_rows
    from repro.core.packing import patch_packed

    scheme = index.scheme
    lms = np.asarray(scheme.landmarks)
    engine = make_relay(index.graph, backend="segment")
    widths = sorted({pad_width(k)
                     for k in range(1, int(CHURN * len(lms)) + 1)})
    for w in widths:
        roots = jnp.asarray(lms[:w], jnp.int32)
        jax.block_until_ready(_build_labelling_rows(
            engine, roots, scheme.landmarks, scheme.is_landmark, 256))
        jax.block_until_ready(patch_packed(
            index.packed, scheme, index._lm_dist_host,
            np.arange(w, dtype=np.int32)).label_dist)
        idx_w = jnp.arange(w, dtype=jnp.int32)
        jax.block_until_ready(scheme.label_dist.at[:, idx_w].set(
            scheme.label_dist[:, idx_w]))


def run(scale: float = 1.0, **_) -> list[tuple]:
    v = max(3_000, int(48_000 * scale))
    r = min(R_LANDMARKS, max(8, v // 128))
    rng = np.random.default_rng(5)
    g = barabasi_albert_graph(v, 4, seed=17)
    index = QbSIndex.build(g, n_landmarks=r, chunk=16)

    def rand_absent(cur: QbSIndex) -> tuple[int, int]:
        present = {tuple(e) for e in edge_set(cur.graph)}
        while True:
            a, b = rng.integers(0, cur.graph.n_vertices, 2)
            if a != b and (min(a, b), max(a, b)) not in present:
                return (int(a), int(b))

    def rand_present(cur: QbSIndex) -> tuple[int, int]:
        es = edge_set(cur.graph)
        return tuple(int(x) for x in es[rng.integers(0, len(es))])

    # capacity-stabilizing update (edge slots double once, then hold),
    # then warm every incremental shape and both terminal branches
    cur = index.apply_update(inserts=[rand_absent(index)],
                             churn_threshold=CHURN)
    _warm_ladder(cur)
    _block(cur.apply_update(inserts=[rand_absent(cur)], churn_threshold=0.0))
    # Small query legs: random pairs at this V are SPG-expensive (the
    # (N, E) edge-mask recover path), so the trace interleaves a few
    # queries rather than a throughput batch — serving-rate benches own
    # that axis (serving_throughput / trace_replay).
    us, vs = (rng.integers(0, v, 8).astype(np.int32) for _ in range(2))
    cur.query_batch_arrays(us, vs)          # warm the serving program

    upd_t = {"insert": [], "delete": []}
    reb_t, affected, q_t = [], [], []
    for i in range(N_UPDATES):
        op = "insert" if i % 2 == 0 else "delete"
        edge = rand_absent(cur) if op == "insert" else rand_present(cur)
        ev = {f"{op}s": [edge]}
        t0 = time.perf_counter()
        nxt = cur.apply_update(**ev, churn_threshold=CHURN)
        _block(nxt)
        upd_t[op].append(time.perf_counter() - t0)
        affected.append(nxt.last_update_info["n_affected"])
        if i % REBUILD_EVERY == 0:
            t0 = time.perf_counter()
            reb = cur.apply_update(**ev, churn_threshold=0.0)
            _block(reb)
            reb_t.append(time.perf_counter() - t0)
        cur = nxt
        if i % 4 == 3:
            t0 = time.perf_counter()
            cur.query_batch_arrays(us, vs)  # epoch-fresh index keeps serving
            q_t.append(time.perf_counter() - t0)

    ins_med = float(np.median(upd_t["insert"]) * 1e6)
    del_med = float(np.median(upd_t["delete"]) * 1e6)
    upd_med = float(np.median(upd_t["insert"] + upd_t["delete"]) * 1e6)
    reb_med = float(np.median(reb_t) * 1e6)
    q_med = float(np.median(q_t) * 1e6)
    speedup = reb_med / max(upd_med, 1e-9)
    aff_med = float(np.median(affected))

    graph = "ba-hub"
    ident = {"graph": graph, "V": v, "R": r}
    rows_json = [
        {**ident, "op": "insert", "us_per_call": ins_med},
        {**ident, "op": "delete", "us_per_call": del_med},
        {**ident, "op": "rebuild", "us_per_call": reb_med},
        {**ident, "op": "query_between_updates", "us_per_call": q_med},
        {**ident, "op": "speedup", "update_speedup": float(speedup),
         "affected_med": aff_med},
    ]
    record = {"bench": "graph_updates", "ts": time.time(), "scale": scale,
              "rows": rows_json}
    with BENCH_PATH.open("a") as f:
        f.write(json.dumps(record) + "\n")

    derived = f"V={v};R={r};affected_med={aff_med:.0f}"
    return [
        (f"graph_updates/insert/{graph}", ins_med, derived),
        (f"graph_updates/delete/{graph}", del_med, derived),
        (f"graph_updates/rebuild/{graph}", reb_med, derived),
        (f"graph_updates/query/{graph}", q_med, f"epochs={cur.epoch}"),
        (f"graph_updates/speedup/{graph}", upd_med,
         f"update_speedup={speedup:.1f}x"),
    ]


if __name__ == "__main__":
    from .common import emit

    emit(run(scale=0.25))
