"""QoS scheduler under adversarial multi-tenant traffic (DESIGN.md §8).

Open-loop two-tenant scenarios driven in *simulated* time through
``ManualClock`` — scheduler decisions (deadline flushes, weighted slot
shares, chunk adaptation) are a pure function of the trace, so the
latency/share columns are deterministic and gate-safe — while wall-clock
timing of the same scenarios feeds the qps columns:

* **flood** (deadline demo) — a bulk tenant drips sub-chunk bursts every
  tick against a large fixed chunk, so without deadlines the backlog
  coasts toward the size trigger and *everything* (including the
  interactive trickle riding along) queues for many ticks — the ``fifo``
  baseline's p99.  With QoS classes, the interactive ``max_wait`` sweep
  shows p99 queueing latency (submit -> admission, simulated) pinned at
  or under each bound while the flood rides in the deadline rounds' spare
  slots.
* **contend** (weighted-share demo) — both tenants flood past the chunk
  width every tick, so every admission round is slot-contended: deficit-
  weighted round robin must hand the interactive class ~weight share and
  still give the bulk tenant its own — neither starves.

The run itself asserts the ISSUE's acceptance properties — interactive
p99 <= max_wait under flood, and contended-round slot shares within
tolerance of the weights — so a broken scheduler turns the CI bench step
red before the gate even compares numbers.  Appends one JSON record per
invocation to BENCH.json.  Only the deterministic simulated-time columns
are gated by ``scripts/bench_gate.py``; the wall-clock throughput
columns ride along as untracked floats because shared-container timing
spread reaches the gate threshold (float-valued fields stay out of row
keys).
"""
from __future__ import annotations

import json
import time
from functools import partial
from pathlib import Path

import numpy as np

from repro.core import QbSIndex, barabasi_albert_graph
from repro.serving import AdmissionPolicy, ManualClock, QoSClass, StreamingService

from .common import interleaved_best

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH.json"

ROUNDS = 4
TICK_DT = 0.001             # simulated seconds per tick
INT_WEIGHT, BULK_WEIGHT = 4.0, 1.0
BULK_MAX_WAIT = 0.5         # never fires inside these traces
SWEEP_MS = (2, 8, 32)       # interactive max_wait sweep (flood trace)

FLOOD_CHUNK = 64            # large width: drip arrivals coast toward it
FLOOD_BULK, FLOOD_INT = 6, 1
# contend: interactive banks just under the trigger, then the bulk burst
# crosses it with a backlog several rounds deep — the first round of each
# flush is oversubscribed on BOTH sides, which is where weights bite
CONTEND_CHUNK = 16
CONTEND_BULK, CONTEND_INT = 48, 14


def _qos(max_wait_s: float | None):
    if max_wait_s is None:
        return None         # single default class: the fifo baseline
    return (QoSClass("interactive", max_wait=max_wait_s, weight=INT_WEIGHT),
            QoSClass("bulk", max_wait=BULK_MAX_WAIT, weight=BULK_WEIGHT))


def _trace(g, n_ticks: int, bulk: int, inter: int, seed: int):
    """Per tick: ordered (class, us, vs) sub-groups — the interactive
    group first (it banks in the backlog below the size trigger), then
    the bulk burst that crosses the trigger and forces the flush while
    both classes hold work."""
    rng = np.random.default_rng(seed)
    n = n_ticks * (bulk + inter)
    us = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    vs = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    ticks, pos = [], 0

    def cut(k):
        nonlocal pos
        sl = (us[pos:pos + k], vs[pos:pos + k])
        pos += k
        return sl

    for _ in range(n_ticks):
        ticks.append([("interactive", *cut(inter)), ("bulk", *cut(bulk))])
    return ticks


def _run(idx, ticks, chunk: int, max_wait_s: float | None) -> StreamingService:
    clk = ManualClock()
    qos = _qos(max_wait_s)
    st = StreamingService(
        idx, clock=clk, qos=qos,
        policy=AdmissionPolicy(adaptive=False, chunk=chunk,
                               max_chunk=max(128, chunk)))
    for groups in ticks:
        for cls, gu, gv in groups:
            if gu.size:
                st.submit_batch(gu, gv, qos=cls if qos else None)
        clk.advance(TICK_DT)
    st.drain()
    return st


def _p99(waits) -> float:
    return float(np.percentile(np.asarray(waits, np.float64), 99)) \
        if len(waits) else 0.0


def run(scale: float = 1.0, **_) -> list[tuple]:
    n_v = max(400, int(3_000 * scale))
    g = barabasi_albert_graph(n_v, 4, seed=9)
    idx = QbSIndex.build(g, n_landmarks=8, chunk=CONTEND_CHUNK)
    gname = f"ba-{n_v}"
    n_ticks = max(16, int(48 * scale))
    flood = _trace(g, n_ticks, FLOOD_BULK, FLOOD_INT, seed=21)
    contend = _trace(g, n_ticks, CONTEND_BULK, CONTEND_INT, seed=22)

    rows: list[tuple] = []
    record = {"bench": "qos_scheduler", "ts": time.time(), "scale": scale,
              "graph": gname, "V": g.n_vertices, "E": g.n_edges,
              "tick_dt_ms": TICK_DT * 1e3, "n_ticks": n_ticks, "rows": []}

    # -- flood: p99 interactive queueing latency vs the max_wait sweep -------
    for mw_ms in SWEEP_MS:
        st = _run(idx, flood, FLOOD_CHUNK, mw_ms / 1e3)
        p99_us = _p99(st.qos_stats["interactive"]["waits"]) * 1e6
        assert p99_us <= mw_ms * 1e3 + 1e-3, \
            f"deadline breached: p99 {p99_us:.0f}us > max_wait {mw_ms}ms"
        rows.append((f"qos/flood/deadline{mw_ms}ms/{gname}", p99_us,
                     f"bound_us={mw_ms * 1e3:.0f},"
                     f"deadline_flushes={st.stats['deadline_flushes']}"))
        record["rows"].append({
            "trace": "flood", "policy": "qos", "max_wait_ms": mw_ms,
            "us_per_query": p99_us,          # simulated p99 queueing wait:
        })                                   # deterministic, so gateable

    # fifo contrast: one undifferentiated backlog coasts to the size
    # trigger, so the same interactive trickle queues ~chunk/rate ticks
    st = _run(idx, flood, FLOOD_CHUNK, None)
    fifo_p99_us = _p99(st.qos_stats["default"]["waits"]) * 1e6
    assert fifo_p99_us > max(SWEEP_MS[:2]) * 1e3, \
        "flood trace failed to produce fifo queueing beyond the sweep bounds"
    rows.append((f"qos/flood/fifo-wait/{gname}", fifo_p99_us,
                 "policy=fifo,no_deadline"))
    record["rows"].append({
        "trace": "flood", "policy": "fifo", "us_per_query": fifo_p99_us,
    })

    # -- contend: deficit-weighted slot shares under a two-sided flood -------
    # a round is *contended* when both classes still hold backlog after
    # it (admission_log snapshots the live post-round counts): both were
    # slot-limited, so the split reflects the weights, not availability
    st = _run(idx, contend, CONTEND_CHUNK, 8 / 1e3)
    contended = [r for r in st.admission_log
                 if r["backlog"].get("bulk", 0) > 0
                 and r["backlog"].get("interactive", 0) > 0
                 and r["n"] == CONTEND_CHUNK]
    slots = sum(r["n"] for r in contended)
    bulk_slots = sum(r["per_class"].get("bulk", 0) for r in contended)
    share = bulk_slots / slots if slots else -1.0
    fair = BULK_WEIGHT / (BULK_WEIGHT + INT_WEIGHT)
    assert contended, "contend trace produced no slot-contended rounds"
    # contended rounds split slots ~by weight (deficit rounding wobbles a
    # slot per round) ...
    assert 0.7 * fair <= share <= 1.6 * fair, \
        f"bulk share {share:.2f} outside tolerance of weighted {fair:.2f}"
    # ... and over the whole trace the flood still achieves at least its
    # weighted throughput share (the scheduler is work-conserving: capping
    # interactive at its weight hands the spare slots to the flood)
    admitted = {n: st.qos_stats[n]["admitted"] for n in ("interactive", "bulk")}
    total_share = admitted["bulk"] / max(sum(admitted.values()), 1)
    assert total_share >= fair, \
        f"flood throughput share {total_share:.2f} fell below weighted {fair:.2f}"
    rows.append((f"qos/contend/bulk-share/{gname}", round(share, 3),
                 f"weighted_fair={fair:.2f},contended_rounds={len(contended)},"
                 f"trace_share={total_share:.2f}"))
    record["bulk_share_contended"] = share
    record["bulk_share_trace"] = total_share
    record["contended_rounds"] = len(contended)

    # -- wall-clock throughput: scheduler overhead vs the fifo baseline.
    # Recorded as *untracked* float keys (wall_qps/wall_us_per_query):
    # this container's run-to-run wall-clock spread reaches the gate's
    # 25% threshold (see .claude/skills/verify/SKILL.md), so gating these
    # would flake — the deterministic simulated-time rows above carry the
    # gated regression signal for the scheduler instead.
    n_q = n_ticks * (CONTEND_BULK + CONTEND_INT)
    best = interleaved_best({
        "qos": partial(_run, idx, contend, CONTEND_CHUNK, 8 / 1e3),
        "fifo": partial(_run, idx, contend, CONTEND_CHUNK, None),
    }, rounds=ROUNDS)
    for pname, dt in best.items():
        qps = n_q / max(dt, 1e-9)
        rows.append((f"qos/contend/{pname}/{gname}", dt / n_q * 1e6,
                     f"qps={qps:.1f}"))
        record["rows"].append({
            "trace": "contend", "policy": pname, "wall_qps": qps,
            "wall_us_per_query": dt / n_q * 1e6,
        })
    record["qos_vs_fifo"] = best["fifo"] / max(best["qos"], 1e-9)

    with BENCH_PATH.open("a") as f:
        f.write(json.dumps(record) + "\n")
    return rows


def main() -> None:
    from .common import emit

    print("name,us_per_call,derived")
    emit(run())


if __name__ == "__main__":
    main()
