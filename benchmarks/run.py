"""Benchmark driver: one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV (us_per_call is bytes for the size
benches, % for coverage, distance for distance_dist — the name prefix
disambiguates; -1 means DNF-analog).

  PYTHONPATH=src python -m benchmarks.run [--scale 1.0] [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--quick", action="store_true",
                    help="smaller graphs, no sweeps")
    args = ap.parse_args()
    scale = 0.25 if args.quick else args.scale
    sweep = not args.quick

    from . import (
        construction,
        coverage,
        distance_dist,
        frontier_relay,
        label_size,
        qos_scheduler,
        query_time,
        serving_throughput,
        sketch_kernel,
        streaming_admission,
    )
    from .common import emit

    t0 = time.time()
    print("name,us_per_call,derived")
    for mod, kw in (
        (distance_dist, {}),
        (construction, {"sweep": sweep}),
        (label_size, {"sweep": sweep}),
        (query_time, {"sweep": sweep}),
        (coverage, {}),
        (frontier_relay, {}),
        (serving_throughput, {}),
        (streaming_admission, {}),
        (qos_scheduler, {}),
    ):
        t = time.time()
        emit(mod.run(scale=scale, **kw))
        print(f"# {mod.__name__} done in {time.time() - t:.1f}s", file=sys.stderr)
    emit(sketch_kernel.run())
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
