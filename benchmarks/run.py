"""Benchmark driver: one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV (us_per_call is bytes for the size
benches, % for coverage, distance for distance_dist — the name prefix
disambiguates; -1 means DNF-analog).  Several modules also append JSON
records to the BENCH.json trajectory; the driver reports how many bytes
the run appended, and ``--prune-keep N`` rewrites the trajectory keeping
only the last N records per ``(bench, scale)`` (append-only files grow
forever; the gate only ever reads the latest record, so pruning is safe).

  PYTHONPATH=src python -m benchmarks.run [--scale 1.0] [--quick]
      [--prune-keep N]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH.json"


def prune_bench(path: Path, keep: int) -> int:
    """Keep the last ``keep`` records per (bench, scale); returns the
    number of records dropped.  Unparseable lines are preserved."""
    if keep < 1:
        raise ValueError("--prune-keep must be >= 1")
    if not path.exists():
        return 0
    lines = [ln for ln in path.read_text().splitlines() if ln.strip()]
    keys = []
    for ln in lines:
        try:
            rec = json.loads(ln)
            keys.append((rec.get("bench"), rec.get("scale")))
        except json.JSONDecodeError:
            keys.append(None)   # never prune what we can't parse
    seen: dict = {}
    for i, key in enumerate(keys):
        if key is not None:
            seen.setdefault(key, []).append(i)
    drop = {i for idxs in seen.values() for i in idxs[:-keep]}
    if drop:
        path.write_text(
            "".join(ln + "\n" for i, ln in enumerate(lines) if i not in drop))
    return len(drop)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--quick", action="store_true",
                    help="smaller graphs, no sweeps")
    ap.add_argument("--prune-keep", type=int, default=None, metavar="N",
                    help="after the run, keep only the last N BENCH.json "
                         "records per (bench, scale)")
    args = ap.parse_args()
    scale = 0.25 if args.quick else args.scale
    sweep = not args.quick

    from . import (
        construction,
        coverage,
        distance_dist,
        frontier_relay,
        graph_updates,
        label_size,
        qos_scheduler,
        query_time,
        roofline,
        serving_throughput,
        sharded_memory,
        sketch_kernel,
        streaming_admission,
        trace_replay,
    )
    from .common import emit

    bench_bytes0 = BENCH_PATH.stat().st_size if BENCH_PATH.exists() else 0
    t0 = time.time()
    print("name,us_per_call,derived")
    for mod, kw in (
        (distance_dist, {}),
        (construction, {"sweep": sweep}),
        (label_size, {"sweep": sweep}),
        (query_time, {"sweep": sweep}),
        (coverage, {}),
        (frontier_relay, {}),
        (serving_throughput, {}),
        (streaming_admission, {}),
        (qos_scheduler, {}),
        (trace_replay, {}),
        (graph_updates, {}),
        (roofline, {}),
        (sharded_memory, {}),
    ):
        t = time.time()
        emit(mod.run(scale=scale, **kw))
        print(f"# {mod.__name__} done in {time.time() - t:.1f}s", file=sys.stderr)
    emit(sketch_kernel.run())
    bench_bytes1 = BENCH_PATH.stat().st_size if BENCH_PATH.exists() else 0
    print(f"# BENCH.json: +{bench_bytes1 - bench_bytes0} bytes appended "
          f"({bench_bytes1} total)", file=sys.stderr)
    if args.prune_keep is not None:
        dropped = prune_bench(BENCH_PATH, args.prune_keep)
        size = BENCH_PATH.stat().st_size if BENCH_PATH.exists() else 0
        print(f"# BENCH.json: pruned {dropped} record(s), keeping last "
              f"{args.prune_keep} per (bench, scale) ({size} bytes)",
              file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
