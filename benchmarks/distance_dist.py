"""Figure 7: distance distribution of randomly sampled query pairs.
Validates that the synthetic suite reproduces the paper's regime (most
random pairs at distance 2-9 on complex networks)."""
from __future__ import annotations

import numpy as np

from repro.core import INF
from repro.core.baselines import bfs_distances

from .common import bench_suite, emit, sample_queries

N_PAIRS = 300


def run(scale: float = 1.0) -> list[tuple]:
    rows = []
    for bg in bench_suite(scale * 0.5):
        us, vs = sample_queries(bg.graph, N_PAIRS, seed=11)
        dists = []
        memo = {}
        for u, v in zip(us, vs):
            u, v = int(u), int(v)
            if u not in memo:
                memo[u] = bfs_distances(bg.graph, u)
            d = memo[u][v]
            if u != v and d < INF:
                dists.append(int(d))
        hist = np.bincount(dists, minlength=12)[:12]
        frac_2_9 = sum(hist[2:10]) / max(len(dists), 1)
        rows.append((f"distance_dist/{bg.name}", float(np.mean(dists)) if dists else -1,
                     "hist=" + "|".join(map(str, hist.tolist()))
                     + f";frac2to9={frac_2_9:.2f}"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
