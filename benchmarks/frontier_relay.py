"""Frontier-relay microbenchmark: ``segment_max`` vs the hybrid hub/tail
backend (and the CSR pull variant) on the two structural regimes the split
is about — hub-heavy barabasi_albert, where high-degree hubs concentrate
edge traffic into the dense block, and flat random_regular, where no hub
block exists and hybrid must not lose to the reference.

Emits the standard ``name,us_per_call,derived`` CSV rows (derived = speedup
vs segment on the same graph/width) and appends one JSON record per
invocation to the BENCH.json trajectory at the repo root, so successive
PRs accumulate a comparable relay-performance history.

On top of the fixed-config comparison, a config sweep picks the best
``n_hubs`` for the hybrid backend and the best ``block_size`` for the
CSR backend per graph (at the labelling width K=20) and records one
``config="hybrid-best"`` / ``config="csr-best"`` row each.  The winning
config values ride along as float columns (``best_n_hubs`` /
``best_block_size``) so they stay out of the gate's row key — the gate
tracks the best-achievable latency, not which knob achieved it.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import barabasi_albert_graph, make_relay, random_regular_graph

from .common import interleaved_best

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH.json"

# relay widths: K=1 is the online bidirectional search, K=20 the batched
# labelling program (|R| simultaneous BFSs)
WIDTHS = (1, 20)

# config sweeps (best-of per graph at K=20): hybrid hub-block sizes and
# CSR edge-block sizes (0 = unblocked single pass)
HUB_SWEEP = (64, 128, 256, 512, 1024)
BLOCK_SWEEP = (0, 1024, 4096, 16384)
SWEEP_K = 20


def _graphs(scale: float):
    n1 = max(256, int(8_000 * scale))
    n2 = max(256, int(6_000 * scale))
    return [
        ("ba-hub", barabasi_albert_graph(n1, 3, seed=1)),
        ("reg-flat", random_regular_graph(n2, 8, seed=3)),
    ]


def _time_interleaved(fns: dict, vals, rounds: int = 15) -> dict:
    """min-of-N over the shared ``common.interleaved_best`` timer, with
    each relay synced through ``block_until_ready`` so the async dispatch
    doesn't leak out of its cell."""
    cells = {name: (lambda fn=fn: jax.block_until_ready(fn(vals)))
             for name, fn in fns.items()}
    return interleaved_best(cells, rounds=rounds)


def run(scale: float = 1.0, n_hubs: int = 512, **_) -> list[tuple]:
    rows: list[tuple] = []
    record = {"bench": "frontier_relay", "ts": time.time(),
              "scale": scale, "n_hubs": n_hubs, "rows": []}
    rng = np.random.default_rng(0)
    for gname, g in _graphs(scale):
        engines = {
            "segment": make_relay(g, backend="segment"),
            "csr": make_relay(g, backend="csr"),
            "hybrid": make_relay(g, backend="hybrid",
                                 n_hubs=min(n_hubs, g.n_vertices // 4)),
        }
        # one jit per fresh engine/graph — shapes change every iteration,
        # so per-loop construction is the point, not recompile churn
        fns = {name: jax.jit(e.relay)  # qbslint: disable=QBS004
               for name, e in engines.items()}
        for k in WIDTHS:
            vals = jnp.asarray(rng.random((k, g.n_vertices)) < 0.1)
            best = _time_interleaved(fns, vals)
            base = best["segment"]
            for bname, dt in best.items():
                speedup = base / max(dt, 1e-12)
                rows.append((f"relay/{gname}/K{k}/{bname}", dt * 1e6,
                             round(speedup, 3)))
                record["rows"].append({
                    "graph": gname, "k": k, "backend": bname,
                    "us_per_call": dt * 1e6, "speedup_vs_segment": speedup,
                    "V": g.n_vertices, "E": g.n_edges,
                })
        # --- best-config sweeps at the labelling width ------------------
        vals = jnp.asarray(rng.random((SWEEP_K, g.n_vertices)) < 0.1)
        base = _time_interleaved(
            {"segment": jax.jit(engines["segment"].relay)}, # qbslint: disable=QBS004
            vals)["segment"]
        hubs = sorted({min(h, g.n_vertices // 4) for h in HUB_SWEEP})
        hyb = {h: jax.jit(make_relay(g, backend="hybrid",  # qbslint: disable=QBS004
                                     n_hubs=h).relay)
               for h in hubs}
        csr = {b: jax.jit(make_relay(g, backend="csr",     # qbslint: disable=QBS004
                                     block_size=b).relay)
               for b in BLOCK_SWEEP}
        for cfg, key, fns in (("hybrid-best", "best_n_hubs", hyb),
                              ("csr-best", "best_block_size", csr)):
            best = _time_interleaved(
                {str(c): fn for c, fn in fns.items()}, vals)
            c, dt = min(best.items(), key=lambda kv: kv[1])
            speedup = base / max(dt, 1e-12)
            rows.append((f"relay/{gname}/K{SWEEP_K}/{cfg}", dt * 1e6,
                         f"{key}={c};speedup={speedup:.3f}"))
            record["rows"].append({
                "graph": gname, "k": SWEEP_K, "config": cfg,
                "us_per_call": dt * 1e6, key: float(c),
                "speedup_vs_segment": speedup,
                "V": g.n_vertices, "E": g.n_edges,
            })
    with BENCH_PATH.open("a") as f:
        f.write(json.dumps(record) + "\n")
    return rows


if __name__ == "__main__":
    from .common import emit

    print("name,us_per_call,derived")
    emit(run())
