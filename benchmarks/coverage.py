"""Figure 8: pair coverage ratios under varying landmark counts.

Case (i): ALL shortest paths between the pair cross a landmark (equivalent
to d_{G-}(u,v) > d_G(u,v)).  Case (ii): some but not all do.  The sketch
can only guide queries with coverage, so these ratios explain QbS's
per-dataset behaviour (§6.3).
"""
from __future__ import annotations

import numpy as np

from repro.core import INF, select_landmarks
from repro.core.baselines import bfs_distances
from repro.core.graph import Graph, from_edges

from .common import bench_suite, emit, sample_queries

N_PAIRS = 200


def sparsify(graph: Graph, landmarks) -> Graph:
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    is_l = np.zeros(graph.n_vertices, bool)
    is_l[np.asarray(landmarks)] = True
    keep = ~is_l[src] & ~is_l[dst] & (src < dst)
    return from_edges(np.stack([src[keep], dst[keep]], 1), graph.n_vertices)


def coverage(graph: Graph, n_landmarks: int, seed: int = 0) -> tuple[float, float]:
    landmarks = select_landmarks(graph, n_landmarks)
    us, vs = sample_queries(graph, N_PAIRS, seed)
    lm_d = np.stack([bfs_distances(graph, int(r)) for r in landmarks])  # (R, V)
    g_minus = sparsify(graph, landmarks)
    all_cross = 0
    some_cross = 0
    n_valid = 0
    # distances in G- from each unique u (memoized)
    memo: dict[int, np.ndarray] = {}
    for u, v in zip(us, vs):
        u, v = int(u), int(v)
        du = bfs_distances(graph, u)
        d = du[v]
        if u == v or d >= INF:
            continue
        n_valid += 1
        through = (lm_d[:, u] + lm_d[:, v] == d).any()
        if not through:
            continue
        if u not in memo:
            memo[u] = bfs_distances(g_minus, u)
        if memo[u][v] > d:
            all_cross += 1
        else:
            some_cross += 1
    return all_cross / max(n_valid, 1), some_cross / max(n_valid, 1)


def run(scale: float = 1.0) -> list[tuple]:
    rows = []
    for bg in bench_suite(scale * 0.5):
        for r in (5, 10, 20, 40):
            all_c, some_c = coverage(bg.graph, r)
            rows.append((f"coverage/R{r}/{bg.name}", (all_c + some_c) * 100,
                         f"all={all_c:.3f};some={some_c:.3f}"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
