"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape) cell on the single-pod mesh:

    compute term    = HLO_FLOPs_per_device / 197e12      (bf16 peak, v5e)
    memory term     = HLO_bytes_per_device / 819e9       (HBM bw)
    collective term = collective_bytes_per_device / 50e9 (ICI link bw)

FLOPs/bytes/collective-bytes come from the depth-extrapolated probes (XLA's
HloCostAnalysis visits scan bodies once; see launch/dryrun.py); memory
footprints come from the real-depth compile.  MODEL_FLOPS = 6*N*D (train) /
2*N*D (inference) with N_active for MoE — the usefulness ratio flags
remat/redundancy waste.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md out.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


PEAK_FLOPS = 197e12       # bf16 / chip (TPU v5e)
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / ICI link

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def _param_count(cfg) -> tuple[float, float]:
    """(total params, active params) analytically from the config."""
    d, f, v, l = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    hd = cfg.hd
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
    if cfg.family == "ssm":  # rwkv6: 4 d^2 timemix + d*f*2 + d^2 channelmix
        per_layer = 5 * d * d + 2 * d * f
        total = l * per_layer + 2 * v * d
        return total, total
    mlp = 3 * d * f
    if cfg.moe_experts:
        dense_part = attn
        expert_part = cfg.moe_experts * mlp
        active_part = cfg.moe_top_k * mlp
        total = l * (dense_part + expert_part) + 2 * v * d
        active = l * (dense_part + active_part) + 2 * v * d
        return total, active
    if cfg.family == "hybrid":
        d_in = d * cfg.ssm_expand
        n = cfg.ssm_state
        heads = cfg.ssm_heads or max(1, d_in // 64)
        mamba = d * (2 * d_in + 2 * n * heads + heads) + d_in * d
        shared = 2 * d * d + attn + mlp + d * d
        total = l * mamba + shared + 2 * v * d
        return total, total
    total = l * (attn + mlp) + 2 * v * d
    return total, total


def _tokens(shape) -> int:
    if shape.kind == "train" or shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one token per sequence


def analyze_cell(name: str, data: dict, cfg, shape, n_chips: int) -> dict | None:
    if "error" in data or "skipped" in data:
        return None
    ext = data.get("depth_extrapolated", {})
    flops = ext.get("flops", data["flops"])
    bytes_acc = ext.get("bytes_accessed", data["bytes_accessed"])
    coll = ext.get("collectives", {k: v for k, v in data["collectives"].items()
                                   if k != "_counts"})
    coll_bytes = float(sum(coll.values()))

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_bytes / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)  # type: ignore[arg-type]

    total, active = _param_count(cfg)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * active * _tokens(shape)
    hlo_global = flops * n_chips
    ratio = model_flops / hlo_global if hlo_global else 0.0

    bound = max(terms.values())
    # roofline fraction: useful model flops vs what the dominant term's time
    # would allow at peak
    step_time = bound
    achievable = model_flops / n_chips / PEAK_FLOPS
    frac = achievable / step_time if step_time > 0 else 0.0

    notes = {
        "compute": "compute-bound: cut non-model FLOPs (remat policy, fused "
                   "attention, avoid fp32 softmax up-casts)",
        "memory": "HBM-bound: fuse elementwise chains, int8 KV cache, "
                  "larger per-step tiles to lift arithmetic intensity",
        "collective": "ICI-bound: reduce-scatter+all-gather decomposition, "
                      "bf16/int8 compressed grads, overlap collectives "
                      "with per-layer compute",
    }
    return {
        "cell": name,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": ratio,
        "roofline_fraction": min(frac, 1.0),
        "collective_bytes": coll_bytes,
        "note": notes[dominant],
    }


def collect(mesh: str = "single", variant: str = "") -> list[dict]:
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    from repro.configs import ARCHS
    from repro.models import SHAPES

    rows = []
    n_chips = 256 if mesh == "single" else 512
    for arch in sorted(ARCHS):
        for shape_name, shape in SHAPES.items():
            suffix = f"__{variant}" if variant else ""
            p = RESULTS / f"lm__{arch}__{shape_name}__{mesh}{suffix}.json"
            if not p.exists():
                continue
            data = json.loads(p.read_text())
            row = analyze_cell(f"{arch}/{shape_name}", data, ARCHS[arch],
                               shape, n_chips)
            if row:
                rows.append(row)
    return rows


def qbs_rows(mesh: str = "single") -> list[dict]:
    rows = []
    n_chips = 256 if mesh == "single" else 512
    for p in sorted(RESULTS.glob(f"qbs-*__*__{mesh}.json")):
        data = json.loads(p.read_text())
        if "error" in data or "skipped" in data:
            continue
        coll = {k: v for k, v in data["collectives"].items() if k != "_counts"}
        cb = float(sum(coll.values()))
        terms = {
            "compute": data["flops"] / PEAK_FLOPS,
            "memory": data["bytes_accessed"] / HBM_BW,
            "collective": cb / LINK_BW,
        }
        dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
        rows.append({
            "cell": p.stem,
            "t_compute_s": terms["compute"],
            "t_memory_s": terms["memory"],
            "t_collective_s": terms["collective"],
            "dominant": dominant,
            "collective_bytes": cb,
            "note": "per-BFS-level terms (while-loop body; multiply by "
                    "expected diameter ~8-12 levels, paper Fig. 7)",
        })
    return rows


def to_markdown(rows: list[dict], title: str) -> str:
    out = [f"### {title}", "",
           "| cell | compute (s) | memory (s) | collective (s) | dominant | "
           "useful ratio | roofline frac | note |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['cell']} | {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r.get('useful_ratio', float('nan')):.2f} "
            f"| {r.get('roofline_fraction', float('nan')):.2f} | {r['note']} |")
    return "\n".join(out) + "\n"


# ---------------------------------------------------------------------------
# Measured kernel roofline (bench_gate-gated): the packed hot-path kernels'
# XLA reference math timed against an optimistic CPU roofline.  Unlike the
# dry-run analysis above (modelled TPU terms from compiled artifacts), these
# rows are *measurements* on the machine running the bench: achieved
# fraction = ideal time at peak / measured wall time, clamped to 1.  The
# fractions land in BENCH.json and scripts/bench_gate.py holds them above an
# absolute floor (--frac-floor) — a kernel silently falling off its roofline
# (accidental dtype widening, a dense materialization of the packed words)
# shows up as a collapsed fraction long before qps notices.
# ---------------------------------------------------------------------------

CPU_PEAK_FLOPS = 5e10     # optimistic single-socket f32 peak (CI runners)
CPU_MEM_BW = 2e10         # B/s; together these overestimate, which is fine:
                          # the floor gates collapse, not absolute efficiency

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH.json"


def _ideal_us(flops: float, bytes_moved: float) -> float:
    return max(flops / CPU_PEAK_FLOPS, bytes_moved / CPU_MEM_BW) * 1e6


def run(scale: float = 1.0, **_) -> list[tuple]:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.graph import INF
    from repro.core.packing import pack_bits, pack_dist, unpack_bits
    from repro.core.sketch import d_top_only

    from .common import interleaved_best

    rng = np.random.default_rng(0)

    # -- hub-relay expand over bit-packed words (kernels/frontier.py) ------
    h = max(512, int(2048 * scale))
    r = 64
    f = jnp.asarray(rng.random((r, h)) < 0.05)
    words = pack_bits(jnp.asarray(rng.random((h, h)) < 0.02))

    @jax.jit
    def expand(f, words):
        a = unpack_bits(words, h).astype(jnp.float32)
        return jnp.dot(f.astype(jnp.float32), a,
                       preferred_element_type=jnp.float32) > 0.5

    expand_flops = 2.0 * r * h * h
    expand_bytes = float(f.nbytes + words.nbytes + r * h)

    # -- Eq. 3 min-plus sketch contraction over packed labels --------------
    b_q = max(128, int(512 * scale))
    n_lm = 64
    lu_i = rng.integers(0, 200, size=(b_q, n_lm)).astype(np.int32)
    lu_i[rng.random((b_q, n_lm)) < 0.3] = INF
    dm_i = rng.integers(0, 200, size=(n_lm, n_lm)).astype(np.int32)
    lu = pack_dist(lu_i, np.uint8)
    lv = pack_dist(lu_i[::-1].copy(), np.uint8)
    dm = pack_dist(dm_i, np.uint8)
    sketch = jax.jit(d_top_only)

    sketch_flops = 2.0 * 2 * b_q * n_lm * n_lm   # two (min, +) contractions
    sketch_bytes = float(lu.nbytes + lv.nbytes + dm.nbytes + 4 * b_q)

    cells = {
        "bitmap_expand": lambda: expand(f, words).block_until_ready(),
        "minplus_sketch": lambda: sketch(lu, lv, dm).block_until_ready(),
    }
    best = interleaved_best(cells, rounds=12)

    specs = {
        "bitmap_expand": (f"{r}x{h}", expand_flops, expand_bytes),
        "minplus_sketch": (f"{b_q}x{n_lm}", sketch_flops, sketch_bytes),
    }
    rows: list[tuple] = []
    record = {"bench": "roofline", "ts": time.time(), "scale": scale,
              "rows": []}
    for kernel, dt in best.items():
        shape, flops, nbytes = specs[kernel]
        wall_us = dt * 1e6
        ideal = _ideal_us(flops, nbytes)
        frac = min(ideal / max(wall_us, 1e-9), 1.0)
        rows.append((f"roofline/{kernel}/{shape}", wall_us,
                     f"frac={frac:.4f},ideal_us={ideal:.1f}"))
        record["rows"].append({"kernel": kernel, "shape": shape,
                               "roofline_frac": frac, "wall_us": wall_us,
                               "ideal_us": ideal})
    with BENCH_PATH.open("a") as fh:
        fh.write(json.dumps(record) + "\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    rows = collect(args.mesh, args.variant)
    qrows = qbs_rows(args.mesh)
    md = to_markdown(rows, f"LM cells ({args.mesh}-pod)")
    md += "\n" + to_markdown(qrows, f"QbS engine cells ({args.mesh}-pod)")
    if args.md:
        Path(args.md).write_text(md)
    print(md)
    for r in rows:
        print(f"{r['cell']},{r['dominant']},{r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
