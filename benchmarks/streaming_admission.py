"""Streaming admission control: open-loop arrival traces through the
``StreamingService`` (DESIGN.md §5).

Traffic arrives as a *stream* of timed groups, not a complete batch, so
this bench drives the admission layer the way callers can't be trusted
to: an open-loop simulation where each tick submits the tick's arrivals
and idle gaps force a flush (the latency deadline a real deployment
would enforce).  Three trace shapes × an arrival-rate sweep:

* **steady(rate)** — ``rate`` queries per tick, deadline flush every few
  ticks: the regime where admission should batch aggressively.
* **bursty** — alternating full bursts and per-query trickles with idle
  gaps: the regime adaptive chunking exists for.  A fixed-width policy
  pads every trickle flush out to a full chunk; the adaptive policy
  shrinks the width to the arrival rate and grows it back inside bursts.
* **repeat-heavy** — a hub-skewed repeat stream (hot pairs touch
  landmarks/high-degree hubs, cold traffic floods the cache): the regime
  the hub-skew eviction policy (protected slots, ``cache_policy="hub"``)
  exists for, compared against plain LRU at equal capacity.

Policies compared at equal everything-else: ``fixed`` (admission at the
index's build-time width, adaptive off) vs ``adaptive``; ``lru`` vs
``hub`` caches on the repeat trace.  Timing is interleaved min-of-N
(``common.interleaved_best``); derived columns report adaptive-vs-fixed
speedup per trace and the two cache hit rates.  Appends one JSON record
per invocation to BENCH.json (gated in CI by ``scripts/bench_gate.py``).
"""
from __future__ import annotations

import json
import time
from functools import partial
from pathlib import Path

import numpy as np

from repro.core import QbSIndex, barabasi_albert_graph
from repro.serving import AdmissionPolicy, StreamingService

from .common import interleaved_best

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH.json"

ROUNDS = 6
FIXED_CHUNK = 32
RATES = (2, 8, 32)          # steady-trace arrivals per tick
BURST = 48                  # bursty-trace burst size
TRICKLE = 8                 # trickle ticks (1 query + flush) after a burst
CACHE_SIZE = 20
HOT_PAIRS = 10


def _policies() -> dict[str, AdmissionPolicy]:
    return {
        "fixed": AdmissionPolicy(adaptive=False, chunk=FIXED_CHUNK),
        "adaptive": AdmissionPolicy(adaptive=True, chunk=FIXED_CHUNK,
                                    min_chunk=4, max_chunk=128),
    }


def _steady_trace(g, n: int, rate: int, seed: int) -> list[tuple]:
    """(us, vs, flush) groups: ``rate`` arrivals per tick, deadline flush
    every 4 ticks."""
    rng = np.random.default_rng(seed)
    us = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    vs = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    groups = []
    for tick, start in enumerate(range(0, n, rate)):
        sl = slice(start, start + rate)
        groups.append((us[sl], vs[sl], tick % 4 == 3))
    return groups


def _bursty_trace(g, n_patterns: int, seed: int) -> list[tuple]:
    """Alternating burst (BURST arrivals, one tick) and trickle (TRICKLE
    ticks of one query, each ending in an idle-gap flush)."""
    rng = np.random.default_rng(seed)
    n = n_patterns * (BURST + TRICKLE)
    us = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    vs = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    groups = []
    pos = 0
    for _ in range(n_patterns):
        groups.append((us[pos:pos + BURST], vs[pos:pos + BURST], True))
        pos += BURST
        for _ in range(TRICKLE):
            groups.append((us[pos:pos + 1], vs[pos:pos + 1], True))
            pos += 1
    return groups


def _repeat_trace(g, idx, n: int, seed: int) -> list[tuple]:
    """Hub-skewed repeat stream: 30% of arrivals cycle over HOT_PAIRS
    hub-endpoint pairs, 70% are fresh cold (non-hub) pairs that flood an
    LRU of CACHE_SIZE between hot recurrences; groups of 8, every group
    deadline-flushed."""
    rng = np.random.default_rng(seed)
    prot = idx._is_landmark_np | g.hub_mask(top_frac=0.01)
    hubs = np.flatnonzero(prot)
    cold = np.flatnonzero(~prot)
    hot_u = rng.choice(hubs, size=HOT_PAIRS)
    hot_v = rng.choice(cold, size=HOT_PAIRS)
    us = rng.choice(cold, size=n).astype(np.int32)
    vs = rng.choice(cold, size=n).astype(np.int32)
    hot = rng.random(n) < 0.3
    pick = rng.integers(0, HOT_PAIRS, size=n)
    us = np.where(hot, hot_u[pick], us).astype(np.int32)
    vs = np.where(hot, hot_v[pick], vs).astype(np.int32)
    return [(us[s:s + 8], vs[s:s + 8], True) for s in range(0, n, 8)]


def _run_trace(idx, groups, policy: AdmissionPolicy, **service_kw) -> StreamingService:
    svc = StreamingService(idx, policy=policy, **service_kw)
    for us, vs, flush in groups:
        svc.submit_batch(us, vs)
        if flush:
            svc.drain()
    svc.drain()
    return svc


def run(scale: float = 1.0, **_) -> list[tuple]:
    n_v = max(800, int(6_000 * scale))
    g = barabasi_albert_graph(n_v, 4, seed=5)
    idx = QbSIndex.build(g, n_landmarks=16, chunk=FIXED_CHUNK)
    gname = f"ba-{n_v}"
    policies = _policies()

    n_steady = max(64, int(192 * scale))
    traces = {("steady", rate): _steady_trace(g, n_steady, rate, seed=7 + rate)
              for rate in RATES}
    traces[("bursty", 0)] = _bursty_trace(
        g, n_patterns=max(2, int(4 * scale)), seed=11)

    rows: list[tuple] = []
    record = {"bench": "streaming_admission", "ts": time.time(),
              "scale": scale, "graph": gname, "V": g.n_vertices,
              "E": g.n_edges, "fixed_chunk": FIXED_CHUNK, "rows": []}

    cells = {(t, r, pname): partial(_run_trace, idx, groups, pol)
             for (t, r), groups in traces.items()
             for pname, pol in policies.items()}
    best = interleaved_best(cells, rounds=ROUNDS)
    for (trace, rate, pname), dt in best.items():
        n_q = sum(u.size for u, _, _ in traces[(trace, rate)])
        qps = n_q / max(dt, 1e-9)
        speedup = best[(trace, rate, "fixed")] / max(dt, 1e-9)
        rows.append((f"stream/{trace}{rate or ''}/{pname}/{gname}",
                     dt / n_q * 1e6,
                     f"qps={qps:.1f},speedup_vs_fixed={speedup:.2f}x"))
        record["rows"].append({
            "trace": trace, "rate": rate, "policy": pname, "qps": qps,
            "us_per_query": dt / n_q * 1e6, "speedup_vs_fixed": speedup,
        })
    adaptive_speedup = (best[("bursty", 0, "fixed")]
                        / max(best[("bursty", 0, "adaptive")], 1e-9))
    rows.append((f"stream/adaptive_speedup_bursty/{gname}",
                 round(adaptive_speedup, 3), f"fixed_chunk={FIXED_CHUNK}"))
    record["adaptive_speedup_bursty"] = adaptive_speedup

    # hub-skew eviction vs LRU at equal capacity on the repeat-heavy trace:
    # hit rates from one fresh pass each (the timing loop would re-serve a
    # warm cache), then interleaved qps
    repeat = _repeat_trace(g, idx, n=max(96, int(256 * scale)), seed=13)
    n_q = sum(u.size for u, _, _ in repeat)
    hit_rates = {}
    for cpol in ("lru", "hub"):
        svc = _run_trace(idx, repeat, policies["adaptive"],
                         cache_size=CACHE_SIZE, cache_policy=cpol)
        c = svc.service.cache
        hit_rates[cpol] = c.hits / max(c.hits + c.misses, 1)
    best = interleaved_best(
        {cpol: partial(_run_trace, idx, repeat, policies["adaptive"],
                       cache_size=CACHE_SIZE, cache_policy=cpol)
         for cpol in ("lru", "hub")},
        rounds=ROUNDS)
    for cpol, dt in best.items():
        qps = n_q / max(dt, 1e-9)
        rows.append((f"stream/repeat-heavy/{cpol}/{gname}", dt / n_q * 1e6,
                     f"qps={qps:.1f},fresh_pass_hit_rate={hit_rates[cpol]:.2f}"))
        record["rows"].append({
            "trace": "repeat-heavy", "rate": 0, "policy": cpol, "qps": qps,
            "us_per_query": dt / n_q * 1e6,
        })
    record["lru_hit_rate"] = hit_rates["lru"]
    record["hub_hit_rate"] = hit_rates["hub"]

    with BENCH_PATH.open("a") as f:
        f.write(json.dumps(record) + "\n")
    return rows


def main() -> None:
    from .common import emit

    print("name,us_per_call,derived")
    emit(run())


if __name__ == "__main__":
    main()
