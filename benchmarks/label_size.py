"""Table 3 + Figure 9: labelling sizes.

size(L): |R| * 8 bits per vertex (paper's packing).  size(Delta): edges of
precomputed landmark-to-landmark SPGs, derived from labels exactly as the
recover search does.  Meta-graph size is bounded by |R|^2 entries.
PPL/ParentPPL label-entry counts show the blowup the paper reports
(hundreds of times larger).

The ``label_size/packed/*`` rows measure what the serving tables
*actually occupy* in HBM (``core.packing``, DESIGN.md §10): packed
uint8/uint16 bytes vs the int32 baseline layout, appended to BENCH.json
per graph (acceptance floor: ratio >= 3.5x; uint8 gives exactly 4.0x).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np


from repro.core import INF, build_labelling, labelling_size_bytes, select_landmarks
from repro.core.baselines import PPLIndex
from repro.core.packing import packed_size_bytes

from .common import bench_suite, emit

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH.json"

PPL_CAP = 1_500
PARENT_CAP = 600


def delta_size_edges(graph, scheme) -> int:
    """|Delta|: for every meta edge (i, j), count G- edges certified on a
    landmark-free shortest r_i..r_j path (+ boundary hops)."""
    ld = np.asarray(scheme.label_dist)
    w = np.asarray(scheme.meta_w)
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    is_l = np.asarray(scheme.is_landmark)
    gminus = ~is_l[src] & ~is_l[dst]
    total = 0
    r = w.shape[0]
    for i in range(r):
        for j in range(r):
            if w[i, j] >= INF:
                continue
            cert = gminus & (ld[src, i] + 1 + ld[dst, j] == w[i, j])
            total += int(cert.sum())
            # boundary hops counted once per direction
            lm_i = scheme.landmarks[i]
            hop = (src == int(lm_i)) & (ld[dst, j] == w[i, j] - 1)
            total += int(hop.sum())
    return total // 2  # both orientations counted


def run(scale: float = 1.0, sweep: bool = False) -> list[tuple]:
    rows = []
    record = {"bench": "label_size", "ts": time.time(), "scale": scale,
              "rows": []}
    for bg in bench_suite(scale):
        g = bg.graph
        scheme = build_labelling(g, select_landmarks(g, 20))
        sz = labelling_size_bytes(scheme)
        graph_bytes = g.n_edges * 4  # paper: 8 bytes per undirected edge
        rows.append((f"label_size/qbs_L/{bg.name}", sz["label_bytes"],
                     f"ratio_to_graph={sz['label_bytes'] / graph_bytes:.3f}"))
        psz = packed_size_bytes(scheme.packed())
        rows.append((f"label_size/packed/{bg.name}", psz["packed_bytes"],
                     f"ratio={psz['ratio']:.2f}x,dtype={psz['dtype']}"))
        record["rows"].append({
            "graph": bg.name, "dtype": psz["dtype"],
            "packed_bytes": float(psz["packed_bytes"]),
            "int32_bytes": float(psz["int32_bytes"]),
            "bytes_per_vertex": psz["packed_bytes"] / g.n_vertices,
            "ratio": psz["ratio"],
        })
        d_edges = delta_size_edges(g, scheme)
        rows.append((f"label_size/qbs_delta/{bg.name}", d_edges * 5,
                     f"edges={d_edges}"))
        rows.append((f"label_size/qbs_meta/{bg.name}", sz["meta_bytes"],
                     f"meta_edges={sz['n_meta_edges']}"))
        if g.n_vertices <= PPL_CAP:
            ppl = PPLIndex(g)
            rows.append((f"label_size/ppl/{bg.name}", ppl.memory_bytes(),
                         f"entries={ppl.label_entries()};"
                         f"x_qbs={ppl.memory_bytes() / max(sz['label_bytes'], 1):.0f}"))
        else:
            rows.append((f"label_size/ppl/{bg.name}", -1, f"DNF-analog:V>{PPL_CAP}"))
        if g.n_vertices <= PARENT_CAP:
            pp = PPLIndex(g, store_parents=True)
            rows.append((f"label_size/parentppl/{bg.name}", pp.memory_bytes(),
                         f"entries={pp.label_entries()}"))
        else:
            rows.append((f"label_size/parentppl/{bg.name}", -1,
                         f"DNF-analog:V>{PARENT_CAP}"))

    if sweep:  # Figure 9
        g = bench_suite(scale)[0].graph
        for r in (5, 10, 20, 40, 80):
            scheme = build_labelling(g, select_landmarks(g, r))
            sz = labelling_size_bytes(scheme)
            rows.append((f"label_size/sweep_R{r}/ba-hub", sz["label_bytes"],
                         f"meta_edges={sz['n_meta_edges']}"))
    with BENCH_PATH.open("a") as fh:
        fh.write(json.dumps(record) + "\n")
    return rows


def main() -> None:
    emit(run(sweep=True))


if __name__ == "__main__":
    main()
