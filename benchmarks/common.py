"""Shared benchmark utilities: graph suite matched to the paper's structural
regimes (Table 1) at CPU-tractable sizes, timing helpers, CSV emission."""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core import (
    Graph,
    barabasi_albert_graph,
    gnp_random_graph,
    random_regular_graph,
)


@dataclass(frozen=True)
class BenchGraph:
    name: str
    regime: str          # analogue from Table 1
    graph: Graph


def bench_suite(scale: float = 1.0) -> list[BenchGraph]:
    """Three structural regimes the paper's analysis distinguishes (§6.3):
    hub-heavy (Youtube/Twitter-like), uniform-degree (Friendster-like), and
    small-diameter social (Orkut-like)."""
    n1 = int(8_000 * scale)
    n2 = int(6_000 * scale)
    n3 = int(4_000 * scale)
    return [
        BenchGraph("ba-hub", "hub-heavy (Youtube/Twitter)",
                   barabasi_albert_graph(n1, 3, seed=1)),
        BenchGraph("reg-flat", "flat-degree small-diameter (Friendster)",
                   random_regular_graph(n2, 8, seed=3)),
        BenchGraph("gnp-social", "dense-social (Orkut)",
                   gnp_random_graph(n3, 10.0, seed=2)),
    ]


def interleaved_best(cells: dict, rounds: int = 8,
                     warmup: bool = True) -> dict:
    """Min-of-N timing of zero-arg callables with all cells interleaved
    round-robin and the order rotated each round, so slow-machine noise
    (CI runners, shared CPUs) hits every cell equally instead of whichever
    was measured during the bad slice.  The one timing methodology shared
    by the comparative benches (frontier_relay, serving_throughput)."""
    if warmup:
        for fn in cells.values():
            fn()                     # warmup / compile
    best = {key: float("inf") for key in cells}
    keys = list(cells)
    for r in range(rounds):
        for key in keys[r % len(keys):] + keys[:r % len(keys)]:
            t0 = time.perf_counter()
            cells[key]()
            best[key] = min(best[key], time.perf_counter() - t0)
    return best


def time_call(fn, *args, repeat: int = 3, **kw) -> tuple[float, object]:
    out = fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return dt, out


def sample_queries(graph: Graph, n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    us = rng.integers(0, graph.n_vertices, size=n).astype(np.int32)
    vs = rng.integers(0, graph.n_vertices, size=n).astype(np.int32)
    return us, vs


def emit(rows: list[tuple]) -> None:
    """CSV protocol required by the harness: name,us_per_call,derived."""
    for name, us_per_call, derived in rows:
        print(f"{name},{us_per_call:.3f},{derived}")
