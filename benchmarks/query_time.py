"""Table 2 (right) + Figure 11: average query time.

QbS (sketch + guided search, batched) vs Bi-BFS (the paper's search
baseline) vs PPL / ParentPPL (recursive label queries, capped sizes).
Times are per query, amortized over a batch — the TPU-native serving mode
(DESIGN.md §2); Bi-BFS is batched identically so the comparison is fair.
"""
from __future__ import annotations

import numpy as np

from repro.core import QbSIndex, select_landmarks
from repro.core.baselines import PPLIndex, bibfs_spg_batch

from .common import bench_suite, emit, sample_queries, time_call

PPL_CAP = 1_500
PARENT_CAP = 600
N_QUERIES = 64


def run(scale: float = 1.0, sweep: bool = False) -> list[tuple]:
    rows = []
    for bg in bench_suite(scale):
        g = bg.graph
        us, vs = sample_queries(g, N_QUERIES, seed=7)
        idx = QbSIndex.build(g, n_landmarks=20, chunk=32)
        dt, res = time_call(lambda: idx.query_batch(us, vs), repeat=2)
        per_q = dt / N_QUERIES
        dists = [r.dist for r in res]
        rows.append((f"query/qbs/{bg.name}", per_q * 1e6,
                     f"avg_dist={np.mean([d for d in dists if d < 1 << 20]):.2f}"))

        dt_b, _ = time_call(lambda: bibfs_spg_batch(g, us, vs), repeat=2)
        rows.append((f"query/bibfs/{bg.name}", dt_b / N_QUERIES * 1e6,
                     f"qbs_speedup={dt_b / max(dt, 1e-9):.2f}x"))

        if g.n_vertices <= PPL_CAP:
            ppl = PPLIndex(g)
            dt_p, _ = time_call(
                lambda: [ppl.query(int(u), int(v)) for u, v in zip(us[:16], vs[:16])],
                repeat=1)
            rows.append((f"query/ppl/{bg.name}", dt_p / 16 * 1e6, "host-recursive"))
        else:
            rows.append((f"query/ppl/{bg.name}", -1, f"DNF-analog:V>{PPL_CAP}"))
        if g.n_vertices <= PARENT_CAP:
            pp = PPLIndex(g, store_parents=True)
            dt_pp, _ = time_call(
                lambda: [pp.query(int(u), int(v)) for u, v in zip(us[:16], vs[:16])],
                repeat=1)
            rows.append((f"query/parentppl/{bg.name}", dt_pp / 16 * 1e6, "host-recursive"))
        else:
            rows.append((f"query/parentppl/{bg.name}", -1,
                         f"DNF-analog:V>{PARENT_CAP}"))

    if sweep:  # Figure 11: query time vs |R|
        g = bench_suite(scale)[0].graph
        us, vs = sample_queries(g, 32, seed=8)
        for r in (5, 10, 20, 40):
            idx = QbSIndex.build(g, n_landmarks=r, chunk=32)
            dt, _ = time_call(lambda: idx.query_batch(us, vs), repeat=2)
            rows.append((f"query/sweep_R{r}/ba-hub", dt / 32 * 1e6, ""))
    return rows


def main() -> None:
    emit(run(sweep=True))


if __name__ == "__main__":
    main()
