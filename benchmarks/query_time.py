"""Table 2 (right) + Figure 11: average query time.

QbS (sketch + guided search, batched) vs Bi-BFS (the paper's search
baseline) vs PPL / ParentPPL (recursive label queries, capped sizes).
Times are per query, amortized over a batch — the TPU-native serving mode
(DESIGN.md §2); Bi-BFS is batched identically so the comparison is fair.

``serving_rows`` reports queries/sec for the planner/service path
(``QbSIndex.query_batch``) over the same query stream.  The old-path
column is gone with ``query_batch_legacy`` (seed semantics are pinned by
``tests/helpers/serving_oracle.py`` instead); sync-vs-async and traffic-mix
comparisons live in ``benchmarks/serving_throughput.py``.

A 10k-vertex synthetic graph (at the default --scale 1.0) is always
included so the numbers cover the scale regime the serving rework
targets.
"""
from __future__ import annotations

import numpy as np

from repro.core import QbSIndex, gnp_random_graph
from repro.core.baselines import PPLIndex, bibfs_spg_batch

from .common import bench_suite, emit, sample_queries, time_call

PPL_CAP = 1_500
PARENT_CAP = 600
N_QUERIES = 64


def serving_rows(g, name: str, n_queries: int = N_QUERIES,
                 seed: int = 7, idx: QbSIndex | None = None,
                 queries: tuple | None = None,
                 timing: float | None = None) -> list[tuple]:
    """Serving-path throughput on one graph: per-query µs + queries/sec.

    ``queries=(us, vs)`` supplies the query sample; ``timing`` reuses a
    seconds-per-batch measurement the caller already took on that exact
    sample, so the suite loop doesn't time ``query_batch`` twice.  Pass
    both together or neither."""
    us, vs = queries if queries is not None else sample_queries(
        g, n_queries, seed=seed)
    n_queries = us.shape[0]
    if idx is None:
        idx = QbSIndex.build(g, n_landmarks=20, chunk=32)

    dt = timing
    if dt is None:
        dt, _ = time_call(lambda: idx.query_batch(us, vs), repeat=2)

    qps = n_queries / max(dt, 1e-9)
    return [
        (f"query/qbs_serve/{name}", dt / n_queries * 1e6, f"qps={qps:.0f}"),
    ]


def run(scale: float = 1.0, sweep: bool = False) -> list[tuple]:
    rows = []
    for bg in bench_suite(scale):
        g = bg.graph
        us, vs = sample_queries(g, N_QUERIES, seed=7)
        idx = QbSIndex.build(g, n_landmarks=20, chunk=32)
        dt, res = time_call(lambda: idx.query_batch(us, vs), repeat=2)
        per_q = dt / N_QUERIES
        dists = [r.dist for r in res]
        rows.append((f"query/qbs/{bg.name}", per_q * 1e6,
                     f"avg_dist={np.mean([d for d in dists if d < 1 << 20]):.2f}"))

        dt_b, _ = time_call(lambda: bibfs_spg_batch(g, us, vs), repeat=2)
        rows.append((f"query/bibfs/{bg.name}", dt_b / N_QUERIES * 1e6,
                     f"qbs_speedup={dt_b / max(dt, 1e-9):.2f}x"))

        rows.extend(serving_rows(g, bg.name, idx=idx, queries=(us, vs),
                                 timing=dt))

        if g.n_vertices <= PPL_CAP:
            ppl = PPLIndex(g)
            dt_p, _ = time_call(
                lambda: [ppl.query(int(u), int(v)) for u, v in zip(us[:16], vs[:16])],
                repeat=1)
            rows.append((f"query/ppl/{bg.name}", dt_p / 16 * 1e6, "host-recursive"))
        else:
            rows.append((f"query/ppl/{bg.name}", -1, f"DNF-analog:V>{PPL_CAP}"))
        if g.n_vertices <= PARENT_CAP:
            pp = PPLIndex(g, store_parents=True)
            dt_pp, _ = time_call(
                lambda: [pp.query(int(u), int(v)) for u, v in zip(us[:16], vs[:16])],
                repeat=1)
            rows.append((f"query/parentppl/{bg.name}", dt_pp / 16 * 1e6, "host-recursive"))
        else:
            rows.append((f"query/parentppl/{bg.name}", -1,
                         f"DNF-analog:V>{PARENT_CAP}"))

    # serving-path comparison at the 10k-vertex scale the rework targets
    # (respects --scale so quick runs stay quick)
    n_big = max(1_000, int(10_000 * scale))
    rows.extend(serving_rows(gnp_random_graph(n_big, 8.0, seed=5),
                             f"gnp-{n_big}"))

    if sweep:  # Figure 11: query time vs |R|
        g = bench_suite(scale)[0].graph
        us, vs = sample_queries(g, 32, seed=8)
        for r in (5, 10, 20, 40):
            idx = QbSIndex.build(g, n_landmarks=r, chunk=32)
            dt, _ = time_call(lambda: idx.query_batch(us, vs), repeat=2)
            rows.append((f"query/sweep_R{r}/ba-hub", dt / 32 * 1e6, ""))
    return rows


def main() -> None:
    emit(run(sweep=True))


if __name__ == "__main__":
    main()
