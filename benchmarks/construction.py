"""Table 2 (left) + Figure 10: labelling construction time.

QbS-P (batched-parallel BFS over landmarks — our TPU-native default) vs QbS
(sequential per-landmark loop, the paper's single-thread analogue) vs PPL /
ParentPPL (pruned path labelling; capped sizes — the paper's own result is
that they DNF beyond small graphs, and their host-side cost here blows up
the same way).
"""
from __future__ import annotations

import numpy as np

from repro.core import build_labelling, select_landmarks
from repro.core.baselines import PPLIndex

from .common import bench_suite, emit, time_call

PPL_CAP = 1_500
PARENT_CAP = 600


def qbs_sequential(graph, landmarks):
    for lm in landmarks:
        build_labelling(graph, np.asarray([lm], np.int32))


def run(scale: float = 1.0, sweep: bool = False) -> list[tuple]:
    rows = []
    for bg in bench_suite(scale):
        g = bg.graph
        landmarks = select_landmarks(g, 20)
        dt, _ = time_call(lambda: build_labelling(g, landmarks), repeat=2)
        rows.append((f"construction/qbs_p/{bg.name}", dt * 1e6,
                     f"V={g.n_vertices};E={g.n_edges // 2};R=20"))
        dt_seq, _ = time_call(lambda: qbs_sequential(g, landmarks), repeat=1)
        rows.append((f"construction/qbs_seq/{bg.name}", dt_seq * 1e6,
                     f"speedup_parallel={dt_seq / max(dt, 1e-9):.1f}x"))

        if g.n_vertices <= PPL_CAP:
            dt_p, _ = time_call(lambda: PPLIndex(g), repeat=1)
            rows.append((f"construction/ppl/{bg.name}", dt_p * 1e6,
                         f"vs_qbs={dt_p / max(dt, 1e-9):.0f}x"))
        else:
            rows.append((f"construction/ppl/{bg.name}", -1,
                         f"DNF-analog:V>{PPL_CAP}"))
        if g.n_vertices <= PARENT_CAP:
            dt_pp, _ = time_call(lambda: PPLIndex(g, store_parents=True), repeat=1)
            rows.append((f"construction/parentppl/{bg.name}", dt_pp * 1e6,
                         f"vs_qbs={dt_pp / max(dt, 1e-9):.0f}x"))
        else:
            rows.append((f"construction/parentppl/{bg.name}", -1,
                         f"DNF-analog:V>{PARENT_CAP}"))

    if sweep:  # Figure 10: construction time vs |R|
        g = bench_suite(scale)[0].graph
        for r in (5, 10, 20, 40, 80):
            lms = select_landmarks(g, r)
            dt, _ = time_call(lambda: build_labelling(g, lms), repeat=2)
            rows.append((f"construction/sweep_R{r}/ba-hub", dt * 1e6,
                         "linear-in-R expected"))
    return rows


def main() -> None:
    emit(run(sweep=True))


if __name__ == "__main__":
    main()
