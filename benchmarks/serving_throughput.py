"""Serving-service throughput: sync vs async dispatch across traffic
mixes (queries/sec), plus the canonical-pair result cache on skewed
streams.

Three policy axes of ``serving.service`` are measured on one graph at the
10k-vertex scale the serving rework targets:

* **sync vs async** — ``async_depth=1`` (the seed's dispatch-then-sync
  loop) vs ``async_depth=2`` (double-buffered: chunk k+1 enqueued before
  chunk k is synced).  The overlap pays on accelerators, where host
  post-processing and device compute are separate silicon; on a CPU host
  the two share cores, so the expected result here is parity (speedup
  ~1.0x either side of noise) — the row exists to pin that the async
  machinery costs nothing, not to show a CPU win.
* **uniform vs landmark-heavy traffic** — random pairs vs a mix where
  ``LANDMARK_FRAC`` of queries touch a landmark endpoint (the hub-skew
  regime: landmarks are the highest-degree hubs, and hub-touching queries
  dominate real traffic).  Landmark-heavy mixes route through the
  vectorized label-only / bounded-BFS lanes instead of guided search, so
  they serve strictly faster than uniform traffic.
* **cache on a skewed stream** — a Zipf-like repeat-heavy stream through
  a cached service; the derived column reports the hit rate.

Timing is interleaved min-of-N like ``frontier_relay`` so slow-machine
noise hits every service equally.  Emits the standard
``name,us_per_call,derived`` CSV rows and appends one JSON record per
invocation to the BENCH.json trajectory at the repo root.
"""
from __future__ import annotations

import json
import time
from functools import partial
from pathlib import Path

import numpy as np

from repro.core import QbSIndex, barabasi_albert_graph
from repro.serving import ServingService

from .common import interleaved_best

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH.json"

N_QUERIES = 96
LANDMARK_FRAC = 0.4   # landmark-endpoint share of the heavy mix (>= 30%)
ROUNDS = 8


def _traffic_mixes(g, idx, n: int, seed: int) -> dict[str, tuple]:
    rng = np.random.default_rng(seed)
    us = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    vs = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    lms = np.asarray(idx.scheme.landmarks)
    k = int(LANDMARK_FRAC * n)
    us_lh = us.copy()
    us_lh[:k] = rng.choice(lms, size=k)
    perm = rng.permutation(n)
    return {"uniform": (us, vs),
            "landmark-heavy": (us_lh[perm], vs[perm])}


def _skewed_stream(g, n: int, seed: int, n_hot: int = 16) -> tuple:
    """Repeat-heavy stream: half the queries cycle over ``n_hot`` hot
    pairs (hub traffic skew), half are fresh random pairs."""
    rng = np.random.default_rng(seed)
    us = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    vs = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    hot = rng.integers(0, n, size=n_hot)
    repeat = rng.random(n) < 0.5
    pick = hot[rng.integers(0, n_hot, size=n)]
    us = np.where(repeat, us[pick], us)
    vs = np.where(repeat, vs[pick], vs)
    return us, vs


def _best_of(cells: dict) -> dict:
    return interleaved_best(cells, rounds=ROUNDS)


def run(scale: float = 1.0, **_) -> list[tuple]:
    n_v = max(1_000, int(10_000 * scale))
    g = barabasi_albert_graph(n_v, 4, seed=5)
    idx = QbSIndex.build(g, n_landmarks=20, chunk=32)
    gname = f"ba-{n_v}"
    services = {
        "sync": ServingService(idx, async_depth=1),
        "async": ServingService(idx, async_depth=2),
    }

    rows: list[tuple] = []
    record = {"bench": "serving_throughput", "ts": time.time(),
              "scale": scale, "graph": gname, "V": g.n_vertices,
              "E": g.n_edges, "n_queries": N_QUERIES,
              "landmark_frac": LANDMARK_FRAC, "rows": []}

    mixes = _traffic_mixes(g, idx, N_QUERIES, seed=7)
    cells = {(mix, name): partial(svc.query_batch, us, vs)
             for mix, (us, vs) in mixes.items()
             for name, svc in services.items()}
    best = _best_of(cells)
    for (mix, name), dt in best.items():
        qps = N_QUERIES / max(dt, 1e-9)
        speedup = best[(mix, "sync")] / max(dt, 1e-9)
        rows.append((f"serve/{mix}/{name}/{gname}",
                     dt / N_QUERIES * 1e6,
                     f"qps={qps:.1f},speedup_vs_sync={speedup:.2f}x"))
        record["rows"].append({
            "mix": mix, "service": name, "qps": qps,
            "us_per_query": dt / N_QUERIES * 1e6,
            "speedup_vs_sync": speedup,
        })
    # the lane-routing win: landmark-heavy traffic vs uniform, async service
    lane_speedup = best[("uniform", "async")] / max(
        best[("landmark-heavy", "async")], 1e-9)
    rows.append((f"serve/landmark_lane_speedup/{gname}",
                 round(lane_speedup, 3),
                 f"landmark_frac={LANDMARK_FRAC}"))
    record["landmark_lane_speedup"] = lane_speedup

    # canonical-pair cache on a repeat-heavy stream, served as successive
    # batches (within-batch repeats are already deduped by the planner; the
    # cache pays off across batches)
    us, vs = _skewed_stream(g, N_QUERIES, seed=11)
    bs = 24
    batches = [(us[i:i + bs], vs[i:i + bs]) for i in range(0, N_QUERIES, bs)]

    def serve_stream(svc):
        for u_, v_ in batches:
            svc.query_batch(u_, v_)

    # hit rate from one fresh single pass — the timing loop below re-serves
    # the same stream, so its counters would report warm-cache ~100%, a
    # property of the loop rather than of the traffic
    stat = ServingService(idx, async_depth=2, cache_size=4096)
    serve_stream(stat)
    hit_rate = stat.cache.hits / max(stat.cache.hits + stat.cache.misses, 1)

    cached = ServingService(idx, async_depth=2, cache_size=4096)
    best = _best_of({"cached": partial(serve_stream, cached),
                     "uncached": partial(serve_stream, services["async"])})
    for name, dt in best.items():
        qps = N_QUERIES / max(dt, 1e-9)
        derived = (f"qps={qps:.1f},fresh_pass_hit_rate={hit_rate:.2f}"
                   if name == "cached" else f"qps={qps:.1f}")
        rows.append((f"serve/skewed/{name}/{gname}",
                     dt / N_QUERIES * 1e6, derived))
        record["rows"].append({"mix": "skewed", "service": name, "qps": qps,
                               "us_per_query": dt / N_QUERIES * 1e6})
    record["cache_hit_rate"] = hit_rate

    with BENCH_PATH.open("a") as f:
        f.write(json.dumps(record) + "\n")
    return rows


def main() -> None:
    from .common import emit

    print("name,us_per_call,derived")
    emit(run())


if __name__ == "__main__":
    main()
