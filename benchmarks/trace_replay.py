"""Trace-replay load generator: recorded open-loop arrival traces against
the replica serving tier, gating p50/p99 per QoS class (DESIGN.md §12).

``qos_scheduler.py`` drives synthetic per-tick two-tenant scenarios; this
bench replays *recorded traces* — committed JSON under
``benchmarks/traces/`` with the structure real traffic has:

* **degree skew** — endpoints are stored as degree-*rank* fractions
  (0.0 = hottest hub), drawn from a power law and mapped to vertex ids
  by degree order at replay, so one trace file replays against any graph
  size;
* **repeat heaviness** — a fraction of arrivals re-query a recent pair
  (the traffic that makes the result cache and the router's cache
  *partitioning* matter);
* **burst structure** — the interactive class arrives as a steady
  open-loop trickle (exponential gaps), the bulk class in tight bursts.

Replay drives ``ReplicaRouter`` sizes N in {1, 4} with per-replica
``ManualClock``s advanced in lockstep to each arrival instant, so every
scheduler decision — and therefore every per-class latency histogram
count — is a deterministic function of the trace file: the p50/p99
columns are gate-safe.  ``scripts/bench_gate.py --p99-ceiling-us``
enforces an absolute per-class ceiling on the ``p99_us`` rows (the
roofline-floor / shard-ceiling pattern).  The run itself asserts the
tier's acceptance properties — N=4 bit-identical to N=1 on
``(dist, edge_ids)``, summed per-replica hot-key cache bytes under the
duplicated-cache baseline, interactive p99 within its (bucket-rounded)
deadline — so a broken tier turns the bench step red before the gate
compares numbers.  Appends one JSON record per invocation to BENCH.json.

Regenerate the committed traces (only when intentionally changing the
workload — the gate baselines assume them):

  PYTHONPATH=src python -m benchmarks.trace_replay --record
"""
from __future__ import annotations

import json
import time
from collections import deque
from pathlib import Path

import numpy as np

from repro.core import QbSIndex, barabasi_albert_graph
from repro.serving import (
    AdmissionPolicy,
    ManualClock,
    QoSClass,
    ReplicaRouter,
    merged_latency,
)

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH.json"
TRACES_DIR = Path(__file__).resolve().parent / "traces"

# the tier's QoS config: deadlines bound resolution latency, so the
# bucket-rounded p99 of each class is capped at the next power of two
# above max_wait (the in-run assert + the CI --p99-ceiling-us values)
QOS = (QoSClass("interactive", max_wait=0.002, weight=4.0),
       QoSClass("bulk", max_wait=0.05, weight=1.0))
CHUNK = 16
CACHE_KW = dict(cache_size=4096, cache_policy="hub")
REPLICA_SIZES = (1, 4)

# committed trace files: (name, seed, generator knobs)
TRACE_SPECS = (
    ("hub-steady", 31, dict(n_events=900, bulk_frac=0.45, repeat_p=0.40,
                            rank_alpha=3.0, int_gap_us=240.0,
                            burst_gap_us=5000.0, burst_len=(8, 25),
                            burst_span_us=250.0)),
    ("hub-burst", 32, dict(n_events=900, bulk_frac=0.65, repeat_p=0.30,
                           rank_alpha=2.2, int_gap_us=420.0,
                           burst_gap_us=2800.0, burst_len=(16, 41),
                           burst_span_us=180.0)),
)


def synthesize_trace(name: str, seed: int, *, n_events: int,
                     bulk_frac: float, repeat_p: float, rank_alpha: float,
                     int_gap_us: float, burst_gap_us: float,
                     burst_len: tuple[int, int],
                     burst_span_us: float) -> dict:
    """Generate one trace: events are ``[t_us, class_idx, u_rank, v_rank]``
    with integer microsecond arrivals and degree-rank-fraction endpoints
    (power-law skewed toward rank 0 — the hubs)."""
    rng = np.random.default_rng(seed)
    recent: deque = deque(maxlen=48)

    def draw_pair():
        if recent and rng.random() < repeat_p:
            return recent[int(rng.integers(len(recent)))]
        ur = round(float(rng.random() ** rank_alpha), 4)
        vr = round(float(rng.random() ** rank_alpha), 4)
        recent.append((ur, vr))
        return ur, vr

    events = []
    n_bulk = int(n_events * bulk_frac)
    # interactive: steady open-loop trickle, exponential inter-arrivals
    t = 0.0
    for _ in range(n_events - n_bulk):
        t += rng.exponential(int_gap_us)
        events.append((int(t), 0, *draw_pair()))
    # bulk: bursts of correlated arrivals inside a tight span
    t, left = 0.0, n_bulk
    while left > 0:
        t += rng.exponential(burst_gap_us)
        k = min(left, int(rng.integers(*burst_len)))
        offs = np.sort(rng.uniform(0.0, burst_span_us, size=k))
        for o in offs.tolist():
            events.append((int(t + o), 1, *draw_pair()))
        left -= k
    events.sort(key=lambda e: e[0])
    return {"name": name, "seed": seed,
            "classes": [c.name for c in QOS],
            "horizon_us": events[-1][0], "events": events}


def record_traces(out_dir: Path = TRACES_DIR) -> list[Path]:
    out_dir.mkdir(parents=True, exist_ok=True)
    paths = []
    for name, seed, kw in TRACE_SPECS:
        trace = synthesize_trace(name, seed, **kw)
        path = out_dir / f"{name}.json"
        with path.open("w") as f:
            json.dump(trace, f, separators=(",", ":"))
            f.write("\n")
        paths.append(path)
    return paths


def load_traces(scale: float) -> list[dict]:
    """Committed traces, truncated to a ``scale`` prefix (the file is the
    full-scale recording; CI replays the first quarter)."""
    traces = []
    for name, _, _ in TRACE_SPECS:
        with (TRACES_DIR / f"{name}.json").open() as f:
            trace = json.load(f)
        n = max(120, int(len(trace["events"]) * scale))
        trace["events"] = trace["events"][:n]
        traces.append(trace)
    return traces


def replay(idx, trace: dict, n_replicas: int):
    """Replay one trace open-loop against an N-replica tier in lockstep
    simulated time; returns ``(router, futures)`` after the final drain
    (the router is closed; its counters/histograms stay readable)."""
    order = np.argsort(-np.asarray(idx.graph.degrees()))
    n_v = idx.graph.n_vertices
    clocks = [ManualClock() for _ in range(n_replicas)]
    router = ReplicaRouter(
        idx, n_replicas=n_replicas, clocks=clocks, qos=QOS,
        policy=AdmissionPolicy(adaptive=True, chunk=CHUNK, max_chunk=64),
        **CACHE_KW)
    classes = trace["classes"]
    futs = []
    for t_us, ci, ur, vr in trace["events"]:
        t = t_us / 1e6
        for clk in clocks:
            clk.advance_to(t)
        u = int(order[min(int(ur * n_v), n_v - 1)])
        v = int(order[min(int(vr * n_v), n_v - 1)])
        futs.append(router.submit(u, v, qos=classes[ci]))
    horizon = trace["events"][-1][0] / 1e6 + 2 * max(
        c.max_wait for c in QOS)
    for clk in clocks:
        clk.advance_to(horizon)
    router.drain()
    router.close()
    return router, futs


def _hot_keys(futs) -> set:
    # resident cache keys carry the serving epoch: 0 here — this bench
    # replays against a static graph (updates are benchmarks/graph_updates)
    seen, hot = set(), set()
    for f in futs:
        key = (min(f.u, f.v), max(f.u, f.v), 0)
        (hot if key in seen else seen).add(key)
    return hot


def run(scale: float = 1.0, **_) -> list[tuple]:
    n_v = max(600, int(2_400 * scale))
    g = barabasi_albert_graph(n_v, 4, seed=17)
    idx = QbSIndex.build(g, n_landmarks=8, chunk=CHUNK)
    gname = f"ba-{n_v}"
    traces = load_traces(scale)

    rows: list[tuple] = []
    record = {"bench": "trace_replay", "ts": time.time(), "scale": scale,
              "graph": gname, "V": g.n_vertices, "E": g.n_edges, "rows": []}

    for trace in traces:
        tname = trace["name"]
        results: dict[int, list] = {}
        routers: dict[int, object] = {}
        for n in REPLICA_SIZES:
            router, futs = replay(idx, trace, n)
            routers[n] = (router, futs)
            results[n] = [f.result() for f in futs]
        # bit-identity across tier sizes: routing partitions *where* a
        # pair computes, never what it answers
        base = results[REPLICA_SIZES[0]]
        for n in REPLICA_SIZES[1:]:
            for a, b in zip(base, results[n]):
                assert a.dist == b.dist and \
                    np.array_equal(a.edge_ids, b.edge_ids), \
                    f"replica tier diverged on {tname} at N={n}"
        # cache partitioning: hot (repeated) keys live on exactly one
        # replica each, so summed hot-key bytes stay at the N=1 level —
        # strictly under the N-duplicated-caches baseline
        hot = _hot_keys(routers[1][1])
        single = routers[1][0].replicas[0].service.cache.bytes_for(hot)
        for n in REPLICA_SIZES[1:]:
            summed = sum(rep.service.cache.bytes_for(hot)
                         for rep in routers[n][0].replicas)
            assert single > 0 and summed < n * single, \
                (tname, n, summed, single)
            record["rows"].append({
                "trace": tname, "n_replicas": n, "qos": "_cache",
                "hot_bytes_frac": summed / (n * single),
            })
        # warm restore (the rejoin bugfix): draining ships the victim's
        # packed entries to the survivors; restoring ships its key range
        # back, so the rejoined replica serves its repeat traffic warm
        # instead of recomputing the hot set cold
        n = REPLICA_SIZES[-1]
        router = routers[n][0]
        victim = max(range(n),
                     key=lambda i: len(router.replicas[i].service.cache))
        held = len(router.replicas[victim].service.cache)
        router.drain_replica(victim)
        assert len(router.replicas[victim].service.cache) == 0, tname
        router.restore_replica(victim)
        restored = router.replicas[victim].service.cache
        owned_hot = [k for k in hot
                     if router.owner_of(k[0], k[1]) == victim]
        back = sum(1 for k in owned_hot if k in restored)
        assert held > 0 and router.stats["cache_shipped"] >= held, tname
        assert owned_hot and back == len(owned_hot), \
            (tname, back, len(owned_hot))
        record["rows"].append({
            "trace": tname, "n_replicas": n, "qos": "_restore",
            "cache_shipped": router.stats["cache_shipped"],
            "restored_hot": back,
        })
        for n in REPLICA_SIZES:
            router = routers[n][0]
            for cls in QOS:
                h = merged_latency(rep.lat_hist[cls.name]
                                   for rep in router.replicas)
                p50, p99 = h.quantile(0.50), h.quantile(0.99)
                bound_us = cls.max_wait * 1e6
                # deadline flushes resolve within max_wait; the histogram
                # rounds up to the next power-of-two bucket edge
                bucket_bound = 1 << int(np.ceil(np.log2(bound_us)))
                assert p99 <= bucket_bound, \
                    (tname, n, cls.name, p99, bucket_bound)
                rows.append((f"replay/{tname}/n{n}/{cls.name}/{gname}",
                             p99, f"p50_us={p50:.0f},total={h.total}"))
                record["rows"].append({
                    "trace": tname, "n_replicas": n, "qos": cls.name,
                    "p50_us": p50, "p99_us": p99, "n_obs": h.total,
                })

    with BENCH_PATH.open("a") as f:
        f.write(json.dumps(record) + "\n")
    return rows


def main() -> None:
    import sys

    from .common import emit

    if "--record" in sys.argv:
        for path in record_traces():
            print(f"recorded {path}")
        return
    print("name,us_per_call,derived")
    emit(run())


if __name__ == "__main__":
    main()
