"""Sketching fast-path microbench: batched d_top via min-plus contraction.

Compares the pure-jnp reference against the Pallas kernel in interpret mode
(CPU: correctness-path timing only — the interpreter is *slower* than XLA;
the derived column carries the analytic VPU cost model for TPU v5e, which
is what §Roofline consumes)."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import INF, d_top_only
from repro.kernels import minplus as minplus_pallas

from .common import emit, time_call


def run() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(0)
    for b, r in ((1024, 20), (4096, 20), (4096, 128)):
        lu = jnp.asarray(
            np.where(rng.random((b, r)) < 0.3, INF, rng.integers(1, 30, (b, r))),
            jnp.int32)
        lv = jnp.asarray(
            np.where(rng.random((b, r)) < 0.3, INF, rng.integers(1, 30, (b, r))),
            jnp.int32)
        dm = jnp.asarray(rng.integers(1, 10, (r, r)), jnp.int32)

        dt_ref, _ = time_call(
            lambda: d_top_only(lu, lv, dm).block_until_ready(), repeat=5)
        # analytic TPU cost: 2*B*R^2 int32 VPU ops / (~1e12 op/s VPU int lane)
        vpu_us = 2 * b * r * r / 1e12 * 1e6
        rows.append((f"sketch/jnp/B{b}_R{r}", dt_ref * 1e6,
                     f"tpu_vpu_model_us={vpu_us:.3f}"))

        dt_pl, _ = time_call(
            lambda: minplus_pallas(lu, dm).block_until_ready(), repeat=2)
        rows.append((f"sketch/pallas_interp/B{b}_R{r}", dt_pl * 1e6,
                     "interpret-mode=correctness-path"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
