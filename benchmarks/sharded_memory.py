"""Per-device memory accounting of the vertex-sharded index (DESIGN.md
§11): build the born-sharded labels + CSR partition over an 8-way mesh
and record what each device actually holds vs the replicated layout.

The acceptance metric is ``per_device_frac`` = (per-device label + CSR
bytes) / (replicated label + CSR bytes); the bench gate holds it under
an absolute linear-scaling ceiling (``--shard-frac-ceiling``, default
0.25 on the 8-way mesh) rather than a relative threshold — the fraction
is a property of the partition, not of machine speed.

Self-spawning: ``run()`` re-execs this module in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the 8-way mesh
exists regardless of how many devices the invoking process sees — the
bench works from any CI step (or a dev laptop) without env gymnastics.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
BENCH_PATH = REPO / "BENCH.json"
N_SHARDS = 8
_MARK = "SHARDED-MEMORY-JSON:"


def _child(scale: float) -> None:
    """Runs under the forced 8-device env: build and measure."""
    import jax

    from repro.core import barabasi_albert_graph, random_regular_graph
    from repro.core.sharded import ShardedIndex

    assert len(jax.devices()) >= N_SHARDS, jax.devices()
    n1 = max(512, int(4_000 * scale))
    n2 = max(512, int(3_000 * scale))
    out = []
    for gname, g in (("ba-hub", barabasi_albert_graph(n1, 3, seed=1)),
                     ("reg-flat", random_regular_graph(n2, 8, seed=3))):
        idx = ShardedIndex.build(g, n_landmarks=20, mesh=N_SHARDS)
        info = idx.sharded_size_bytes()
        out.append({
            "graph": gname, "n_shards": info["n_shards"],
            "V": g.n_vertices, "E": g.n_edges,
            "dtype": str(idx.labels.pack_dtype),
            "per_device_frac": float(info["per_device_frac"]),
            "per_device_bytes": float(info["per_device_bytes"]),
            "per_device_label_bytes": float(info["per_device_label_bytes"]),
            "per_device_csr_bytes": float(info["per_device_csr_bytes"]),
            "replicated_bytes": float(info["replicated_bytes"]),
        })
    print(_MARK + json.dumps(out))


def run(scale: float = 1.0, **_) -> list[tuple]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_SHARDS}"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO / "src"), env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, str(Path(__file__).resolve()), "--child",
         str(scale)],
        env=env, capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError("sharded_memory child failed:\n"
                           f"{proc.stdout}\n{proc.stderr}")
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            payload = json.loads(line[len(_MARK):])
    assert payload is not None, proc.stdout
    record = {"bench": "sharded_memory", "ts": time.time(), "scale": scale,
              "rows": payload}
    with BENCH_PATH.open("a") as f:
        f.write(json.dumps(record) + "\n")
    return [(f"sharded_memory/{r['graph']}/S{r['n_shards']}",
             r["per_device_bytes"],
             f"frac={r['per_device_frac']:.3f},dtype={r['dtype']}")
            for r in payload]


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child(float(sys.argv[sys.argv.index("--child") + 1]))
    else:
        sys.path.insert(0, str(REPO))
        from benchmarks.common import emit

        print("name,per_device_bytes,derived")
        emit(run())
