#!/usr/bin/env python
"""CI benchmark-regression gate: BENCH.json vs a committed baseline.

BENCH.json is an append-only JSONL trajectory — every benchmark
invocation appends one record.  This gate compares the *latest* record
per ``(bench, scale)`` in the current file against the latest in
``BENCH_BASELINE.json`` and fails when any tracked throughput/latency
metric regresses beyond the threshold (default 25%, sized for shared-CI
noise on top of the benches' own interleaved min-of-N timing).

Matching is structural, not positional: rows inside a record are keyed
by their *identifying* fields — every ``str``/``int``/``bool`` field
that is not itself a tracked metric (graph, backend, mix, trace, rate,
policy, V, E, ...) — so reordering rows or adding new ones never breaks
the gate, and float-valued derived columns (speedups, hit rates, ts)
never leak into the key.  Tracked metrics: ``qps`` (higher is better)
and ``us_per_call`` / ``us_per_query`` (lower is better).  Rows whose
baseline latency is under ``--min-us`` (default 50us — cache-hit hot
loops) are noise-dominated and skipped.

Baseline rows with no counterpart in the current file are reported but
don't fail (the nightly job writes full-scale records CI never
produces); new rows with no baseline pass silently — refresh the
baseline in the PR that adds them:

    PYTHONPATH=src python scripts/bench_gate.py [--refresh]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# metric name -> +1 (higher is better) / -1 (lower is better)
TRACKED = {"qps": +1, "us_per_call": -1, "us_per_query": -1}

# default absolute ceiling for p99_us rows when no --p99-ceiling-us class
# bound matches (benchmarks/trace_replay.py; generous — CI passes real
# per-class bounds)
P99_DEFAULT_CEILING_US = 200_000.0


def parse_p99_spec(spec: str | None) -> dict[str, float]:
    """``--p99-ceiling-us`` spec -> {qos class: ceiling}.  A bare number
    applies to every class (the ``*`` key); ``cls=value`` entries bound
    one class each: ``"interactive=2048,bulk=65536"``."""
    out = {"*": P99_DEFAULT_CEILING_US}
    if not spec:
        return out
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            cls, val = part.split("=", 1)
            out[cls.strip()] = float(val)
        else:
            out["*"] = float(part)
    return out


def load_latest(path: Path, scale: float | None = None) -> dict:
    """Latest record per (bench, scale) from a JSONL trajectory.
    ``scale`` restricts to that scale's records — CI pins 0.25 so the
    committed trajectory's full-scale (nightly/dev) records can never be
    compared against a baseline no CI step reproduces."""
    latest: dict[tuple, dict] = {}
    if not path.exists():
        return latest
    with path.open() as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if scale is not None and rec.get("scale") != scale:
                continue
            latest[(rec.get("bench"), rec.get("scale"))] = rec
    return latest


def _row_key(row: dict) -> tuple:
    """Identifying fields only: deterministic str/int/bool values that are
    not tracked metrics (floats are measurements or derived ratios)."""
    return tuple(sorted(
        (k, v) for k, v in row.items()
        if k not in TRACKED and isinstance(v, (str, int))))


def compare(baseline: dict, current: dict, threshold: float,
            min_us: float = 50.0, frac_floor: float = 0.01,
            shard_frac_ceiling: float = 0.25,
            p99_ceiling_us: dict[str, float] | None = None,
            update_speedup_floor: float = 5.0,
            ) -> tuple[list, list, list]:
    """Compare two ``load_latest`` maps.  Returns ``(regressions, notes,
    retired)`` where each regression is a dict with the offending row key,
    metric, baseline/current values and the ratio, and ``retired`` lists
    baseline rows with no structural counterpart in the current file — a
    row key that changed shape across PRs is *retired*, reported but never
    fatal, so a baseline refresh can't silently mask regressions in the
    rows that do still match.

    Rows whose *baseline* latency sits under ``min_us`` are skipped
    entirely: sub-tens-of-microseconds timings are cache-hit hot loops
    whose run-to-run spread dwarfs any threshold a gate could hold (the
    skewed/cached serving row swings >2x between healthy runs).

    Rows carrying ``roofline_frac`` (``benchmarks/roofline.py``) are
    gated by an *absolute floor* instead of the relative threshold: the
    achieved fraction already normalizes out machine speed, so the gate
    fails only when the current fraction collapses below ``frac_floor``
    — a kernel falling off its roofline — never on run-to-run wiggle of
    an otherwise healthy fraction.

    Symmetrically, rows carrying ``per_device_frac``
    (``benchmarks/sharded_memory.py``) are gated by an absolute
    *ceiling*: the vertex-sharded index must keep per-device label+CSR
    bytes under ``shard_frac_ceiling`` of the replicated footprint
    (linear-scaling floor on an 8-way mesh — DESIGN.md §11); the gate
    fails only when the fraction climbs above the ceiling.

    Rows carrying ``p99_us`` (``benchmarks/trace_replay.py`` — simulated
    deterministic tail latency per QoS class) are gated by an absolute
    per-class ceiling from ``p99_ceiling_us`` (``parse_p99_spec``): the
    row's ``qos`` field selects its bound, falling back to the ``*``
    entry.  ``p50_us`` rides along untracked.

    Rows carrying ``update_speedup`` (``benchmarks/graph_updates.py`` —
    incremental label maintenance vs the full-rebuild branch) are gated
    by an absolute floor: the gate fails when the measured speedup drops
    below ``update_speedup_floor`` (default 5), machine speed having been
    normalized out of the ratio."""
    p99_ceiling_us = (p99_ceiling_us if p99_ceiling_us is not None
                      else parse_p99_spec(None))
    regressions, notes, retired = [], [], []
    for rec_key, base_rec in sorted(baseline.items(), key=str):
        cur_rec = current.get(rec_key)
        if cur_rec is None:
            notes.append(f"no current record for bench={rec_key[0]} "
                         f"scale={rec_key[1]} (skipped)")
            continue
        cur_rows = {_row_key(r): r for r in cur_rec.get("rows", [])}
        for base_row in base_rec.get("rows", []):
            key = _row_key(base_row)
            lat = [float(base_row[m]) for m in ("us_per_call", "us_per_query")
                   if m in base_row]
            if lat and min(lat) < min_us:
                notes.append(f"row {dict(key)} under the {min_us:.0f}us "
                             f"noise floor ({min(lat):.1f}us; skipped)")
                continue
            cur_row = cur_rows.get(key)
            if cur_row is None:
                retired.append({"bench": rec_key[0], "scale": rec_key[1],
                                "row": dict(key)})
                continue
            if "update_speedup" in cur_row:
                speedup = float(cur_row["update_speedup"])
                if speedup < update_speedup_floor:
                    regressions.append({
                        "bench": rec_key[0], "scale": rec_key[1],
                        "row": dict(key), "metric": "update_speedup",
                        "baseline": update_speedup_floor, "current": speedup,
                        "ratio": speedup / max(update_speedup_floor, 1e-12),
                    })
                continue   # absolute-floor rows never hit the relative rule
            if "roofline_frac" in cur_row:
                frac = float(cur_row["roofline_frac"])
                if frac < frac_floor:
                    regressions.append({
                        "bench": rec_key[0], "scale": rec_key[1],
                        "row": dict(key), "metric": "roofline_frac",
                        "baseline": frac_floor, "current": frac,
                        "ratio": frac / max(frac_floor, 1e-12),
                    })
                continue   # absolute-floor rows never hit the relative rule
            if "per_device_frac" in cur_row:
                frac = float(cur_row["per_device_frac"])
                if frac > shard_frac_ceiling:
                    regressions.append({
                        "bench": rec_key[0], "scale": rec_key[1],
                        "row": dict(key), "metric": "per_device_frac",
                        "baseline": shard_frac_ceiling, "current": frac,
                        "ratio": frac / max(shard_frac_ceiling, 1e-12),
                    })
                continue   # absolute-ceiling rows likewise
            if "p99_us" in cur_row:
                ceiling = p99_ceiling_us.get(
                    str(cur_row.get("qos")), p99_ceiling_us["*"])
                p99 = float(cur_row["p99_us"])
                if p99 > ceiling:
                    regressions.append({
                        "bench": rec_key[0], "scale": rec_key[1],
                        "row": dict(key), "metric": "p99_us",
                        "baseline": ceiling, "current": p99,
                        "ratio": p99 / max(ceiling, 1e-12),
                    })
                continue   # absolute per-class ceiling rows likewise
            for metric, sense in TRACKED.items():
                if metric not in base_row or metric not in cur_row:
                    continue
                base, cur = float(base_row[metric]), float(cur_row[metric])
                if base <= 0:
                    continue
                ratio = cur / base
                bad = (ratio < 1 - threshold if sense > 0
                       else ratio > 1 + threshold)
                if bad:
                    regressions.append({
                        "bench": rec_key[0], "scale": rec_key[1],
                        "row": dict(key), "metric": metric,
                        "baseline": base, "current": cur, "ratio": ratio,
                    })
    return regressions, notes, retired


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", type=Path, default=REPO / "BENCH.json")
    ap.add_argument("--baseline", type=Path,
                    default=REPO / "BENCH_BASELINE.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="skip rows whose baseline latency is below this "
                         "(noise-dominated cache-hit loops; default 50)")
    ap.add_argument("--frac-floor", type=float, default=0.01,
                    help="absolute floor for roofline_frac rows (fail iff "
                         "current < floor; default 0.01)")
    ap.add_argument("--shard-frac-ceiling", type=float, default=0.25,
                    help="absolute ceiling for per_device_frac rows from "
                         "the vertex-sharded index (fail iff current > "
                         "ceiling; default 0.25 = linear scaling on >= 4 "
                         "effective shards)")
    ap.add_argument("--update-speedup-floor", type=float, default=5.0,
                    help="absolute floor for update_speedup rows from "
                         "graph_updates (fail iff current < floor; "
                         "default 5 = incremental maintenance must beat "
                         "the full-rebuild branch five-fold)")
    ap.add_argument("--p99-ceiling-us", default=None, metavar="SPEC",
                    help="absolute ceiling(s) for p99_us rows from "
                         "trace_replay: a bare number for every class or "
                         "'cls=value,...' per class, e.g. "
                         "'interactive=2048,bulk=65536' (default "
                         f"{P99_DEFAULT_CEILING_US:.0f} for all)")
    ap.add_argument("--only", default=None, metavar="BENCH1,BENCH2",
                    help="restrict gating to these bench names — the CI "
                         "retry re-measures only the failing set")
    ap.add_argument("--emit-failures", type=Path, default=None,
                    metavar="FILE",
                    help="write the comma-joined failing bench names to "
                         "FILE (empty on pass) for the CI retry's --only")
    ap.add_argument("--scale", type=float, default=None,
                    help="only gate/refresh records at this scale (CI "
                         "pins 0.25; default: all)")
    ap.add_argument("--refresh", action="store_true",
                    help="rewrite the baseline from the current file's "
                         "latest records instead of comparing")
    args = ap.parse_args(argv)

    current = load_latest(args.current, scale=args.scale)
    if args.refresh:
        with args.baseline.open("w") as f:
            for _, rec in sorted(current.items(), key=str):
                f.write(json.dumps(rec) + "\n")
        print(f"baseline refreshed: {len(current)} records -> {args.baseline}")
        return 0

    baseline = load_latest(args.baseline, scale=args.scale)
    if args.only is not None:
        only = {b.strip() for b in args.only.split(",") if b.strip()}
        baseline = {k: v for k, v in baseline.items() if k[0] in only}
        current = {k: v for k, v in current.items() if k[0] in only}
        print(f"bench gate: restricted to {sorted(only)}")
    if not baseline:
        print(f"bench gate: no baseline at {args.baseline}; nothing to gate")
        return 0
    regressions, notes, retired = compare(
        baseline, current, args.threshold,
        min_us=args.min_us,
        frac_floor=args.frac_floor,
        shard_frac_ceiling=args.shard_frac_ceiling,
        p99_ceiling_us=parse_p99_spec(args.p99_ceiling_us),
        update_speedup_floor=args.update_speedup_floor)
    for note in notes:
        print(f"bench gate: {note}")
    if retired:
        print(f"bench gate: {len(retired)} retired baseline row(s) with no "
              f"structural counterpart (reported, not fatal — refresh the "
              f"baseline to drop them):")
        for r in retired:
            print(f"  RETIRED {r['bench']}@scale={r['scale']} {r['row']}")
    failing = sorted({r["bench"] for r in regressions})
    if args.emit_failures is not None:
        args.emit_failures.write_text(",".join(failing))
    if regressions:
        print(f"bench gate: {len(regressions)} regression(s) beyond "
              f"{args.threshold:.0%}:")
        for r in regressions:
            print(f"  FAIL {r['bench']}@scale={r['scale']} {r['row']} "
                  f"{r['metric']}: {r['baseline']:.3f} -> {r['current']:.3f} "
                  f"({r['ratio']:.2f}x)")
        print(f"bench gate: failing benches: {','.join(failing)}")
        return 1
    print(f"bench gate: OK ({len(baseline)} baseline records, "
          f"threshold {args.threshold:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
