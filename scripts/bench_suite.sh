#!/usr/bin/env bash
# The one CI bench list — every benchmark that appends a BENCH.json
# record.  All three CI legs (quick PR benches, gate noise-retry,
# nightly full-scale) run through here so the list can never drift
# between them; the gate retry passes --only with the failing set
# (scripts/bench_gate.py --emit-failures) to re-measure just those.
#
#   scripts/bench_suite.sh <scale> [--only bench1,bench2]
set -euo pipefail
cd "$(dirname "$0")/.."

scale="${1:?usage: bench_suite.sh <scale> [--only bench1,bench2]}"
shift
only=""
while [ $# -gt 0 ]; do
  case "$1" in
    --only) only="${2:?--only needs a comma-separated bench list}"; shift 2 ;;
    *) echo "bench_suite.sh: unknown argument $1" >&2; exit 2 ;;
  esac
done

benches=(
  frontier_relay
  serving_throughput
  streaming_admission
  qos_scheduler
  trace_replay
  graph_updates
  label_size
  roofline
  sharded_memory
)
if [ -n "$only" ]; then
  IFS=',' read -r -a benches <<<"$only"
fi

for bench in "${benches[@]}"; do
  echo "# bench_suite: $bench (scale=$scale)" >&2
  PYTHONPATH=src python -c "from benchmarks.$bench import run; run(scale=$scale)"
done
