#!/usr/bin/env bash
# Tier-1 verify — the one command builders and CI both run.
#
# Mirrors ROADMAP.md's tier-1 command with the slow multi-device subprocess
# tests deselected so the exit code is a usable regression gate: green
# unless the diff broke something.  Extra args are forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q -m "not slow" "$@"
