#!/usr/bin/env bash
# Tier-1 verify — the one command builders and CI both run.
#
# Mirrors ROADMAP.md's tier-1 command with the slow multi-device subprocess
# tests deselected so the exit code is a usable regression gate: green
# unless the diff broke something.  Extra args are forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
# run the whole suite under the serving concurrency sanitizer
# (serving/debug.py): guarded containers + owner-tracked lock turn any
# off-lock scheduler mutation into a hard failure.  Opt out per-run with
# QBS_SANITIZE=0.
export QBS_SANITIZE="${QBS_SANITIZE:-1}"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q -m "not slow" "$@"
