#!/usr/bin/env bash
# Tier-1 verify — the one command builders and CI both run.
#
# Mirrors ROADMAP.md's tier-1 command with (a) the slow multi-device
# subprocess tests deselected and (b) the 4 known pre-existing LM-side
# failures deselected (tracked in ROADMAP Open items) so the exit code is
# a usable regression gate: green unless the diff broke something.
# Remove a --deselect line when its test is fixed; extra args are
# forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q -m "not slow" \
  --deselect "tests/test_arch_smoke.py::test_prefill_decode_matches_forward[dbrx-132b]" \
  --deselect "tests/test_arch_smoke.py::test_prefill_decode_matches_forward[phi3.5-moe-42b-a6.6b]" \
  --deselect "tests/test_perf_variants.py::test_layer_remat_same_loss_and_grads" \
  --deselect "tests/test_substrate.py::test_loss_decreases" \
  "$@"
