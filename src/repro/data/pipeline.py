"""Deterministic sharded data pipeline.

Keyed generation: batch(step, host) is a pure function of (seed, step,
host), so

* any host subset can replay its shard after a failure (fault tolerance),
* elastic re-scaling re-partitions deterministically (the global batch for
  a step is identical regardless of host count),
* no coordination traffic is needed between hosts.

A file-backed dataset (token shards on disk, memory-mapped) and a prefetch
thread cover the production path; the synthetic stream drives tests and
benchmarks (the paper's workloads are graphs, not corpora — LM data here
exercises the substrate).
"""
from __future__ import annotations

import hashlib
import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator

import numpy as np


def _seed_for(base_seed: int, step: int, host: int) -> int:
    h = hashlib.blake2b(
        f"{base_seed}:{step}:{host}".encode(), digest_size=8
    ).digest()
    return int.from_bytes(h, "little") % (2**63)


@dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # [audio]/[vlm] stubs
    frontend: str = "none"
    frontend_dim: int = 0
    n_patches: int = 256


class SyntheticLM:
    """Markov-ish synthetic token stream (learnable: next token depends on
    the current one, so loss decreases measurably within a few steps)."""

    def __init__(self, cfg: SyntheticLMConfig, host: int = 0, n_hosts: int = 1):
        if cfg.global_batch % n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.host = host
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(_seed_for(cfg.seed, step, self.host))
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        start = rng.integers(0, v, size=(b, 1))
        drift = rng.integers(1, 17, size=(b, s))
        toks = (start + np.cumsum(drift, axis=1) - drift) % v
        noise = rng.random((b, s)) < 0.05
        toks = np.where(noise, rng.integers(0, v, size=(b, s)), toks)
        toks = toks.astype(np.int32)
        if cfg.frontend == "audio_frames":
            feats = rng.normal(size=(b, s, cfg.frontend_dim)).astype(np.float32)
            return {
                "features": feats,
                "targets": toks,
                "loss_mask": (rng.random((b, s)) < 0.3),
            }
        if cfg.frontend == "vision_patches":
            return {
                "patches": rng.normal(size=(b, cfg.n_patches, cfg.frontend_dim)).astype(np.float32),
                "tokens": toks,
            }
        return {"tokens": toks}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FileBackedLM:
    """Token shards on disk (one .npy per host-shard), memory-mapped reads,
    deterministic step->window addressing."""

    def __init__(self, root: str | Path, seq_len: int, local_batch: int,
                 host: int = 0, n_hosts: int = 1):
        self.root = Path(root)
        self.seq_len = seq_len
        self.local_batch = local_batch
        path = self.root / f"shard_{host:05d}_of_{n_hosts:05d}.npy"
        self.tokens = np.load(path, mmap_mode="r")

    @staticmethod
    def write_corpus(root: str | Path, tokens: np.ndarray, n_hosts: int) -> None:
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        shards = np.array_split(tokens, n_hosts)
        for h, sh in enumerate(shards):
            np.save(root / f"shard_{h:05d}_of_{n_hosts:05d}.npy", sh)

    def batch_at(self, step: int) -> dict:
        n = self.tokens.shape[0]
        need = self.local_batch * (self.seq_len + 1)
        start = (step * need) % max(n - need, 1)
        window = np.asarray(self.tokens[start:start + need])
        window = window[: self.local_batch * (self.seq_len + 1)]
        return {"tokens": window.reshape(self.local_batch, self.seq_len + 1)[:, :-1].astype(np.int32)}


class Prefetcher:
    """Background-thread prefetch (straggler slack: the host pipeline runs
    ``depth`` steps ahead of the device step)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.source.batch_at(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
