from .pipeline import FileBackedLM, Prefetcher, SyntheticLM, SyntheticLMConfig

__all__ = ["FileBackedLM", "Prefetcher", "SyntheticLM", "SyntheticLMConfig"]
