"""Version-compat shims for JAX API drift.

``jax.shard_map`` only exists as a top-level name on newer JAX releases; on
the pinned 0.4.x toolchain the attribute raises ``AttributeError`` through
the deprecation machinery while the implementation lives in
``jax.experimental.shard_map``.  The experimental version also lacks a
replication rule for ``lax.while_loop`` (every labelling/serving program
here carries a loop), so it must run with ``check_rep=False`` — the newer
per-axis varying-type checker accepts those programs as written.
Everything in this repo imports ``shard_map`` from here so a future JAX
bump is a one-line change.
"""
from __future__ import annotations

import jax

_native = getattr(jax, "shard_map", None)

if _native is not None:
    shard_map = _native
else:
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, /, **kwargs):
        kwargs.setdefault("check_rep", False)
        return _shard_map_exp(f, **kwargs)

__all__ = ["shard_map"]
