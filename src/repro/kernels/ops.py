"""Jit'd public wrappers for the Pallas kernels.

On this CPU container kernels run with ``interpret=True`` (the kernel body
executed in Python by the Pallas interpreter — same dataflow, same BlockSpec
tiling, no TPU).  ``use_pallas=False`` falls back to the pure-jnp reference
(what XLA:TPU would fuse anyway); the flag exists so the serving path can be
profiled both ways.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .frontier import bitmap_expand as _bitmap_expand_pallas
from .minplus import minplus as _minplus_pallas

_ON_TPU = jax.default_backend() == "tpu"


def minplus(a: jax.Array, b: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """Tropical matmul  C = A (minplus) B.  Shapes (M,K) x (K,N) -> (M,N)."""
    if not use_pallas:
        return ref.minplus_ref(a, b)
    return _minplus_pallas(a, b, interpret=not _ON_TPU)


def bitmap_expand(frontier: jax.Array, adjacency: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """One BFS expansion level over a dense adjacency block (OR-AND matmul)."""
    if not use_pallas:
        return ref.bitmap_expand_ref(frontier, adjacency)
    return _bitmap_expand_pallas(frontier, adjacency, interpret=not _ON_TPU)


def sketch_d_top(lu: jax.Array, lv: jax.Array, meta_dist: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """d_top for a query batch via two chained min-plus contractions
    (the Pallas-accelerated sketching fast path)."""
    t = minplus(lu, meta_dist, use_pallas=use_pallas)           # (B, R)
    return jnp.min(t + lv, axis=1)
