"""Pallas TPU kernel: blocked min-plus (tropical) matmul.

Sketching (Eq. 3) is a min-plus contraction (B, R) x (R, R) -> (B, R).  The
MXU multiplies-and-adds and cannot evaluate a (min, +) semiring, so this
kernel targets the **VPU**: 8x128-aligned VMEM tiles, a fori_loop over the
contraction dim broadcasting one A-column + one B-row per step, and a
running elementwise minimum held in registers/VMEM.  This is the honest TPU
mapping of the paper's nested landmark-pair loop (Algorithm 3, lines 2-5):
arithmetic intensity is O(K) per output element, so for K = |R| = 20..128
the op is compute-bound on the VPU rather than HBM-bound.

Block shapes: A tile (TM, K), B tile (K, TN), C tile (TM, TN); K is kept
whole (R <= 128 after padding) so the grid is (M/TM, N/TN) with no K-grid —
each grid cell touches A and B exactly once: no revisits, no accumulator
spills.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _minplus_kernel(a_ref, b_ref, o_ref, *, k_steps: int):
    a = a_ref[...]  # (TM, K)
    b = b_ref[...]  # (K, TN)

    def body(k, acc):
        col = jax.lax.dynamic_slice_in_dim(a, k, 1, axis=1)  # (TM, 1)
        row = jax.lax.dynamic_slice_in_dim(b, k, 1, axis=0)  # (1, TN)
        return jnp.minimum(acc, col + row)

    init = a[:, 0:1] + b[0:1, :]
    o_ref[...] = jax.lax.fori_loop(1, k_steps, body, init)


def _pad_to(x: jax.Array, m: int, axis: int, fill) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % m
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def minplus(
    a: jax.Array,
    b: jax.Array,
    *,
    tm: int = 128,
    tn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """C[m, n] = min_k (A[m, k] + B[k, n]) with INF-safe padding.

    ``interpret=True`` executes the kernel body on CPU for validation; on a
    real TPU pass ``interpret=False``.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad shapes {a.shape} x {b.shape}")
    if jnp.issubdtype(a.dtype, jnp.unsignedinteger) or \
            jnp.issubdtype(b.dtype, jnp.unsignedinteger):
        # packed uint8/uint16 tables must widen first (sentinel + sentinel
        # wraps around in the narrow dtype): core.packing.widen_dist
        raise ValueError(
            f"minplus on unsigned dtypes {a.dtype}/{b.dtype}; widen packed "
            f"tables with core.packing.widen_dist before the contraction")
    m, k = a.shape
    _, n = b.shape
    big = jnp.asarray(1 << 24, a.dtype)  # > INF, still overflow-safe

    ap = _pad_to(_pad_to(a, tm, 0, big), 128, 1, big)
    bp = _pad_to(_pad_to(b, 128, 0, big), tn, 1, big)
    kp = ap.shape[1]

    grid = (ap.shape[0] // tm, bp.shape[1] // tn)
    out = pl.pallas_call(
        functools.partial(_minplus_kernel, k_steps=kp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), a.dtype),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]
