"""Pure-jnp oracles for every Pallas kernel (the reference semantics the
kernels must reproduce bit-exactly)."""
from __future__ import annotations

import jax.numpy as jnp


def minplus_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Min-plus (tropical) matmul: C[m, n] = min_k (A[m, k] + B[k, n]).

    Used by sketching (Eq. 3): (B_queries, R) x (R, R) distance contraction.
    int32 inputs with INF sentinels; caller guarantees no overflow
    (INF = 2**20, so INF + INF << int32 max).
    """
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def bitmap_expand_ref(frontier: jnp.ndarray, adjacency: jnp.ndarray) -> jnp.ndarray:
    """One level-synchronous BFS expansion over a dense adjacency block.

    frontier:  (R, V) bool — current frontier per BFS root
    adjacency: (V, V) bool — symmetric adjacency block
    returns    (R, V) bool — vertices adjacent to the frontier

    The OR-AND semiring product; on the MXU this is an f32 matmul + (>0).
    """
    return (frontier.astype(jnp.float32) @ adjacency.astype(jnp.float32)) > 0.5
