"""Pallas TPU kernels for QbS hot spots, validated in interpret mode.

* ``minplus``       — tropical matmul for sketching (VPU; min-plus is not an
                      MXU semiring, see minplus.py docstring)
* ``bitmap_expand`` — OR-AND BFS frontier expansion on dense blocks (MXU)
"""
from .ops import bitmap_expand, minplus, sketch_d_top

__all__ = ["bitmap_expand", "minplus", "sketch_d_top"]
