"""Pallas TPU kernel: dense-block BFS frontier expansion on the MXU.

The labelling phase is |R| simultaneous BFSs (Algorithm 2).  On hub-dense
graph blocks the level-synchronous expansion

    next[r, w] = OR_{v} frontier[r, v] AND adjacency[v, w]

is an OR-AND semiring matmul.  Unlike min-plus, this semiring *does* map
onto the MXU: cast to f32, matmul, threshold (>0).  The kernel is a blocked
matmul with a K-grid accumulator; the final grid step applies the
threshold so the boolean never round-trips through HBM as f32.

This is the TPU-native replacement for the paper's per-thread adjacency
walks: a (R, V) x (V, V) block product with 128-aligned VMEM tiles keeps
the MXU busy instead of chasing pointers.  The edge-list ``segment_max``
path in ``repro.core`` remains the scalable route for sparse graphs; this
kernel serves the dense blocks (hub-hub subgraphs) where tens of percent
of all traversal work concentrates (§6.5 of the paper: high-centrality
regions dominate query work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _expand_kernel(f_ref, a_ref, o_ref, acc_ref, *, k_grid: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        f_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_grid - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] > 0.5).astype(jnp.bool_)


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    rem = (-x.shape[axis]) % m
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk", "interpret"))
def bitmap_expand(
    frontier: jax.Array,
    adjacency: jax.Array,
    *,
    tm: int = 8,
    tn: int = 128,
    tk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """next[r, w] = any_v frontier[r, v] & adjacency[v, w].

    frontier (R, V) bool; adjacency (V, V) bool -> (R, V) bool.
    """
    if frontier.ndim != 2 or adjacency.ndim != 2:
        raise ValueError("rank-2 inputs required")
    if frontier.shape[1] != adjacency.shape[0]:
        raise ValueError(f"bad shapes {frontier.shape} x {adjacency.shape}")
    r, v = frontier.shape
    f = _pad_to(_pad_to(frontier.astype(jnp.float32), tm, 0), tk, 1)
    a = _pad_to(_pad_to(adjacency.astype(jnp.float32), tk, 0), tn, 1)
    k_grid = f.shape[1] // tk
    grid = (f.shape[0] // tm, a.shape[1] // tn, k_grid)

    out = pl.pallas_call(
        functools.partial(_expand_kernel, k_grid=k_grid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((f.shape[0], a.shape[1]), jnp.bool_),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=interpret,
    )(f, a)
    return out[:r, :v]
