"""Pallas TPU kernel: dense-block BFS frontier expansion on the MXU.

The labelling phase is |R| simultaneous BFSs (Algorithm 2).  On hub-dense
graph blocks the level-synchronous expansion

    next[r, w] = OR_{v} frontier[r, v] AND adjacency[v, w]

is an OR-AND semiring matmul.  Unlike min-plus, this semiring *does* map
onto the MXU: cast to f32, matmul, threshold (>0).  The kernel is a blocked
matmul with a K-grid accumulator; the final grid step applies the
threshold so the boolean never round-trips through HBM as f32.

This is the TPU-native replacement for the paper's per-thread adjacency
walks: a (R, V) x (V, V) block product with 128-aligned VMEM tiles keeps
the MXU busy instead of chasing pointers.  The edge-list ``segment_max``
path in ``repro.core`` remains the scalable route for sparse graphs; this
kernel serves the dense blocks (hub-hub subgraphs) where tens of percent
of all traversal work concentrates (§6.5 of the paper: high-centrality
regions dominate query work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _expand_kernel(f_ref, a_ref, o_ref, acc_ref, *, k_grid: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        f_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_grid - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] > 0.5).astype(jnp.bool_)


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    rem = (-x.shape[axis]) % m
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk", "interpret"))
def bitmap_expand(
    frontier: jax.Array,
    adjacency: jax.Array,
    *,
    tm: int = 8,
    tn: int = 128,
    tk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """next[r, w] = any_v frontier[r, v] & adjacency[v, w].

    frontier (R, V) bool; adjacency (V, W) bool -> (R, W) bool.
    """
    if frontier.ndim != 2 or adjacency.ndim != 2:
        raise ValueError("rank-2 inputs required")
    if frontier.shape[1] != adjacency.shape[0]:
        raise ValueError(f"bad shapes {frontier.shape} x {adjacency.shape}")
    r = frontier.shape[0]
    v = adjacency.shape[1]
    f = _pad_to(_pad_to(frontier.astype(jnp.float32), tm, 0), tk, 1)
    a = _pad_to(_pad_to(adjacency.astype(jnp.float32), tk, 0), tn, 1)
    k_grid = f.shape[1] // tk
    grid = (f.shape[0] // tm, a.shape[1] // tn, k_grid)

    out = pl.pallas_call(
        functools.partial(_expand_kernel, k_grid=k_grid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((f.shape[0], a.shape[1]), jnp.bool_),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=interpret,
    )(f, a)
    return out[:r, :v]


def _expand_packed_kernel(f_ref, w_ref, o_ref, acc_ref, *, k_grid: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Unpack the (tk, tn/32) uint32 word tile into the (tk, tn) f32 operand
    # in VMEM: bit i of word w is column 32*w + i (core.packing order).  The
    # dense mask exists only here, per tile — HBM holds the words.
    words = w_ref[...]
    bits = (words[:, :, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    a = bits.reshape(words.shape[0], -1).astype(jnp.float32)
    acc_ref[...] += jnp.dot(
        f_ref[...], a, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_grid - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] > 0.5).astype(jnp.bool_)


@functools.partial(jax.jit,
                   static_argnames=("n_cols", "tm", "tn", "tk", "interpret"))
def bitmap_expand_packed(
    frontier: jax.Array,
    adj_words: jax.Array,
    *,
    n_cols: int | None = None,
    tm: int = 8,
    tn: int = 128,
    tk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """``bitmap_expand`` over a *bit-packed* adjacency: frontier (R, V)
    bool x adj_words (V, W) uint32 (32 little-endian columns per word,
    ``core.packing.pack_bits`` layout) -> (R, n_cols) bool.

    The adjacency never materializes densely in HBM: each grid step loads a
    uint32 word tile and unpacks it in VMEM right before the OR-AND matmul,
    so the hub-hub reachability rows stay 32x smaller end-to-end.
    """
    if frontier.ndim != 2 or adj_words.ndim != 2:
        raise ValueError("rank-2 inputs required")
    if frontier.shape[1] != adj_words.shape[0]:
        raise ValueError(f"bad shapes {frontier.shape} x {adj_words.shape}")
    if tn % 32:
        raise ValueError("tn must be a multiple of the 32-bit word width")
    r = frontier.shape[0]
    n = adj_words.shape[1] * 32 if n_cols is None else n_cols
    tw = tn // 32
    f = _pad_to(_pad_to(frontier.astype(jnp.float32), tm, 0), tk, 1)
    w = _pad_to(_pad_to(adj_words, tk, 0), tw, 1)
    k_grid = f.shape[1] // tk
    grid = (f.shape[0] // tm, w.shape[1] // tw, k_grid)

    out = pl.pallas_call(
        functools.partial(_expand_packed_kernel, k_grid=k_grid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tw), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((f.shape[0], w.shape[1] * 32),
                                       jnp.bool_),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=interpret,
    )(f, w)
    return out[:r, :n]
