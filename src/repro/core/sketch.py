"""Algorithm 3 (sketch computation), batched over queries.

A sketch for SPG(u, v) is the set of landmark paths attaining

    d_top(u,v) = min_{r,r'} ( delta_ur + d_M(r, r') + delta_r'v )     (Eq. 3)

We compute it for a whole query batch as a min-plus semiring contraction
(B,R) x (R,R) x (R,B): exactly the shape the Pallas kernel in
``repro.kernels.minplus`` implements with VMEM tiling.  Passing
``use_pallas=True`` routes the Eq. 3 contraction through that kernel
(interpreted on CPU, real VPU tiles on TPU); the default pure-jnp reduction
is the reference fallback and what the shard_map programs use.  Both paths
are bit-identical: the semiring is exact integer (min, +).  The structural
part (which landmark pairs attain the min, which meta edges lie on their
meta shortest paths) stays as masked dense ops over R^2/R^4 — with
|R| = 20 these are tiny and fuse into the surrounding program.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels.ops import sketch_d_top as kernel_sketch_d_top
from .graph import INF
from .packing import widen_dist


class SketchBatch(NamedTuple):
    """Sketches S_uv for a batch of queries (Definition 4.5)."""

    d_top: jax.Array        # (B,) upper bound; INF when no landmark path exists
    du_land: jax.Array      # (B, R) sigma_S(u, r): weight of sketch edge (u,r); INF = absent
    dv_land: jax.Array      # (B, R) sigma_S(v, r')
    meta_edge: jax.Array    # (B, R, R) bool: meta edge (i, j) in the sketch
    d_star_u: jax.Array     # (B,) per-side search budget (Eq. 4)
    d_star_v: jax.Array     # (B,)


def minplus_vm(lu: jax.Array, dm: jax.Array) -> jax.Array:
    """(B,R) minplus (R,R) -> (B,R); pure-jnp reference used by default on
    CPU. ``repro.kernels.ops.minplus`` is the Pallas drop-in."""
    return jnp.min(lu[:, :, None] + dm[None, :, :], axis=1)


def compute_sketch_batch(
    lu: jax.Array,           # (B, R) label distances of u (INF = no entry)
    lv: jax.Array,           # (B, R)
    meta_w: jax.Array,       # (R, R) direct meta edge weights
    meta_dist: jax.Array,    # (R, R) d_M
    *,
    use_pallas: bool = False,
) -> SketchBatch:
    # Dual-mode inputs: packed uint8/uint16 rows (sentinel = INF) widen to
    # int32 here, in the registers of this program — the packed table is
    # what HBM holds (core.packing, DESIGN.md §10).  Unpacked int32 inputs
    # pass through, keeping the oracle path bit-identical.
    lu = widen_dist(lu)
    lv = widen_dist(lv)
    meta_w = widen_dist(meta_w)
    meta_dist = widen_dist(meta_dist)

    # pi[b, r, r'] = delta_ur + d_M(r,r') + delta_r'v  (clamped to INF)
    pi = lu[:, :, None] + meta_dist[None, :, :] + lv[:, None, :]
    pi = jnp.minimum(pi, INF)
    if use_pallas:
        # Eq. 3 hot loop on the Pallas min-plus kernel (min is monotone, so
        # clamping after the reduction matches the clamped-pi reduction).
        # pi stays materialized either way for the attaining-pair masks
        # below; the kernel replaces only the (B,R,R) reduction, so this
        # route is about running the real serving path through the TPU
        # kernel — a d_top-only pipeline (kernels.ops.sketch_d_top,
        # d_top_only) is where it skips pi entirely.
        d_top = jnp.minimum(kernel_sketch_d_top(lu, lv, meta_dist), INF)
    else:
        d_top = pi.min(axis=(1, 2))
    have = d_top < INF

    att = (pi == d_top[:, None, None]) & have[:, None, None]  # attaining pairs

    du_land = jnp.where(att.any(axis=2), lu, INF)
    dv_land = jnp.where(att.any(axis=1), lv, INF)

    # Meta edge (i, j) is in the sketch iff it lies on a shortest meta path
    # between some attaining pair (r, r'):
    #   d_M(r,i) + w(i,j) + d_M(j,r') == d_M(r,r')
    # Contracted without materializing (B,R,R,R,R):
    #   left[b,i]  covers nothing alone; couple via per-pair check below.
    w_fin = meta_w < INF
    # cost[r, i, j, r'] = d_M(r,i) + w(i,j) + d_M(j,r') ; compare to d_M(r,r')
    cost = (
        meta_dist[:, :, None, None]
        + meta_w[None, :, :, None]
        + meta_dist.T[None, None, :, :]
    )  # (R, i, j, R')
    on_path = (cost == meta_dist[:, None, None, :]) & w_fin[None, :, :, None]
    # meta_edge[b,i,j] = any_{r,r'} att[b,r,r'] & on_path[r,i,j,r']
    meta_edge = jnp.einsum("brs,rijs->bij", att, on_path, preferred_element_type=jnp.int32) > 0

    def budget(side_land):
        present = side_land < INF
        b = jnp.max(jnp.where(present, side_land - 1, -1), axis=1)
        return jnp.maximum(b, 0).astype(jnp.int32)

    return SketchBatch(
        d_top=d_top.astype(jnp.int32),
        du_land=du_land.astype(jnp.int32),
        dv_land=dv_land.astype(jnp.int32),
        meta_edge=meta_edge,
        d_star_u=budget(du_land),
        d_star_v=budget(dv_land),
    )


def d_top_only(lu: jax.Array, lv: jax.Array, meta_dist: jax.Array, minplus=minplus_vm) -> jax.Array:
    """Fast path computing just the bound d_top (used by benchmarks and the
    Pallas kernel integration): two chained min-plus contractions.  Accepts
    packed or unpacked inputs like ``compute_sketch_batch``."""
    t = minplus(widen_dist(lu), widen_dist(meta_dist))     # (B, R)
    return jnp.minimum(jnp.min(t + widen_dist(lv), axis=1), INF)
