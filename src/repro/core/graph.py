"""Fixed-shape CSR graph representation, builders and synthetic generators.

The QbS engine operates on unweighted, undirected graphs stored as a
symmetrized directed edge list (every undirected edge appears in both
orientations) plus a CSR ``indptr``.  All shapes are static so every phase
jits cleanly; padding uses self-loops on an isolated padding vertex, which
are no-ops for level-synchronous BFS (a self loop re-delivers a message to
an already-visited vertex).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Distance sentinel.  Small enough that INF + INF + INF fits int32 with room
# to spare, large enough to exceed any real distance (max_levels <= 2**14).
INF = 1 << 20
INF_I32 = np.int32(INF)


class Graph(NamedTuple):
    """Symmetrized CSR graph. ``src``/``dst`` are sorted by ``src``."""

    indptr: jax.Array  # (V+1,) int32
    src: jax.Array     # (E,) int32
    dst: jax.Array     # (E,) int32

    @property
    def n_vertices(self) -> int:
        return int(self.indptr.shape[0]) - 1

    @property
    def n_edges(self) -> int:
        """Directed edge-slot count (2x undirected edges + padding)."""
        return int(self.src.shape[0])

    def degrees(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def hub_mask(self, n_hubs: int | None = None,
                 top_frac: float = 0.01) -> np.ndarray:
        """Host-side ``(V,)`` bool mask of the top-degree "hub" vertices —
        the degree-skew metadata consumers outside the engine key on (the
        serving cache's hub-aware eviction protects entries whose
        endpoints land in this set).  ``n_hubs`` picks an explicit count;
        otherwise the top ``top_frac`` of vertices (at least one).  Ties
        break by vertex id (stable sort), matching ``select_landmarks``.
        Derived from ``frontier.hub_split`` so there is exactly one
        definition of "hub" (self-loop edge padding excluded from the
        degree count: the padding vertex carries every pad slot as a self
        loop and must never rank as a hub)."""
        from .frontier import hub_split

        if n_hubs is None:
            n_hubs = max(1, int(self.n_vertices * top_frac))
        return hub_split(self, int(n_hubs)).is_hub

    def hub_split(self, n_hubs: int | None = None):
        """Degree split for the hybrid frontier backend: the top-``n_hubs``
        vertices by (self-loop-free) degree form a dense hub block, the rest
        stay on the sparse edge-list relay.  Returns a host-side
        ``frontier.HubSplit``; see ``core.frontier`` for the engine that
        consumes it."""
        from .frontier import hub_split

        return hub_split(self, n_hubs)


def from_edges(
    edges: np.ndarray,
    n_vertices: int,
    *,
    pad_vertices_to: int | None = None,
    pad_edges_to: int | None = None,
) -> Graph:
    """Build a symmetrized ``Graph`` from an (M, 2) undirected edge array.

    Self-loops and duplicate edges are dropped.  Optional padding appends
    isolated vertices and self-loop edge slots on the last padding vertex so
    differently-sized test graphs share one jit cache entry.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    mask = edges[:, 0] != edges[:, 1]
    edges = edges[mask]
    if edges.size:
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        canon = np.unique(lo * np.int64(n_vertices) + hi)
        lo = (canon // n_vertices).astype(np.int32)
        hi = (canon % n_vertices).astype(np.int32)
        s = np.concatenate([lo, hi])
        d = np.concatenate([hi, lo])
    else:
        s = np.zeros((0,), np.int32)
        d = np.zeros((0,), np.int32)

    n_v = n_vertices
    if pad_vertices_to is not None:
        if pad_vertices_to < n_vertices:
            raise ValueError("pad_vertices_to < n_vertices")
        n_v = pad_vertices_to
    n_e = s.shape[0]
    if pad_edges_to is not None:
        if pad_edges_to < n_e:
            raise ValueError(f"pad_edges_to={pad_edges_to} < {n_e}")
        pad_v = n_v - 1  # isolated when padding vertices were requested
        extra = pad_edges_to - n_e
        s = np.concatenate([s, np.full((extra,), pad_v, np.int32)])
        d = np.concatenate([d, np.full((extra,), pad_v, np.int32)])

    order = np.argsort(s, kind="stable")
    s = s[order].astype(np.int32)
    d = d[order].astype(np.int32)
    indptr = np.zeros((n_v + 1,), np.int64)
    np.add.at(indptr, s + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    return Graph(jnp.asarray(indptr), jnp.asarray(s), jnp.asarray(d))


def edge_set(graph: Graph) -> np.ndarray:
    """Host-side ``(M, 2)`` canonical undirected edge array (lo < hi, sorted
    lexicographically) of the real edges in ``graph`` — the inverse of
    ``from_edges`` up to padding.  Padding self-loop slots are excluded, so
    the result depends only on the edge *set*, never on pad capacity."""
    s = np.asarray(graph.src, np.int64)
    d = np.asarray(graph.dst, np.int64)
    real = s < d  # one orientation per undirected edge; drops self-loop pads
    return np.stack([s[real], d[real]], axis=1)


def edge_keys(edges: np.ndarray, n_vertices: int) -> np.ndarray:
    """Canonical sorted int64 keys (``lo * V + hi``, self-loops dropped) for
    an (M, 2) undirected edge array — the set-algebra currency shared by
    ``apply_edge_updates`` and ``QbSIndex.apply_update``."""
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    if edges.size and (edges.min() < 0 or edges.max() >= n_vertices):
        raise ValueError(f"edge endpoint out of range for {n_vertices} vertices")
    edges = edges[edges[:, 0] != edges[:, 1]]
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    return np.unique(lo * np.int64(n_vertices) + hi)


def apply_edge_updates(
    graph: Graph,
    inserts: np.ndarray | None = None,
    deletes: np.ndarray | None = None,
) -> Graph:
    """Rebuild a ``Graph`` with ``inserts`` added and ``deletes`` removed
    (an edge in both is inserted — inserts win).

    Capacity-preserving: the new CSR keeps the old vertex count and edge-slot
    capacity (doubling the slot capacity only when the new edge set
    overflows it), so jitted consumers with static shapes keep their
    compilation cache across epochs.  Because ``from_edges`` canonicalizes
    deterministically, the resulting edge-slot ids for the surviving edges
    depend only on the edge set — an independently rebuilt graph over the
    same edges is bit-identical.
    """
    n_v = graph.n_vertices
    cur = edge_set(graph)
    keys = cur[:, 0] * np.int64(n_v) + cur[:, 1]
    if deletes is not None:
        dk = edge_keys(deletes, n_v)
        keys = keys[~np.isin(keys, dk)]
    if inserts is not None:
        keys = np.union1d(keys, edge_keys(inserts, n_v))
    new_edges = np.stack([keys // n_v, keys % n_v], axis=1)
    cap = graph.n_edges
    while cap < 2 * len(keys):
        cap = max(2 * cap, 2)
    return from_edges(new_edges, n_v, pad_vertices_to=n_v, pad_edges_to=cap)


def to_networkx(graph: Graph):
    import networkx as nx

    g = nx.Graph()
    g.add_nodes_from(range(graph.n_vertices))
    s = np.asarray(graph.src)
    d = np.asarray(graph.dst)
    real = s != d
    g.add_edges_from(zip(s[real].tolist(), d[real].tolist()))
    return g


# ---------------------------------------------------------------------------
# Generators (host-side; the data pipeline is host code in real frameworks).
# ---------------------------------------------------------------------------

def gnp_random_graph(n: int, avg_degree: float, seed: int, **pad) -> Graph:
    """Erdos-Renyi-ish sparse sampler: E = n*avg_degree/2 sampled pairs."""
    rng = np.random.default_rng(seed)
    m = max(1, int(n * avg_degree / 2))
    edges = rng.integers(0, n, size=(m, 2), dtype=np.int64)
    return from_edges(edges, n, **pad)


def barabasi_albert_graph(n: int, m: int, seed: int, **pad) -> Graph:
    """Preferential-attachment generator (hub-heavy, matches social/web
    regimes of the paper: Twitter/Youtube-like degree skew)."""
    rng = np.random.default_rng(seed)
    m = max(1, min(m, n - 1))
    targets = list(range(m))
    repeated: list[int] = []
    edges = []
    for v in range(m, n):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * m)
        # sample next targets from the degree-weighted multiset
        idx = rng.integers(0, len(repeated), size=(m,))
        targets = list({repeated[i] for i in idx})
        while len(targets) < m:
            targets.append(int(rng.integers(0, v + 1)))
    return from_edges(np.asarray(edges, np.int64), n, **pad)


def random_regular_graph(n: int, degree: int, seed: int, **pad) -> Graph:
    """~degree-regular random graph via unions of random matchings:
    flat degree distribution AND small diameter — the Friendster regime
    (ring_of_cliques is flat-degree but long-diameter; keep it for tests)."""
    rng = np.random.default_rng(seed)
    edges = []
    for _ in range(max(1, degree // 2)):
        perm = rng.permutation(n)
        edges.append(np.stack([np.arange(n), perm], axis=1))
    return from_edges(np.concatenate(edges), n, **pad)


def ring_of_cliques(n_cliques: int, clique_size: int, seed: int = 0, **pad) -> Graph:
    """Flat-degree, long-diameter stress regime (tests)."""
    edges = []
    n = n_cliques * clique_size
    for c in range(n_cliques):
        base = c * clique_size
        for i in range(clique_size):
            for j in range(i + 1, clique_size):
                edges.append((base + i, base + j))
        nxt = ((c + 1) % n_cliques) * clique_size
        edges.append((base, nxt))
    return from_edges(np.asarray(edges, np.int64), n, **pad)


def grid_graph(rows: int, cols: int, **pad) -> Graph:
    edges = []
    for r in range(rows):
        for c in range(cols):
            v = r * cols + c
            if c + 1 < cols:
                edges.append((v, v + 1))
            if r + 1 < rows:
                edges.append((v, v + cols))
    return from_edges(np.asarray(edges, np.int64), rows * cols, **pad)


def largest_connected_component(edges: np.ndarray, n: int) -> tuple[np.ndarray, int]:
    """Relabel ``edges`` to the largest connected component. Host-side."""
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    parent = np.arange(n)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for a, b in edges:
        ra, rb = find(int(a)), find(int(b))
        if ra != rb:
            parent[ra] = rb
    roots = np.array([find(i) for i in range(n)])
    vals, counts = np.unique(roots, return_counts=True)
    big = vals[np.argmax(counts)]
    keep = roots == big
    remap = -np.ones(n, np.int64)
    remap[keep] = np.arange(keep.sum())
    mask = keep[edges[:, 0]] & keep[edges[:, 1]]
    out = remap[edges[mask]]
    return out, int(keep.sum())


def select_landmarks(graph: Graph, n_landmarks: int) -> np.ndarray:
    """Paper's strategy: highest-degree vertices (§6.1 Landmarks)."""
    deg = np.asarray(graph.degrees())
    order = np.argsort(-deg, kind="stable")
    return np.sort(order[:n_landmarks]).astype(np.int32)
