"""Distributed QbS: edge-sharded labelling and batch-sharded query serving.

Mapping of the paper onto a TPU mesh (DESIGN.md §2, §7):

* **Labelling** (offline): the |R| BFSs are one batched frontier program.
  Edges are sharded across devices *by destination-vertex block* (blocks cut
  at balanced edge counts, so hub-heavy blocks stay narrow); ``depth`` /
  ``reach_L`` live vertex-sharded next to the edges that write them.  Each
  level every device relays its local edges and the new frontier is
  exchanged with one ``all_gather``.  Lemma 5.2 (order-independence) is what
  makes the device-local relays commute — the merge is an exact OR/min.

  Two exchange formats:
    - ``frontier_mode="bool"``   : gather (2, R, V_loc) bool   (paper-faithful
                                   straightforward port; 2 bytes/vertex/root)
    - ``frontier_mode="bitmap"`` : gather (2, R, V_loc/32) uint32 packed
                                   (beyond-paper: 16x fewer collective bytes)

* **Serving** (online): queries are embarrassingly parallel — the batch is
  sharded across the mesh, labels and the sparsified graph are replicated
  within a pod (``make_serve_step``).  Billion-vertex variants keep the
  labels *vertex-sharded*: ``distributed_build_sharded`` finishes the
  labelling on-device so the packed tables are born sharded
  (``ShardedLabels``, one ``jax.sharding.NamedSharding`` block per
  device, never gathered to host), and ``core.sharded.ShardedIndex``
  serves every lane from those shards (DESIGN.md §11).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from .frontier import segment_or
from .graph import INF, Graph
from .labelling import LabellingScheme, meta_apsp
# Bit-packed word layout shared with the hybrid frontier's hub block; the
# canonical definitions live in core.packing (DESIGN.md §10).
from .packing import PackedLabels, choose_pack_dtype, pack_dist, sentinel_of
from .packing import pack_bits as _pack_bits
from .packing import unpack_bits as _unpack_bits
from .search import Query, SearchContext, guided_search
from .sketch import compute_sketch_batch


class EdgePartition(NamedTuple):
    """Host-side edge partition into S destination-contiguous shards."""

    src: np.ndarray        # (S, E_max) int32, global src ids (pad: 0)
    dst_local: np.ndarray  # (S, E_max) int32, dst - vstart (pad: V_loc_max)
    vstart: np.ndarray     # (S,) int32 first vertex of each shard's block
    v_loc: int             # max local block size (padded)
    e_max: int
    eid: np.ndarray | None = None  # (S, E_max) int32 global edge-slot ids
    #                                (pad: n_edges) — lets sharded serving
    #                                scatter local certificates back into
    #                                the canonical (B, E) edge mask


def partition_edges(graph: Graph, n_shards: int) -> EdgePartition:
    """Cut vertices into contiguous blocks with ~equal *edge* counts (not
    vertex counts) so degree skew doesn't create straggler shards, then
    assign each directed edge to its destination's block."""
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    v = graph.n_vertices
    order = np.argsort(dst, kind="stable")
    dsorted = dst[order]
    ssorted = src[order]
    e = dst.shape[0]
    # block boundaries at ~equal edge quantiles, snapped to vertex borders
    cuts = [0]
    for s in range(1, n_shards):
        target = (e * s) // n_shards
        vtx = dsorted[min(target, e - 1)]
        cuts.append(int(vtx))
    cuts.append(v)
    vstart = np.maximum.accumulate(np.asarray(cuts[:-1], np.int64))
    vend = np.concatenate([vstart[1:], [v]])
    v_loc = int((vend - vstart).max()) if n_shards > 0 else v

    starts = np.searchsorted(dsorted, vstart)
    ends = np.searchsorted(dsorted, vend - 1, side="right")
    # guard empty blocks
    ends = np.maximum(ends, starts)
    e_max = int((ends - starts).max())
    e_max = max(e_max, 1)
    src_sh = np.zeros((n_shards, e_max), np.int32)
    dst_sh = np.full((n_shards, e_max), v_loc, np.int32)  # pad row = dropped
    eid_sh = np.full((n_shards, e_max), e, np.int32)      # pad -> dropped col
    for s in range(n_shards):
        a, b = starts[s], ends[s]
        src_sh[s, : b - a] = ssorted[a:b]
        dst_sh[s, : b - a] = dsorted[a:b] - vstart[s]
        eid_sh[s, : b - a] = order[a:b]
    return EdgePartition(src_sh, dst_sh, vstart.astype(np.int32), v_loc,
                         e_max, eid_sh)




def make_labelling_step(
    mesh: Mesh,
    *,
    n_vertices: int,
    v_loc: int,
    e_max: int,
    n_landmarks: int,
    axis_names: tuple[str, ...] | None = None,
    frontier_mode: str = "bitmap",
    max_levels: int = 64,
):
    """Build the jitted edge-sharded labelling program.

    Closes over *static* sizes only, so the dry-run can ``.lower()`` it from
    ShapeDtypeStructs at paper scale (ClueWeb09: V=1.7e9, E=15.6e9 directed)
    without allocating anything.  Landmark-ness is computed on the fly from
    the (R,) landmark-id vector — no (V,)-sized auxiliary arrays exist.

    Inputs: src_sh (S, E_max) int32, dst_local_sh (S, E_max) int32,
            vstart_sh (S,) int32, landmarks (R,) int32
    Outputs: depth (S, R, v_loc) int32, reach_L (S, R, v_loc) bool
    """
    axis_names = axis_names or tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    v = n_vertices
    r = n_landmarks
    vloc = v_loc
    spec_e = P(axis_names)
    rep = P()

    def shard_body(src_sh, dst_sh, vstart_sh, landmarks_j):
        # local shapes: src/dst (1, E_max) -> squeeze; vstart (1,)
        src_l = src_sh[0]
        dst_l = dst_sh[0]
        vst = vstart_sh[0]

        # local state (padded local block + 1 garbage row at index vloc)
        depth = jnp.full((r, vloc + 1), INF, jnp.int32)
        reach = jnp.zeros((r, vloc + 1), bool)
        lm_local = landmarks_j - vst
        own = (landmarks_j >= vst) & (lm_local < vloc)
        lm_idx = jnp.where(own, lm_local, vloc)
        depth = depth.at[jnp.arange(r), lm_idx].min(0)
        reach = reach.at[jnp.arange(r), lm_idx].set(own)

        local_ids = vst + jnp.arange(vloc, dtype=jnp.int32)
        local_ids = jnp.clip(local_ids, 0, v - 1)
        # landmark-ness on the fly: (R, vloc) root mask and its any-reduction
        is_root_loc = local_ids[None, :] == landmarks_j[:, None]
        is_lm_loc = is_root_loc.any(axis=0)
        prop_ok = (~is_lm_loc)[None, :] | is_root_loc

        # map global vertex id -> gathered layout index (shard, local)
        vstart_all = jax.lax.all_gather(vstart_sh, axis_names, tiled=True)  # (S,)

        def to_gathered(ids):
            shard = jnp.clip(
                jnp.searchsorted(vstart_all, ids, side="right") - 1, 0, n_shards - 1
            )
            return shard * vloc + (ids - vstart_all[shard])

        src_g = to_gathered(src_l)

        def exchange_and_read(fr_loc, pl_loc):
            """All-gather the frontier and read it at local edge sources.

            bitmap mode gathers uint32-packed words (16x fewer collective
            bytes than bool x2 flags) and extracts per-edge bits directly —
            the full boolean frontier is never materialized."""
            both = jnp.stack([fr_loc, pl_loc])  # (2, R, vloc)
            if frontier_mode == "bitmap":
                packed = _pack_bits(both)                       # (2, R, Wloc)
                wloc = packed.shape[-1]
                full = jax.lax.all_gather(packed, axis_names, tiled=False)
                full = jnp.moveaxis(full, 0, 2).reshape(2, r, n_shards * wloc)
                sh_i = src_g // vloc
                loc_i = src_g % vloc
                w_idx = sh_i * wloc + loc_i // 32
                bit = (loc_i % 32).astype(jnp.uint32)
                words = full[:, :, w_idx]                       # (2, R, E)
                vals = ((words >> bit[None, None, :]) & jnp.uint32(1)) > 0
                return vals[0], vals[1]
            full = jax.lax.all_gather(both, axis_names, tiled=False)
            full = jnp.moveaxis(full, 0, 2).reshape(2, r, n_shards * vloc)
            return full[0][:, src_g], full[1][:, src_g]

        def cond(c):
            _, _, level, alive = c
            return alive & (level < max_levels)

        def body(c):
            depth, reach, level, _ = c
            fr_loc = depth[:, :vloc] == level
            pl_loc = fr_loc & reach[:, :vloc] & prop_ok
            fr_src, pl_src = exchange_and_read(fr_loc, pl_loc)

            # local edge relay = the shared frontier primitive (int8
            # accumulator: smaller on-device temporaries, same booleans)
            msg_v = segment_or(fr_src, dst_l, vloc + 1, acc_dtype=jnp.int8)
            msg_l = segment_or(pl_src, dst_l, vloc + 1, acc_dtype=jnp.int8)
            new = msg_v & (depth == INF)
            depth2 = jnp.where(new, level + 1, depth)
            reach2 = reach | (new & msg_l)
            # psum makes the flag globally agreed (required: the all_gather
            # in the body must run the same trip count on every device);
            # OR with a varying-false keeps the carry type device-varying.
            alive = jax.lax.psum(new[:, :vloc].any().astype(jnp.int32), axis_names) > 0
            alive = alive | (vst < 0)
            return depth2, reach2, level + 1, alive

        depth, reach, _, _ = jax.lax.while_loop(
            cond, body, (depth, reach, vst * 0, vst == vst)
        )
        return depth[None, :, :vloc], reach[None, :, :vloc]

    return jax.jit(
        shard_map(
            shard_body,
            mesh=mesh,
            in_specs=(spec_e, spec_e, spec_e, rep),
            out_specs=(spec_e, spec_e),
        )
    )


class PullPlan(NamedTuple):
    """Static routing plan for demand-driven frontier exchange.

    The all-gather exchange moves 2*R*V/8 bytes/device/level, but a device
    only ever reads the frontier bits of *its local edges' sources* —
    typically ~E_loc of V vertices (50x less at ClueWeb09 scale).  The plan
    precomputes, per (sender i, receiver j), the sorted list of i-owned
    vertices that j needs; the exchange is then one all_to_all of packed
    bit-buffers and per-edge reads become static word/bit lookups.
    """

    send_idx: np.ndarray   # (S, S, P) int32: [i][j] = local idx of vertices i sends j
    edge_word: np.ndarray  # (S, E_max) int32: per-edge word into flat recv buffer
    edge_bit: np.ndarray   # (S, E_max) int32: per-edge bit position
    p_pad: int             # padded per-pair list length (multiple of 32)


def build_pull_plan(part: EdgePartition, n_shards: int) -> PullPlan:
    vstart = part.vstart.astype(np.int64)
    s_cnt = n_shards
    lists: list[list[np.ndarray]] = [[None] * s_cnt for _ in range(s_cnt)]  # type: ignore
    p_max = 1
    for j in range(s_cnt):
        valid = part.dst_local[j] < part.v_loc
        srcs = np.unique(part.src[j][valid])
        owner = np.clip(np.searchsorted(vstart, srcs, side="right") - 1, 0, s_cnt - 1)
        for i in range(s_cnt):
            li = srcs[owner == i]
            lists[i][j] = li
            p_max = max(p_max, li.size)
    p_pad = ((p_max + 31) // 32) * 32
    pw = p_pad // 32

    send_idx = np.zeros((s_cnt, s_cnt, p_pad), np.int32)
    for i in range(s_cnt):
        for j in range(s_cnt):
            li = lists[i][j]
            send_idx[i, j, : li.size] = (li - vstart[i]).astype(np.int32)

    edge_word = np.zeros((s_cnt, part.e_max), np.int32)
    edge_bit = np.zeros((s_cnt, part.e_max), np.int32)
    for j in range(s_cnt):
        valid = part.dst_local[j] < part.v_loc
        srcs = part.src[j]
        owner = np.clip(np.searchsorted(vstart, srcs, side="right") - 1, 0, s_cnt - 1)
        pos = np.zeros(srcs.shape, np.int64)
        for i in range(s_cnt):
            sel = (owner == i) & valid
            pos[sel] = np.searchsorted(lists[i][j], srcs[sel])
        edge_word[j] = (owner * pw + pos // 32).astype(np.int32)
        edge_bit[j] = (pos % 32).astype(np.int32)
    return PullPlan(send_idx, edge_word, edge_bit, p_pad)


def make_labelling_step_pull(
    mesh: Mesh,
    *,
    n_vertices: int,
    v_loc: int,
    e_max: int,
    p_pad: int,
    n_landmarks: int,
    axis_names: tuple[str, ...] | None = None,
    max_levels: int = 64,
):
    """Labelling program with demand-driven (pull) frontier exchange."""
    axis_names = axis_names or tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    v, r, vloc = n_vertices, n_landmarks, v_loc
    pw = p_pad // 32
    spec_e = P(axis_names)
    rep = P()

    def shard_body(src_sh, dst_sh, vstart_sh, landmarks_j,
                   send_idx_sh, edge_word_sh, edge_bit_sh):
        dst_l = dst_sh[0]
        vst = vstart_sh[0]
        send_idx = send_idx_sh[0]          # (S, P)
        edge_word = edge_word_sh[0]        # (E,)
        edge_bit = edge_bit_sh[0].astype(jnp.uint32)

        depth = jnp.full((r, vloc + 1), INF, jnp.int32)
        reach = jnp.zeros((r, vloc + 1), bool)
        lm_local = landmarks_j - vst
        own = (landmarks_j >= vst) & (lm_local < vloc)
        lm_idx = jnp.where(own, lm_local, vloc)
        depth = depth.at[jnp.arange(r), lm_idx].min(0)
        reach = reach.at[jnp.arange(r), lm_idx].set(own)

        local_ids = jnp.clip(vst + jnp.arange(vloc, dtype=jnp.int32), 0, v - 1)
        is_root_loc = local_ids[None, :] == landmarks_j[:, None]
        is_lm_loc = is_root_loc.any(axis=0)
        prop_ok = (~is_lm_loc)[None, :] | is_root_loc

        def exchange_and_read(fr_loc, pl_loc):
            both = jnp.concatenate([fr_loc, pl_loc], axis=0)   # (2R, vloc)
            vals = both[:, send_idx]                            # (2R, S, P)
            packed = _pack_bits(vals)                           # (2R, S, Pw)
            buf = jnp.moveaxis(packed, 1, 0)                    # (S, 2R, Pw)
            recv = jax.lax.all_to_all(
                buf, axis_names, split_axis=0, concat_axis=0, tiled=True)
            flat = jnp.moveaxis(recv, 0, 1).reshape(2 * r, n_shards * pw)
            words = flat[:, edge_word]                          # (2R, E)
            bits = (words >> edge_bit[None, :]) & jnp.uint32(1)
            on = bits > 0
            return on[:r], on[r:]

        def cond(c):
            _, _, level, alive = c
            return alive & (level < max_levels)

        def body(c):
            depth, reach, level, _ = c
            fr_loc = depth[:, :vloc] == level
            pl_loc = fr_loc & reach[:, :vloc] & prop_ok
            fr_src, pl_src = exchange_and_read(fr_loc, pl_loc)
            msg_v = segment_or(fr_src, dst_l, vloc + 1, acc_dtype=jnp.int8)
            msg_l = segment_or(pl_src, dst_l, vloc + 1, acc_dtype=jnp.int8)
            new = msg_v & (depth == INF)
            depth2 = jnp.where(new, level + 1, depth)
            reach2 = reach | (new & msg_l)
            alive = jax.lax.psum(new[:, :vloc].any().astype(jnp.int32), axis_names) > 0
            alive = alive | (vst < 0)
            return depth2, reach2, level + 1, alive

        depth, reach, _, _ = jax.lax.while_loop(
            cond, body, (depth, reach, vst * 0, vst == vst)
        )
        return depth[None, :, :vloc], reach[None, :, :vloc]

    return jax.jit(
        shard_map(
            shard_body,
            mesh=mesh,
            in_specs=(spec_e, spec_e, spec_e, rep, spec_e, spec_e, spec_e),
            out_specs=(spec_e, spec_e),
        )
    )


def distributed_build_labelling(  # qbslint: host-boundary
    graph: Graph,
    landmarks: np.ndarray,
    mesh: Mesh,
    *,
    axis_names: tuple[str, ...] | None = None,
    frontier_mode: str = "bitmap",
    max_levels: int = 64,
) -> LabellingScheme:
    """Edge-sharded Algorithm 2 over a device mesh.  Exact (== the
    single-device labelling) for any shard count.  frontier_mode: "bool"
    (paper-faithful port), "bitmap" (packed exchange), "pull" (demand-driven
    all_to_all exchange)."""
    axis_names = axis_names or tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    part = partition_edges(graph, n_shards)
    v = graph.n_vertices
    r = int(np.asarray(landmarks).shape[0])
    landmarks_j = jnp.asarray(landmarks, jnp.int32)
    is_landmark = jnp.zeros((v,), bool).at[landmarks_j].set(True)
    lid = jnp.full((v,), -1, jnp.int32).at[landmarks_j].set(
        jnp.arange(r, dtype=jnp.int32)
    )

    if frontier_mode == "pull":
        plan = build_pull_plan(part, n_shards)
        step = make_labelling_step_pull(
            mesh, n_vertices=v, v_loc=part.v_loc, e_max=part.e_max,
            p_pad=plan.p_pad, n_landmarks=r, axis_names=axis_names,
            max_levels=max_levels,
        )
        depth_sh, reach_sh = step(
            jnp.asarray(part.src), jnp.asarray(part.dst_local),
            jnp.asarray(part.vstart), landmarks_j,
            jnp.asarray(plan.send_idx), jnp.asarray(plan.edge_word),
            jnp.asarray(plan.edge_bit),
        )
    else:
        step = make_labelling_step(
            mesh, n_vertices=v, v_loc=part.v_loc, e_max=part.e_max,
            n_landmarks=r, axis_names=axis_names, frontier_mode=frontier_mode,
            max_levels=max_levels,
        )
        depth_sh, reach_sh = step(
            jnp.asarray(part.src), jnp.asarray(part.dst_local),
            jnp.asarray(part.vstart), landmarks_j,
        )

    # host re-assembly into the canonical dense labelling
    depth_np = np.asarray(depth_sh)   # (S, R, vloc)
    reach_np = np.asarray(reach_sh)
    depth_full = np.full((r, v), INF, np.int64)
    reach_full = np.zeros((r, v), bool)
    vstart = part.vstart
    vend = np.concatenate([vstart[1:], [v]])
    for s in range(depth_np.shape[0]):
        n_loc = vend[s] - vstart[s]
        depth_full[:, vstart[s]:vend[s]] = depth_np[s, :, :n_loc]
        reach_full[:, vstart[s]:vend[s]] = reach_np[s, :, :n_loc]

    is_lm_np = np.zeros((v,), bool)
    is_lm_np[np.asarray(landmarks)] = True
    valid = reach_full & ~is_lm_np[None, :]
    label_dist = np.where(valid, depth_full, INF).T.astype(np.int32)
    at_land = depth_full[:, np.asarray(landmarks)]
    l_at_land = reach_full[:, np.asarray(landmarks)]
    meta_w = np.where(l_at_land, at_land, INF)
    np.fill_diagonal(meta_w, INF)
    meta_w = np.minimum(meta_w, meta_w.T).astype(np.int32)

    return LabellingScheme(
        landmarks=landmarks_j,
        lid=lid,
        is_landmark=is_landmark,
        label_dist=jnp.asarray(label_dist),
        meta_w=jnp.asarray(meta_w),
        meta_dist=meta_apsp(jnp.asarray(meta_w)),
    )


# ---------------------------------------------------------------------------
# Born-sharded labelling: packed tables that never leave the mesh
# ---------------------------------------------------------------------------


class ShardedLabels(NamedTuple):
    """Packed label tables of one index, vertex-sharded over a mesh.

    Device fields carry a ``jax.sharding.NamedSharding``: one contiguous
    vertex block per device along the leading S axis.  The (R, R) meta
    tables and the landmark list are replicated — they are the sketch's
    landmark-landmark block, tiny by design (DESIGN.md §11).  Host fields
    hold partition *geometry* only, never table contents: the full (V, R)
    table is never materialized anywhere.
    """

    labels_sh: jax.Array   # (S, v_loc, R) packed, vertex-sharded
    lm_sh: jax.Array       # (S, R, v_loc) packed, vertex-sharded
    meta_w: jax.Array      # (R, R) packed, replicated
    meta_dist: jax.Array   # (R, R) packed, replicated (APSP closure)
    landmarks: jax.Array   # (R,) int32, replicated
    vstart: np.ndarray     # (S,) int32 first vertex of each block
    nloc: np.ndarray       # (S,) int32 real (un-padded) block sizes
    v_loc: int             # padded block size (labels_sh.shape[1])
    n_vertices: int

    @property
    def n_landmarks(self) -> int:
        return int(self.labels_sh.shape[-1])

    @property
    def pack_dtype(self) -> np.dtype:
        return np.dtype(self.labels_sh.dtype)

    @property
    def sentinel(self) -> int:
        return sentinel_of(self.labels_sh.dtype)

    def per_device_label_bytes(self) -> int:
        """Packed label bytes resident on ONE device: its (v_loc, R) label
        block + (R, v_loc) landmark-distance block + the replicated meta
        pair.  The sharding acceptance gate (benchmarks/sharded_memory.py)
        compares this against ``PackedLabels.nbytes``."""
        item = self.pack_dtype.itemsize
        r = self.n_landmarks
        return 2 * self.v_loc * r * item + 2 * r * r * item


def make_sharded_finalize(
    mesh: Mesh,
    *,
    n_vertices: int,
    v_loc: int,
    n_landmarks: int,
    axis_names: tuple[str, ...] | None = None,
):
    """Device program A of the born-sharded build: raw labelling state
    (depth, reach_L) -> int32 label blocks plus the replicated
    landmark-landmark readouts, all still on the mesh.

    Mirrors ``distributed_build_labelling``'s host re-assembly formulas
    exactly, per shard: ``label32 = where(reach & ~is_lm & real, depth,
    INF).T`` (pad rows forced INF), and the (R, R) ``at_land`` /
    ``l_at_land`` blocks read from each landmark's *owning* shard
    (owned-else-neutral + pmin/pmax, so the outputs are replicated).
    """
    axis_names = axis_names or tuple(mesh.axis_names)
    vloc = v_loc
    spec_e = P(axis_names)
    rep = P()

    def body(depth_sh, reach_sh, vstart_sh, nloc_sh, landmarks_j):
        depth = depth_sh[0]          # (R, vloc) int32
        reach = reach_sh[0]          # (R, vloc) bool
        vst = vstart_sh[0]
        n_loc = nloc_sh[0]
        local_ids = vst + jnp.arange(vloc, dtype=jnp.int32)
        real = jnp.arange(vloc, dtype=jnp.int32) < n_loc
        is_lm_loc = (local_ids[:, None] == landmarks_j[None, :]).any(axis=1)
        valid = reach & (~is_lm_loc & real)[None, :]
        label32 = jnp.where(valid, depth, INF).T       # (vloc, R)

        # landmark-landmark readout from the exact owner (each landmark is
        # claimed by exactly one shard, so owned-else-neutral + pmin/pmax
        # reconstructs depth_full[:, landmarks] bit-for-bit)
        lm_local = landmarks_j - vst
        own = (landmarks_j >= vst) & (landmarks_j < vst + n_loc)
        idx = jnp.clip(lm_local, 0, vloc - 1)
        at_land = jnp.where(own[None, :], depth[:, idx], INF)        # (R, R)
        at_land = jax.lax.pmin(at_land, axis_names)
        l_at_land = jnp.where(own[None, :], reach[:, idx], False)
        l_at_land = jax.lax.pmax(
            l_at_land.astype(jnp.int32), axis_names) > 0
        return label32[None], at_land, l_at_land

    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(spec_e, spec_e, spec_e, spec_e, rep),
            out_specs=(spec_e, rep, rep),
        )
    )


def make_sharded_lm_table(
    mesh: Mesh,
    *,
    n_vertices: int,
    v_loc: int,
    n_landmarks: int,
    axis_names: tuple[str, ...] | None = None,
):
    """Device program B: per-shard (R, v_loc) exact vertex-to-landmark
    distances from the local int32 label block + the replicated meta APSP
    — the vertex-sharded twin of ``qbs._dists_to_landmark_batch``,
    bit-identical on real rows (pad rows are INF).  Also emits the global
    max finite entry across label + lm tables (pmax-replicated scalar) so
    the host can run the same pack-dtype ladder as ``choose_pack_dtype``
    without ever gathering a table.
    """
    axis_names = axis_names or tuple(mesh.axis_names)
    vloc = v_loc
    spec_e = P(axis_names)
    rep = P()

    def body(label_sh, vstart_sh, nloc_sh, landmarks_j, meta_dist32):
        lab = label_sh[0]            # (vloc, R) int32
        vst = vstart_sh[0]
        n_loc = nloc_sh[0]
        # base[x, r] = min_i lab[x, i] + meta_dist[i, r]  (non-landmark rows)
        base = jnp.min(lab[:, :, None] + meta_dist32[None, :, :], axis=1)
        local_ids = vst + jnp.arange(vloc, dtype=jnp.int32)
        eqs = local_ids[:, None] == landmarks_j[None, :]
        is_lm = eqs.any(axis=1)
        lid_loc = jnp.argmax(eqs, axis=1)              # 0 where not landmark
        at_lm = meta_dist32[lid_loc]                   # (vloc, R); unused rows
        lm = jnp.minimum(jnp.where(is_lm[:, None], at_lm, base), INF)
        real = (jnp.arange(vloc, dtype=jnp.int32) < n_loc)[:, None]
        lm = jnp.where(real, lm, INF).astype(jnp.int32)
        mx = jnp.maximum(
            jnp.max(jnp.where(lab < INF, lab, -1)),
            jnp.max(jnp.where(lm < INF, lm, -1)),
        )
        mx = jax.lax.pmax(mx, axis_names)
        return lm.T[None], mx

    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(spec_e, spec_e, spec_e, rep, rep),
            out_specs=(spec_e, rep),
        )
    )


@partial(jax.jit, static_argnames=("sentinel", "dtype"))
def _pack_dist_device(a, *, sentinel: int, dtype: str):
    """Elementwise sentinel-encode on device; jitted so XLA carries the
    input's NamedSharding onto the output — the packed table is *born*
    sharded, never staged through host (``pack_dist`` is its host twin)."""
    return jnp.where(a >= INF, sentinel, a).astype(dtype)


def distributed_build_sharded(  # qbslint: host-boundary
    graph: Graph,
    landmarks: np.ndarray,
    mesh: Mesh,
    *,
    axis_names: tuple[str, ...] | None = None,
    frontier_mode: str = "bitmap",
    max_levels: int = 64,
) -> tuple[ShardedLabels, EdgePartition]:
    """Edge-sharded Algorithm 2 whose packed tables are *born*
    vertex-sharded: the labelling finishes on-device (finalize + lm-table
    shard_map programs) and only the (R, R) landmark-landmark block ever
    crosses to host — to run ``meta_apsp`` and the pack-dtype ladder.
    Exact: packs the same values ``distributed_build_labelling`` +
    ``pack_labelling`` would, per block (the bit-identity is pinned by
    tests/test_sharded_index.py).  Returns ``(ShardedLabels,
    EdgePartition)`` — the partition doubles as the serving CSR layout
    (``core.sharded.ShardedIndex``)."""
    axis_names = axis_names or tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    part = partition_edges(graph, n_shards)
    v = graph.n_vertices
    r = int(np.asarray(landmarks).shape[0])
    landmarks_j = jnp.asarray(landmarks, jnp.int32)
    vend = np.concatenate([part.vstart[1:], [v]])
    nloc = (vend - part.vstart).astype(np.int32)

    step = make_labelling_step(
        mesh, n_vertices=v, v_loc=part.v_loc, e_max=part.e_max,
        n_landmarks=r, axis_names=axis_names, frontier_mode=frontier_mode,
        max_levels=max_levels,
    )
    depth_sh, reach_sh = step(
        jnp.asarray(part.src), jnp.asarray(part.dst_local),
        jnp.asarray(part.vstart), landmarks_j,
    )

    finalize = make_sharded_finalize(
        mesh, n_vertices=v, v_loc=part.v_loc, n_landmarks=r,
        axis_names=axis_names,
    )
    vstart_j = jnp.asarray(part.vstart)
    nloc_j = jnp.asarray(nloc)
    label32_sh, at_land, l_at_land = finalize(
        depth_sh, reach_sh, vstart_j, nloc_j, landmarks_j)

    # Host boundary: the (R, R) landmark block is the one sanctioned
    # replicated readout (R^2 ints — bytes, not tables).
    at_np = np.asarray(at_land)
    l_np = np.asarray(l_at_land)
    meta_w_np = np.where(l_np, at_np, INF)
    np.fill_diagonal(meta_w_np, INF)
    meta_w_np = np.minimum(meta_w_np, meta_w_np.T).astype(np.int32)
    meta_dist32 = meta_apsp(jnp.asarray(meta_w_np))

    lm_step = make_sharded_lm_table(
        mesh, n_vertices=v, v_loc=part.v_loc, n_landmarks=r,
        axis_names=axis_names,
    )
    lm32_sh, mx = lm_step(label32_sh, vstart_j, nloc_j, landmarks_j,
                          meta_dist32)

    # Same dtype ladder as choose_pack_dtype, fed by the pmax scalar
    # instead of a gathered table.
    md_np = np.asarray(meta_dist32)
    dtype = choose_pack_dtype(
        np.asarray([max(int(mx), 0)]), meta_w_np, md_np)
    sent = sentinel_of(dtype)
    labels_sh = _pack_dist_device(
        label32_sh, sentinel=sent, dtype=np.dtype(dtype).name)
    lm_sh = _pack_dist_device(
        lm32_sh, sentinel=sent, dtype=np.dtype(dtype).name)
    return ShardedLabels(
        labels_sh=labels_sh,
        lm_sh=lm_sh,
        meta_w=pack_dist(meta_w_np, dtype),
        meta_dist=pack_dist(md_np, dtype),
        landmarks=landmarks_j,
        vstart=part.vstart,
        nloc=nloc,
        v_loc=part.v_loc,
        n_vertices=v,
    ), part


# ---------------------------------------------------------------------------
# Sharded batch serving
# ---------------------------------------------------------------------------


def make_serve_step(
    ctx: SearchContext,
    scheme: LabellingScheme,
    mesh: Mesh,
    *,
    n_vertices: int,
    axis_names: tuple[str, ...] | None = None,
    max_levels: int = 64,
    max_chain: int = 64,
    use_pallas: bool = False,
    packed: PackedLabels | None = None,
):
    """Return a jitted serve step: (us, vs) batch -> (edge_mask, dist),
    batch-sharded across the mesh, graph/labels replicated.  ``use_pallas``
    selects the sketch kernel like ``QbSIndex(use_pallas=...)`` does for
    the single-device pipeline (the serving service threads the index's
    setting through).  ``packed=`` replicates the index's packed label
    tables instead of the int32 scheme arrays (~4x fewer replicated label
    bytes per device; ``compute_sketch_batch`` widens in registers) — the
    two are bit-identical."""
    axis_names = axis_names or tuple(mesh.axis_names)
    searcher = partial(
        guided_search, n_vertices=n_vertices,
        max_levels=max_levels, max_chain=max_chain,
    )

    def step(ctx, label_dist, meta_w, meta_dist, us, vs):
        lu = label_dist[us]
        lv = label_dist[vs]
        sk = compute_sketch_batch(lu, lv, meta_w, meta_dist,
                                  use_pallas=use_pallas)
        queries = Query(
            u=us, v=vs, d_top=sk.d_top, du_land=sk.du_land, dv_land=sk.dv_land,
            meta_edge=sk.meta_edge, d_star_u=sk.d_star_u, d_star_v=sk.d_star_v,
        )
        res = jax.vmap(searcher, in_axes=(None, 0))(ctx, queries)
        return res.edge_mask, res.dist

    batch_spec = P(axis_names)
    rep = P()
    # per-leaf replication spec (ctx.engine is a nested pytree, so the spec
    # tree is built by tree_map rather than positional construction)
    ctx_specs = jax.tree_util.tree_map(lambda _: rep, ctx)
    step_sharded = shard_map(
        step,
        mesh=mesh,
        in_specs=(ctx_specs, rep, rep, rep, batch_spec, batch_spec),
        out_specs=(batch_spec, batch_spec),
    )
    fn = jax.jit(step_sharded)
    labels = scheme if packed is None else packed
    return partial(fn, ctx, labels.label_dist, labels.meta_w, labels.meta_dist)
