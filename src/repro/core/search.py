"""Algorithm 4 (guided searching): sketch-bounded bidirectional BFS on the
sparsified graph G- = G[V \\ R], then a reverse search (extract the SPG edges
avoiding landmarks) and a recover search (re-attach shortest paths through
landmarks from the labelling).

TPU adaptation notes (see DESIGN.md §2):

* Queues -> level-synchronous frontier masks; every step is an edge-parallel
  relay through the pluggable ``core.frontier`` engine (``segment_max`` by
  default, CSR-blocked or hybrid hub/tail via ``backend=``), so hub
  vertices never serialize a lane.
* The paper's recover search walks pointers from anchor set Z.  Here the
  labels act as *global* distance certificates, which turns most of the walk
  into a single pointwise test:  a vertex x lies on a landmark-free shortest
  u->r path iff  depth_u[x] + delta_xr == sigma_S(u, r)  (compose the G- BFS
  prefix with the label suffix).  Only the part of a path *beyond* the
  explored ball needs the paper's anchor chain, which we run as a masked
  OR-closure over label levels (``while_loop``, trip count <= diameter).
* Landmark-to-landmark segments (the paper's precomputed Delta) need no
  search at all: both endpoints of an edge carry label certificates, so
  Delta is one min-plus contraction over the sketch's meta edges.

Everything is fixed-shape and vmap-able over a query batch.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .frontier import FrontierEngine, make_relay
from .graph import INF, Graph
from .packing import PackedLabels, pack_dist, pack_labelling, widen_dist


class SearchContext(NamedTuple):
    """Per-graph constants shared by every query."""

    src: jax.Array          # (E,) int32
    dst: jax.Array          # (E,) int32
    gminus_e: jax.Array     # (E,) bool: both endpoints are non-landmarks
    is_landmark: jax.Array  # (V,) bool
    lid: jax.Array          # (V,) int32: vertex -> landmark index, -1 otherwise
    label_dist: jax.Array   # (V, R) packed uint8/uint16 (sentinel = INF)
    meta_w: jax.Array       # (R, R) packed direct meta edge weights
    engine: FrontierEngine  # G- relay (gminus_e baked in as the edge mask)


def make_search_context(
    graph: Graph,
    scheme=None,
    *,
    backend: str = "segment",
    engine: FrontierEngine | None = None,
    packed: PackedLabels | None = None,
    **engine_kw,
) -> SearchContext:
    """Build the per-graph search context (single construction point for the
    replicated-label path: ``QbSIndex``, the Bi-BFS baseline, the sharded
    serve step).  ``scheme=None`` means an empty landmark set, which is
    exactly the Bi-BFS degeneration.  ``engine`` overrides the built one
    (tests); otherwise the relay backend is chosen by ``backend=``.

    The label tables enter the context *packed* (``core.packing``): pass
    ``packed=`` to share the caller's ``PackedLabels`` buffers (as
    ``QbSIndex`` does, so HBM holds one packed copy for sketch + recover),
    otherwise the scheme is packed here.  ``widen_dist`` at the use sites
    restores exact int32/INF semantics inside the jit programs."""
    v, e = graph.n_vertices, graph.n_edges
    if scheme is None:
        gminus_e = jnp.ones((e,), bool)
        is_landmark = jnp.zeros((v,), bool)
        lid = jnp.full((v,), -1, jnp.int32)
        label_dist = pack_dist(np.full((v, 1), INF, np.int32), np.uint8)
        meta_w = pack_dist(np.full((1, 1), INF, np.int32), np.uint8)
    else:
        is_landmark = scheme.is_landmark
        gminus_e = (~is_landmark[graph.src]) & (~is_landmark[graph.dst])
        lid = scheme.lid
        if packed is None:
            packed = pack_labelling(scheme)
        label_dist = packed.label_dist
        meta_w = packed.meta_w
    if engine is None:
        engine = make_relay(graph, backend=backend, edge_mask=gminus_e,
                            **engine_kw)
    return SearchContext(
        src=graph.src, dst=graph.dst, gminus_e=gminus_e,
        is_landmark=is_landmark, lid=lid, label_dist=label_dist,
        meta_w=meta_w, engine=engine,
    )


class Query(NamedTuple):
    """One query + its sketch (leading axis = batch under vmap)."""

    u: jax.Array          # () int32
    v: jax.Array          # () int32
    d_top: jax.Array      # () int32
    du_land: jax.Array    # (R,) int32 sigma_S(u, r)
    dv_land: jax.Array    # (R,) int32 sigma_S(v, r')
    meta_edge: jax.Array  # (R, R) bool
    d_star_u: jax.Array   # () int32
    d_star_v: jax.Array   # () int32


class SearchResult(NamedTuple):
    edge_mask: jax.Array  # (E,) bool, path-direction orientation marks
    dist: jax.Array       # () int32, INF if disconnected
    d_minus: jax.Array    # () int32 d_{G-}(u, v), INF if balls never met
    d_u: jax.Array        # () int32 explored radius, u side
    d_v: jax.Array        # () int32 explored radius, v side


# ---------------------------------------------------------------------------
# Stage 1: sketch-bounded bidirectional BFS on G-  (Alg. 4 lines 1-15)
# ---------------------------------------------------------------------------

def bidirectional_bfs(ctx: SearchContext, q: Query, n_vertices: int, max_levels: int):
    V = n_vertices
    depth_u = jnp.full((V,), INF, jnp.int32).at[q.u].set(0)
    depth_v = jnp.full((V,), INF, jnp.int32).at[q.v].set(0)

    def cond(c):
        depth_u, depth_v, d_u, d_v, alive_u, alive_v, met = c
        more = (d_u + d_v < q.d_top) & (d_u + d_v < max_levels)
        return more & (~met) & (alive_u | alive_v)

    def body(c):
        depth_u, depth_v, d_u, d_v, alive_u, alive_v, met = c
        # pick_search: prefer the side whose sketch budget d* is unmet; on a
        # tie use the smaller explored ball (paper's |P_u| vs |P_v| rule).
        want_u = q.d_star_u > d_u
        want_v = q.d_star_v > d_v
        size_u = jnp.sum(depth_u < INF)
        size_v = jnp.sum(depth_v < INF)
        pick_u = jnp.where(
            want_u != want_v, want_u, size_u <= size_v
        )
        pick_u = jnp.where(alive_u & alive_v, pick_u, alive_u)

        def expand(depth, d):
            frontier = depth == d
            msg = ctx.engine.relay(frontier)
            new = msg & (depth == INF)
            return jnp.where(new, d + 1, depth), d + 1, new.any()

        du2, dcu, au2 = expand(depth_u, d_u)
        dv2, dcv, av2 = expand(depth_v, d_v)
        depth_u = jnp.where(pick_u, du2, depth_u)
        depth_v = jnp.where(pick_u, depth_v, dv2)
        d_u = jnp.where(pick_u, dcu, d_u)
        d_v = jnp.where(pick_u, d_v, dcv)
        alive_u = jnp.where(pick_u, au2, alive_u)
        alive_v = jnp.where(pick_u, alive_v, av2)
        met = jnp.any((depth_u < INF) & (depth_v < INF))
        return depth_u, depth_v, d_u, d_v, alive_u, alive_v, met

    # Carry scalars are derived from query data (not literals) so their
    # varying-manual-axes type matches the loop outputs under shard_map.
    true_ = q.u == q.u
    zero = q.u * 0
    init = (depth_u, depth_v, zero, zero, true_, true_, ~true_)
    return jax.lax.while_loop(cond, body, init)


# ---------------------------------------------------------------------------
# Stage 2: reverse search  (Alg. 4 lines 16-17)
# ---------------------------------------------------------------------------

def reverse_search(ctx: SearchContext, depth_u, depth_v, d_minus, n_vertices: int):
    """Extract the SPG edges of shortest u-v paths inside G-.

    Pointwise certification with *partial* balls only covers the two levels
    adjacent to the meeting cut, so we chain backward from the meeting set
    W = {x : depth_u[x] + depth_v[x] == d_minus} on each side.  Certified
    edges are oriented along the u->v path direction.

    The per-vertex chaining is one engine relay per level: a vertex x at
    depth l-1 joins the on-path set iff some on-path depth-l neighbour
    reaches it through G-.  For the u side the seed scattered the oriented
    certificates by *source*; on the symmetrized edge list (edge set and
    G- mask both symmetric) that equals the canonical dst-keyed relay, so
    both sides share one relay form.  The oriented per-edge certificate
    masks themselves stay explicit per-edge expressions (pure gathers).
    """
    common = (depth_u < INF) & (depth_v < INF)
    w_set = common & (depth_u + depth_v == d_minus)

    def sweep(depth, toward_u: bool):
        # walk from W back to the endpoint, level by level
        start_level = jnp.max(jnp.where(w_set, depth, 0))

        def cond(c):
            _, _, l = c
            return l >= 1

        def body(c):
            on, emask, l = c
            if toward_u:
                # certify (x -> y) with depth[x] == l-1, depth[y] == l, y on-path
                cert = (
                    ctx.gminus_e
                    & on[ctx.dst]
                    & (depth[ctx.dst] == l)
                    & (depth[ctx.src] == l - 1)
                )
            else:
                # certify (x -> y) with depth_v[x] == l, depth_v[y] == l-1
                cert = (
                    ctx.gminus_e
                    & on[ctx.src]
                    & (depth[ctx.src] == l)
                    & (depth[ctx.dst] == l - 1)
                )
            on = on | ((depth == l - 1) & ctx.engine.relay(on & (depth == l)))
            return on, emask | cert, l - 1

        on0 = w_set
        emask0 = w_set[ctx.src] & ~w_set[ctx.src]  # all-False, varying-typed
        _, emask, _ = jax.lax.while_loop(cond, body, (on0, emask0, start_level))
        return emask

    return sweep(depth_u, True) | sweep(depth_v, False)


# ---------------------------------------------------------------------------
# Stage 3: recover search  (Alg. 4 lines 18-24)
# ---------------------------------------------------------------------------

def _side_attach(ctx: SearchContext, depth, side_land, n_vertices: int, max_chain: int):
    """Component (i)/(ii): edges of landmark-free shortest t->r paths for
    every sketch edge (r, t), vectorized over all landmarks r at once.

    Returns (edge_mask, on) where on[x, r] certifies x on such a path.
    """
    ld = widen_dist(ctx.label_dist)
    lvalid = ld < INF
    sigma = side_land  # (R,)

    # Pointwise certificate: G- BFS prefix + label suffix == sigma.
    on = (
        lvalid
        & (depth[:, None] < INF)
        & (sigma[None, :] < INF)
        & (depth[:, None] + ld == sigma[None, :])
    )

    # Anchor-chain closure for path segments beyond the explored ball
    # (paper's Z-walk): extend along label-decrement edges in G-.
    def cond(c):
        _, changed, it = c
        return changed & (it < max_chain)

    def body(c):
        on, _, it = c
        # label-decrement coupling ties src and dst per landmark, so this is
        # a generic per-edge message, not a vertex-value relay
        msgs = (
            ctx.gminus_e[:, None]
            & on[ctx.src]
            & lvalid[ctx.dst]
            & (ld[ctx.dst] == ld[ctx.src] - 1)
        )
        grown = ctx.engine.scatter(msgs.T).T
        new_on = on | grown
        changed = jnp.any(new_on & ~on)
        return new_on, changed, it + 1

    t = jnp.any(on)
    on, _, _ = jax.lax.while_loop(cond, body, (on, t | ~t, t.astype(jnp.int32) * 0))

    # Interior edges: both endpoints certified, label distance decrements.
    interior = ctx.gminus_e & jnp.any(
        on[ctx.src] & on[ctx.dst] & (ld[ctx.dst] == ld[ctx.src] - 1), axis=1
    )

    # Final hops into the landmark (both orientations of the same edge).
    def hop(edge_end, other_end):
        r_idx = jnp.clip(ctx.lid[edge_end], 0, None)
        valid = ctx.is_landmark[edge_end]
        on_o = jnp.take_along_axis(on[other_end], r_idx[:, None], axis=1)[:, 0]
        ld_o = jnp.take_along_axis(ld[other_end], r_idx[:, None], axis=1)[:, 0]
        return valid & on_o & (ld_o == 1)

    hops = hop(ctx.dst, ctx.src) | hop(ctx.src, ctx.dst)
    return interior | hops, on


def _delta_edges(ctx: SearchContext, meta_edge, n_vertices: int):
    """Component (iii): edges on landmark-free shortest r_i - r_j paths for
    every meta edge in the sketch (the paper's precomputed Delta), derived
    from labels alone via a min-plus contraction.

    For a G- edge (x, y):  on path iff  exists (i,j) in sketch meta edges:
        ld[x,i] + 1 + ld[y,j] == w[i,j]
    By the triangle inequality ld[x,i] + ld[y,j] - w[i,j] >= -1, so the
    existential test is  min_{i,j} masked(ld[x,i] + ld[y,j] - w[i,j]) == -1.
    """
    ld = widen_dist(ctx.label_dist)
    w = widen_dist(ctx.meta_w)
    fin = (w < INF) & meta_edge

    # T[x, i] = min_j ( ld[x, j] + (-w[i, j] | INF) )
    m2 = jnp.where(fin, -w, INF).T.astype(jnp.int32)        # (j, i)
    t = jnp.min(ld[:, :, None] + m2[None, :, :], axis=1)    # (V, R_i)
    minval = jnp.min(ld[ctx.src] + t[ctx.dst], axis=1)      # (E,)
    interior = ctx.gminus_e & (minval == -1)

    # Boundary hops r_i -> y (y has ld[y, j] == w[i,j]-1) and x -> r_j.
    g1 = jnp.where(fin, w - 1, -1)          # (i, j) row-indexed by src landmark
    h1 = jnp.where(fin, w - 1, -1).T        # (j, i) row-indexed by dst landmark

    def hop(end_land, end_other, table):
        r_idx = jnp.clip(ctx.lid[end_land], 0, None)
        valid = ctx.is_landmark[end_land] & ~ctx.is_landmark[end_other]
        targets = table[r_idx]              # (E, R)
        match = jnp.any(ld[end_other] == targets, axis=1)
        return valid & match

    hops = hop(ctx.src, ctx.dst, g1) | hop(ctx.dst, ctx.src, h1)

    # Direct landmark-landmark sketch edges of weight 1.
    both = ctx.is_landmark[ctx.src] & ctx.is_landmark[ctx.dst]
    i_idx = jnp.clip(ctx.lid[ctx.src], 0, None)
    j_idx = jnp.clip(ctx.lid[ctx.dst], 0, None)
    direct = both & meta_edge[i_idx, j_idx] & (w[i_idx, j_idx] == 1)

    return interior | hops | direct


def recover_search(ctx: SearchContext, q: Query, depth_u, depth_v,
                   n_vertices: int, max_chain: int):
    e_u, _ = _side_attach(ctx, depth_u, q.du_land, n_vertices, max_chain)
    e_v, _ = _side_attach(ctx, depth_v, q.dv_land, n_vertices, max_chain)
    e_m = _delta_edges(ctx, q.meta_edge, n_vertices)
    return e_u | e_v | e_m


# ---------------------------------------------------------------------------
# Full guided search for one query
# ---------------------------------------------------------------------------

def guided_search(ctx: SearchContext, q: Query, n_vertices: int,
                  max_levels: int = 64, max_chain: int = 64) -> SearchResult:
    depth_u, depth_v, d_u, d_v, _, _, met = bidirectional_bfs(
        ctx, q, n_vertices, max_levels
    )

    common = (depth_u < INF) & (depth_v < INF)
    sums = jnp.where(common, depth_u + depth_v, INF)
    d_minus = jnp.min(sums)

    dist = jnp.minimum(d_minus, q.d_top)
    reverse_on = met & (d_minus <= q.d_top)
    recover_on = (q.d_top < INF) & (q.d_top <= d_minus)

    e_rev = reverse_search(ctx, depth_u, depth_v, d_minus, n_vertices)
    e_rec = recover_search(ctx, q, depth_u, depth_v, n_vertices, max_chain)

    trivial = q.u == q.v
    edge_mask = ((e_rev & reverse_on) | (e_rec & recover_on)) & ~trivial
    dist = jnp.where(trivial, 0, dist)
    return SearchResult(edge_mask=edge_mask, dist=dist.astype(jnp.int32),
                        d_minus=d_minus.astype(jnp.int32), d_u=d_u, d_v=d_v)
