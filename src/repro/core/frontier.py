"""Pluggable frontier engine: the one level-synchronous BFS relay shared by
every phase of QbS (DESIGN.md §3).

Every phase of the system — offline labelling (Algorithm 2), the online
sketch-bounded bidirectional search, the reverse/recover sweeps
(Algorithm 4), and the Bi-BFS / full-BFS baselines — is the same
operation: propagate per-edge boolean messages into their destination
vertices,

    next[k, w] = OR_{e : dst[e] = w}  values[k, src[e]] & mask[e]

This module owns that operation behind pluggable backends:

* ``segment``  — the edge-list ``jax.ops.segment_max`` push relay (the seed
                 formulation; default, bit-identical reference).
* ``csr``      — pull formulation over the CSR (src-sorted) edge layout:
                 ``next[w] = OR_{e in row w} values[dst[e]]``, valid because
                 the graph and any baked edge mask are symmetric.  The
                 segment ids are the *sorted* ``src`` array, so the
                 reduction runs over contiguous segments; an optional
                 ``block_size`` processes the edge list in fixed-size blocks
                 to bound the (K, E) message temporary.
* ``hybrid``   — degree-split hub/tail relay: the dense hub-hub block (where
                 traversal work concentrates on complex networks, §6.5 of
                 the paper) runs as an OR-AND matmul — the MXU-native
                 ``kernels.frontier.bitmap_expand`` on TPU, the same math as
                 a jnp f32 matmul elsewhere — while the sparse tail keeps
                 the ``segment_max`` relay over a *compacted* tail edge
                 list.  Results are OR-ed.  Bit-identical to ``segment`` for
                 symmetric graphs.

Edge masks that are static per index (the G- mask ``gminus_e``) are baked
in at build time: ``hybrid`` folds them into the dense block and the tail
compaction, so the per-level relay carries no mask traffic at all.

The engine is a registered pytree (arrays are leaves; backend/shape config
is static aux data), so it passes through ``jit`` / ``vmap(in_axes=None)``
/ ``shard_map`` like any other per-graph constant, and jit caches key on
the static config.

``segment_or`` is the raw primitive; the edge-sharded shard_map programs in
``core.distributed`` / ``core.scale_serve`` call it directly on their local
edge shards so the relay semantics live in exactly one module.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import INF, Graph
from .packing import pack_bits, unpack_bits

BACKENDS = ("segment", "csr", "hybrid")


def segment_or(
    messages: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    acc_dtype=jnp.int32,
) -> jax.Array:
    """OR-reduce per-edge boolean messages ``(K, E)`` into ``(K, N)``.

    The canonical frontier-relay reduction: booleans accumulate through an
    integer ``segment_max`` (order-invariant, hence safe to reorder, shard
    and block).  ``acc_dtype`` only changes the accumulator width (the
    shard_map programs use int8 to shrink on-device temporaries); the
    boolean result is identical for any width.
    """
    acc = jax.ops.segment_max(
        messages.astype(acc_dtype).T, segment_ids, num_segments=num_segments
    )
    return (acc > 0).T


def _dense_or_matmul(frontier: jax.Array, adjacency: jax.Array) -> jax.Array:
    """next[k, j] = OR_i frontier[k, i] & adjacency[i, j] via an f32 matmul
    (the same OR-AND-semiring-on-MXU math as ``bitmap_expand``)."""
    acc = jnp.dot(
        frontier.astype(jnp.float32),
        adjacency.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return acc > 0.5


@jax.tree_util.register_pytree_node_class
class FrontierEngine:
    """Per-graph relay engine.  Arrays are pytree leaves; everything else is
    static aux data (part of the jit cache key)."""

    def __init__(
        self,
        arrays: dict[str, Any],
        *,
        backend: str,
        n_vertices: int,
        n_edges: int,
        block_size: int = 0,
        use_pallas: bool = False,
        interpret: bool = True,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
        self.arrays = arrays
        self.backend = backend
        self.n_vertices = n_vertices
        self.n_edges = n_edges
        self.block_size = block_size
        self.use_pallas = use_pallas
        self.interpret = interpret

    # -- pytree protocol -----------------------------------------------------

    def tree_flatten(self):
        keys = tuple(sorted(self.arrays))
        children = tuple(self.arrays[k] for k in keys)
        aux = (keys, self.backend, self.n_vertices, self.n_edges,
               self.block_size, self.use_pallas, self.interpret)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, backend, n_v, n_e, block, pallas, interp = aux
        return cls(dict(zip(keys, children)), backend=backend, n_vertices=n_v,
                   n_edges=n_e, block_size=block, use_pallas=pallas,
                   interpret=interp)

    # -- the one operation ---------------------------------------------------

    def relay(self, values: jax.Array) -> jax.Array:
        """Frontier relay: ``(K, V) -> (K, V)`` (or ``(V,) -> (V,)``) with
        the build-time edge mask applied.  next[k, w] = OR over unmasked
        edges (x, w) of values[k, x]."""
        squeeze = values.ndim == 1
        f = values[None] if squeeze else values
        if self.backend == "segment":
            out = self._relay_segment(f)
        elif self.backend == "csr":
            out = self._relay_csr(f)
        else:  # constructor validated membership in BACKENDS
            out = self._relay_hybrid(f)
        return out[0] if squeeze else out

    def scatter(self, messages: jax.Array) -> jax.Array:
        """Generic per-edge OR-scatter ``(K, E) -> (K, V)`` keyed by ``dst``
        (edge ids index the *original* edge list).  Messages that cannot be
        factored into per-vertex values (the recover chain's label-decrement
        coupling) relay through here; it is ``segment``-based on every
        backend because a dense block cannot represent arbitrary per-edge
        messages."""
        squeeze = messages.ndim == 1
        m = messages[None] if squeeze else messages
        out = segment_or(m, self.arrays["dst"], self.n_vertices)
        return out[0] if squeeze else out

    # -- backends ------------------------------------------------------------

    def _relay_segment(self, f: jax.Array) -> jax.Array:
        msgs = f[:, self.arrays["src"]]
        mask = self.arrays.get("mask")
        if mask is not None:
            msgs = msgs & mask
        return segment_or(msgs, self.arrays["dst"], self.n_vertices)

    def _relay_csr(self, f: jax.Array) -> jax.Array:
        # Pull over the src-sorted (CSR-row) layout: by edge-set and mask
        # symmetry, OR over out-neighbours == OR over in-neighbours.
        gather = self.arrays["csr_gather"]   # dst column, padded to blocks
        key = self.arrays["csr_key"]         # sorted src, pad rows -> V
        mask = self.arrays.get("csr_mask")
        v = self.n_vertices
        if not self.block_size:
            msgs = f[:, gather]
            if mask is not None:
                msgs = msgs & mask
            return segment_or(msgs, key, v + 1)[:, :v]

        b = self.block_size
        nb = gather.shape[0] // b
        k = f.shape[0]

        def body(i, acc):
            sl = functools.partial(jax.lax.dynamic_slice_in_dim,
                                   start_index=i * b, slice_size=b)
            msgs = f[:, sl(gather)]
            if mask is not None:
                msgs = msgs & sl(mask)
            blk = segment_or(msgs, sl(key), v + 1)
            return acc | blk

        acc0 = jnp.zeros((k, v + 1), bool)
        return jax.lax.fori_loop(0, nb, body, acc0)[:, :v]

    def _relay_hybrid(self, f: jax.Array) -> jax.Array:
        hub_ids = self.arrays["hub_ids"]
        # hub-hub reachability rows live bit-packed in HBM (32 columns per
        # uint32 word, core.packing layout); the Pallas kernel unpacks word
        # tiles in VMEM and the matmul fallback unpacks inside this program
        # — the dense (H, H) mask never persists in HBM
        adj_words = self.arrays["adj_hh_words"]
        h = hub_ids.shape[0]
        tail_src = self.arrays.get("tail_src")
        if tail_src is not None:
            out = segment_or(f[:, tail_src], self.arrays["tail_dst"],
                             self.n_vertices)
        else:
            out = jnp.zeros((f.shape[0], self.n_vertices), bool)
        f_h = f[:, hub_ids]
        if self.use_pallas:
            from ..kernels.frontier import bitmap_expand_packed
            next_h = bitmap_expand_packed(f_h, adj_words, n_cols=h,
                                          interpret=self.interpret)
        else:
            next_h = _dense_or_matmul(f_h, unpack_bits(adj_words, h))
        return out.at[:, hub_ids].set(out[:, hub_ids] | next_h)


@functools.partial(jax.jit, static_argnames=("max_levels",))
def bfs_depths(engine: FrontierEngine, root: jax.Array, max_levels: int,
               bound: jax.Array | None = None) -> jax.Array:
    """Level-synchronous single-source BFS over the engine's graph:
    ``(V,)`` int32 depths, ``INF`` = unreached.  ``bound`` (traced)
    optionally truncates the expansion at that depth — the landmark-endpoint
    serving path explores only the ball certificates need.  The one BFS
    driver shared by the oracle/baseline BFSs and the serving fallbacks."""
    depth0 = jnp.full((engine.n_vertices,), INF, jnp.int32).at[root].set(0)

    def cond(c):
        _, level, alive = c
        more = alive & (level < max_levels)
        if bound is not None:
            more = more & (level < bound)
        return more

    def body(c):
        depth, level, _ = c
        msg = engine.relay(depth == level)
        new = msg & (depth == INF)
        return jnp.where(new, level + 1, depth), level + 1, new.any()

    depth, _, _ = jax.lax.while_loop(
        cond, body, (depth0, jnp.int32(0), jnp.bool_(True)))
    return depth


@functools.partial(jax.jit, static_argnames=("max_levels",))
def bfs_depths_batch(engine: FrontierEngine, roots: jax.Array, max_levels: int,
                     bounds: jax.Array | None = None) -> jax.Array:
    """Batched level-synchronous BFS: ``(B,)`` roots -> ``(B, V)`` int32
    depths, ``INF`` = unreached.  One engine relay per level serves every
    row at once (the relay is row-independent for all backends), so a lane
    of B sources costs the same number of device programs as one.

    ``bounds`` (traced ``(B,)``) truncates each row independently at its own
    depth, exactly like ``bfs_depths``'s scalar ``bound``: row k expands
    only while ``level < bounds[k]``.  Rows are bit-identical to running
    ``bfs_depths`` per root with the matching bound — the batched form of
    the landmark-endpoint serving lane (see ``serving.planner``)."""
    b = roots.shape[0]
    depth0 = jnp.full((b, engine.n_vertices), INF, jnp.int32)
    depth0 = depth0.at[jnp.arange(b), roots].set(0)

    def active_rows(level, alive):
        act = alive & (level < max_levels)
        if bounds is not None:
            act = act & (level < bounds)
        return act

    def cond(c):
        _, level, alive = c
        return active_rows(level, alive).any()

    def body(c):
        depth, level, alive = c
        act = active_rows(level, alive)
        frontier = (depth == level) & act[:, None]
        msg = engine.relay(frontier)
        new = msg & (depth == INF)
        alive = jnp.where(act, new.any(axis=1), alive)
        return jnp.where(new, level + 1, depth), level + 1, alive

    depth, _, _ = jax.lax.while_loop(
        cond, body, (depth0, jnp.int32(0), jnp.ones((b,), bool)))
    return depth


class HubSplit(NamedTuple):
    """Host-side degree split (see ``Graph.hub_split``)."""

    hub_ids: np.ndarray    # (H,) int32, ascending vertex ids
    is_hub: np.ndarray     # (V,) bool
    hub_pos: np.ndarray    # (V,) int64 vertex -> hub-block row, -1 otherwise
    adj_hh: np.ndarray     # (H, H) bool dense hub-hub adjacency
    hub_edge: np.ndarray   # (E,) bool: both endpoints are hubs (excl. loops)


def hub_split(graph: Graph, n_hubs: int | None = None) -> HubSplit:
    """Split vertices by degree: the top-``n_hubs`` vertices (self-loop edge
    padding excluded from the degree count) become the dense hub block."""
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    v = graph.n_vertices
    real = src != dst
    deg = np.zeros((v,), np.int64)
    np.add.at(deg, src[real], 1)
    h = min(v, 128 if n_hubs is None else n_hubs)
    h = max(h, 1)
    order = np.argsort(-deg, kind="stable")
    hub_ids = np.sort(order[:h]).astype(np.int32)
    is_hub = np.zeros((v,), bool)
    is_hub[hub_ids] = True
    hub_pos = np.full((v,), -1, np.int64)
    hub_pos[hub_ids] = np.arange(h)
    hub_edge = real & is_hub[src] & is_hub[dst]
    adj = np.zeros((h, h), bool)
    adj[hub_pos[src[hub_edge]], hub_pos[dst[hub_edge]]] = True
    return HubSplit(hub_ids, is_hub, hub_pos, adj, hub_edge)


def make_relay(
    graph: Graph,
    *,
    backend: str = "segment",
    edge_mask: np.ndarray | jax.Array | None = None,
    n_hubs: int | None = None,
    block_size: int = 0,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> FrontierEngine:
    """Build a ``FrontierEngine`` for ``graph``.

    ``edge_mask`` is a *static* per-edge boolean (the G- mask); it must be
    symmetric (``mask[e] == mask[rev(e)]``), which holds for any mask of the
    form ``f[src] & f[dst]`` on the symmetrized edge list.  ``csr`` and
    ``hybrid`` additionally require the edge set itself to be symmetric,
    which ``graph.from_edges`` guarantees.  Build is host-side (numpy).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
    v, e = graph.n_vertices, graph.n_edges
    src_np = np.asarray(graph.src)
    dst_np = np.asarray(graph.dst)
    mask_np = None if edge_mask is None else np.asarray(edge_mask).astype(bool)

    arrays: dict[str, Any] = {"src": graph.src, "dst": graph.dst}

    if backend == "segment":
        if mask_np is not None:
            arrays["mask"] = jnp.asarray(mask_np)
        return FrontierEngine(arrays, backend=backend, n_vertices=v, n_edges=e)

    if backend == "csr":
        gather = dst_np
        key = src_np
        m = mask_np
        if block_size:
            pad = (-e) % block_size
            if pad:
                gather = np.concatenate([gather, np.zeros((pad,), np.int32)])
                key = np.concatenate([key, np.full((pad,), v, np.int32)])
                if m is not None:
                    m = np.concatenate([m, np.zeros((pad,), bool)])
        arrays["csr_gather"] = jnp.asarray(gather)
        arrays["csr_key"] = jnp.asarray(key)
        if m is not None:
            arrays["csr_mask"] = jnp.asarray(m)
        return FrontierEngine(arrays, backend=backend, n_vertices=v,
                              n_edges=e, block_size=block_size)

    # hybrid: degree split, dense hub block (mask baked in), compacted tail
    split = hub_split(graph, n_hubs)
    adj = split.adj_hh.copy()
    keep_tail = ~split.hub_edge
    if mask_np is not None:
        dead = split.hub_edge & ~mask_np
        adj[split.hub_pos[src_np[dead]], split.hub_pos[dst_np[dead]]] = False
        keep_tail = keep_tail & mask_np
    arrays["hub_ids"] = jnp.asarray(split.hub_ids)
    # store the hub-hub block bit-packed end-to-end (uint32 words); both
    # relay paths unpack on the fly (_relay_hybrid)
    arrays["adj_hh_words"] = pack_bits(jnp.asarray(adj))
    if keep_tail.any():
        arrays["tail_src"] = jnp.asarray(src_np[keep_tail])
        arrays["tail_dst"] = jnp.asarray(dst_np[keep_tail])
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return FrontierEngine(arrays, backend=backend, n_vertices=v, n_edges=e,
                          use_pallas=bool(use_pallas), interpret=bool(interpret))


def abstract_engine(n_vertices: int, n_edges: int, *,
                    masked: bool = False) -> FrontierEngine:
    """ShapeDtypeStruct-only ``segment`` engine for ``.lower()`` dry-runs at
    paper scale (no allocation; see ``launch.dryrun``)."""
    i32 = jnp.int32
    arrays: dict[str, Any] = {
        "src": jax.ShapeDtypeStruct((n_edges,), i32),
        "dst": jax.ShapeDtypeStruct((n_edges,), i32),
    }
    if masked:
        arrays["mask"] = jax.ShapeDtypeStruct((n_edges,), jnp.bool_)
    return FrontierEngine(arrays, backend="segment", n_vertices=n_vertices,
                          n_edges=n_edges)
