"""Query-by-Sketch core: the paper's contribution as composable JAX modules."""
from .graph import (
    INF,
    Graph,
    barabasi_albert_graph,
    from_edges,
    gnp_random_graph,
    grid_graph,
    random_regular_graph,
    largest_connected_component,
    ring_of_cliques,
    select_landmarks,
    to_networkx,
)
from .frontier import FrontierEngine, HubSplit, make_relay, segment_or
from .labelling import LabellingScheme, build_labelling, labelling_size_bytes, meta_apsp
from .packing import (
    PackedLabels,
    pack_bits,
    pack_labelling,
    packed_size_bytes,
    unpack_bits,
    widen_dist,
)
from .distributed import ShardedLabels, distributed_build_sharded
from .qbs import QbSIndex, SPGResult
from .search import Query, SearchContext, SearchResult, guided_search, make_search_context
from .sharded import ShardedIndex
from .sketch import SketchBatch, compute_sketch_batch, d_top_only

__all__ = [
    "INF",
    "Graph",
    "barabasi_albert_graph",
    "from_edges",
    "gnp_random_graph",
    "grid_graph",
    "random_regular_graph",
    "largest_connected_component",
    "ring_of_cliques",
    "select_landmarks",
    "to_networkx",
    "FrontierEngine",
    "HubSplit",
    "make_relay",
    "segment_or",
    "make_search_context",
    "LabellingScheme",
    "build_labelling",
    "labelling_size_bytes",
    "meta_apsp",
    "PackedLabels",
    "pack_bits",
    "pack_labelling",
    "packed_size_bytes",
    "unpack_bits",
    "widen_dist",
    "QbSIndex",
    "ShardedIndex",
    "ShardedLabels",
    "distributed_build_sharded",
    "SPGResult",
    "Query",
    "SearchContext",
    "SearchResult",
    "guided_search",
    "SketchBatch",
    "compute_sketch_batch",
    "d_top_only",
]
