"""Bit-packed memory layouts (DESIGN.md §10).

QbS's premise is that the precomputed label table is small enough that
online queries are memory-bandwidth-cheap.  This module owns the packed
representations that make that true in HBM, not just on paper:

* **Packed distance tables** (``PackedLabels``): the ``(V, R)`` label
  table plus the meta-graph arrays and the serving-lane ``(R, V)``
  landmark-distance table stored as ``uint8`` (escape hatch to ``uint16``
  chosen at build time from the measured diameter).  ``INF`` is encoded as
  the dtype max — a *sentinel*, because the true ``INF = 1 << 20`` cannot
  fit a narrow lane.  ``widen_dist`` restores exact int32 semantics
  (sentinel -> ``INF``) and is the one sanctioned widening point: it runs
  *inside* jit programs, so the int32 view lives in registers/VMEM of the
  consuming computation and the packed array is what HBM holds (rule
  QBS007 enforces the host-side half of this contract).
* **Bit-packed reachability words** (``pack_bits`` / ``unpack_bits``):
  ``(..., N)`` bool <-> ``(..., ceil(N/32))`` uint32, 32 little-endian
  columns per word — the layout shared by the distributed labelling
  exchange and the hybrid frontier's hub-hub adjacency block
  (``kernels.frontier.bitmap_expand_packed`` unpacks word tiles on the
  fly inside the kernel).

Packing is exact, never lossy: every stored distance is either finite and
below the sentinel (enforced at pack time) or exactly ``INF`` (labelling
clamps there), so ``widen_dist(pack_dist(x)) == x`` bit-for-bit and every
packed pipeline stays bit-identical to the unpacked oracle.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import INF

# Escape-hatch ladder: narrowest first; the dtype max is the INF sentinel.
_PACK_DTYPES = (np.uint8, np.uint16)


def sentinel_of(dtype) -> int:
    """The INF sentinel of a packed dtype: its maximum value."""
    return int(np.iinfo(np.dtype(dtype)).max)


def choose_pack_dtype(*arrays) -> np.dtype:
    """Pick the narrowest packed dtype for a set of distance arrays.

    The max *finite* (< INF) value across the arrays is the measured
    diameter bound; the escape hatch to uint16 triggers exactly when that
    bound would collide with the uint8 sentinel (255).  ``None`` entries
    are skipped so callers can pass optional tables.
    """
    m = 0
    for a in arrays:
        if a is None:
            continue
        a = np.asarray(a)
        finite = a[a < INF]
        if finite.size:
            m = max(m, int(finite.max()))
    for dtype in _PACK_DTYPES:
        if m < sentinel_of(dtype):
            return np.dtype(dtype)
    raise ValueError(
        f"max finite distance {m} collides with the uint16 sentinel "
        f"{sentinel_of(np.uint16)}; no packed layout fits")


def pad_width(n: int) -> int:
    """Smallest ladder width >= ``n`` from {1, 2, 3, 4, 6, 8, 12, 16, ...}
    (powers of two plus their 1.5x midpoints — two shapes per octave).
    The incremental-update path pads its affected-landmark sets to these
    widths so the jit/eager compile caches see a log-bounded family of
    shapes instead of one entry per distinct ``|affected|``."""
    if n <= 1:
        return 1
    p = 1 << (n - 1).bit_length()
    mid = p // 4 * 3
    return mid if n <= mid else p


def pack_dist(a, dtype) -> jax.Array:
    """Pack an int32 distance array (INF = no entry) into ``dtype`` with
    the dtype-max sentinel standing in for INF.  Host-side, build-time
    only; raises if any finite value would collide with the sentinel
    (``choose_pack_dtype`` guarantees it doesn't)."""
    a = np.asarray(a)
    sent = sentinel_of(dtype)
    bad = (a >= sent) & (a < INF)
    if bad.any():
        raise ValueError(
            f"finite distance {int(a[bad].max())} >= sentinel {sent}; "
            f"promote the pack dtype")
    return jnp.asarray(np.where(a >= INF, sent, a).astype(dtype))


def widen_dist(a: jax.Array) -> jax.Array:
    """Widen a (possibly packed) distance array to int32 with INF restored.

    Dual-mode: signed inputs pass through as int32 (the unpacked oracle
    path), unsigned inputs are sentinel-decoded.  This is the *only*
    sanctioned widening of packed tables and it belongs inside jit
    programs — the int32 view materializes in the consuming computation,
    never as a persistent HBM array (QBS007 guards host code).
    """
    if not jnp.issubdtype(a.dtype, jnp.unsignedinteger):
        return a.astype(jnp.int32)
    sent = jnp.iinfo(a.dtype).max          # static: derived from the dtype
    a32 = a.astype(jnp.int32)
    return jnp.where(a32 == sent, INF, a32)


class PackedLabels(NamedTuple):
    """The labelling's distance tables in packed HBM layout (all the same
    dtype, chosen once at build by ``choose_pack_dtype``).  A pytree:
    rides into jit programs as-is; consumers gather narrow rows and widen
    with ``widen_dist`` in registers."""

    label_dist: jax.Array        # (V, R) uint8/uint16, sentinel = INF
    meta_w: jax.Array            # (R, R) direct meta edge weights
    meta_dist: jax.Array         # (R, R) meta-graph APSP
    lm_dist: jax.Array | None = None   # (R, V) vertex-to-landmark (serving lanes)

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(self.label_dist.dtype)

    @property
    def sentinel(self) -> int:
        return sentinel_of(self.label_dist.dtype)

    @property
    def nbytes(self) -> int:
        return sum(int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
                   for a in self if a is not None)


def pack_labelling(scheme, lm_dist=None, *, dtype=None) -> PackedLabels:
    """Pack a ``LabellingScheme`` (and optionally the serving-lane
    ``(R, V)`` landmark-distance table) into one ``PackedLabels``.  The
    dtype is chosen from the measured max finite distance across *all*
    tables so one sentinel convention covers the whole index."""
    if dtype is None:
        dtype = choose_pack_dtype(
            scheme.label_dist, scheme.meta_w, scheme.meta_dist, lm_dist)
    return PackedLabels(
        label_dist=pack_dist(scheme.label_dist, dtype),
        meta_w=pack_dist(scheme.meta_w, dtype),
        meta_dist=pack_dist(scheme.meta_dist, dtype),
        lm_dist=None if lm_dist is None else pack_dist(lm_dist, dtype),
    )


def patch_packed(
    old: PackedLabels,
    scheme,
    lm_dist,
    affected: np.ndarray,
) -> PackedLabels:
    """Patch a ``PackedLabels`` after an incremental labelling update.

    ``affected`` holds the landmark indices whose rows/columns changed
    (``update_labelling``'s ``info["affected"]``).  The dtype is re-derived
    from the *new* tables so the result is bit-identical to a fresh
    ``pack_labelling`` — including the uint8 -> uint16 escape hatch, which
    forces a full repack when the measured diameter crosses the sentinel
    (and the narrowing back when it recedes).  Otherwise only the affected
    label columns and lm_dist rows are scattered; the (R, R) meta tables
    are tiny and repacked whole.

    Hot-path discipline: the dtype probe and the label-column gather/pack/
    scatter all run on device (one scalar sync), never round-tripping the
    (V, R) table through the host, and the scatter width is padded to the
    ``pad_width`` ladder (duplicated indices rewrite identical values) so
    the compile caches stay log-bounded across epochs.
    """
    m = jnp.asarray(0, jnp.int32)
    for a in (scheme.label_dist, scheme.meta_w, scheme.meta_dist, lm_dist):
        a = jnp.asarray(a)
        m = jnp.maximum(m, jnp.where(a < INF, a, 0).max().astype(jnp.int32))
    m = int(m)
    for dtype in _PACK_DTYPES:
        if m < sentinel_of(dtype):
            dtype = np.dtype(dtype)
            break
    else:
        raise ValueError(
            f"max finite distance {m} collides with the uint16 sentinel "
            f"{sentinel_of(np.uint16)}; no packed layout fits")
    if dtype != old.dtype or old.lm_dist is None:
        return pack_labelling(scheme, lm_dist=lm_dist, dtype=dtype)
    aff = np.asarray(affected, np.int32)
    k_pad = pad_width(int(aff.size))
    aff = np.concatenate([aff, np.full((k_pad - aff.size,), aff[0], np.int32)])
    idx = jnp.asarray(aff)
    sent = sentinel_of(dtype)
    cols = jnp.asarray(scheme.label_dist)[:, idx]
    cols = jnp.where(cols >= INF, sent, cols).astype(dtype)
    rows = jnp.asarray(np.asarray(lm_dist)[aff, :])
    rows = jnp.where(rows >= INF, sent, rows).astype(dtype)
    return PackedLabels(
        label_dist=old.label_dist.at[:, idx].set(cols),
        meta_w=pack_dist(scheme.meta_w, dtype),
        meta_dist=pack_dist(scheme.meta_dist, dtype),
        lm_dist=old.lm_dist.at[idx, :].set(rows),
    )


def packed_size_bytes(packed: PackedLabels) -> dict:
    """Byte accounting for the compression win: packed vs the int32
    baseline layout of the same tables (``benchmarks/label_size.py``
    commits the ratio to BENCH.json)."""
    n_elems = sum(int(np.prod(a.shape)) for a in packed if a is not None)
    return {
        "packed_bytes": packed.nbytes,
        "int32_bytes": n_elems * 4,
        "dtype": str(packed.dtype),
        "ratio": (n_elems * 4) / max(packed.nbytes, 1),
    }


# ---------------------------------------------------------------------------
# Bit-packed boolean words (shared by distributed exchange + hybrid frontier)
# ---------------------------------------------------------------------------


def pack_bits(x: jax.Array) -> jax.Array:
    """(..., N) bool -> (..., ceil(N/32)) uint32, 32 little-endian columns
    per word (bit ``i`` of word ``w`` is column ``32 * w + i``)."""
    n = x.shape[-1]
    pad = (-n) % 32
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    x = x.reshape(*x.shape[:-1], -1, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (x * weights).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(x: jax.Array, n: int) -> jax.Array:
    """(..., W) uint32 -> (..., n) bool (inverse of ``pack_bits``)."""
    bits = (x[..., :, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    out = bits.reshape(*x.shape[:-1], -1)
    return out[..., :n].astype(bool)
