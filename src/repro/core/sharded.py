"""Vertex-sharded QbS index: every serving lane answered from the
born-sharded tables (DESIGN.md §11).

``distributed_build_sharded`` leaves the packed label table, the (R, V)
landmark-distance table and the CSR edge partition resident one vertex
block per device (``jax.sharding.NamedSharding``); ``ShardedIndex`` is
the ``QbSIndex``-shaped facade that serves from them without ever
materializing a full table:

* **General lane** (``make_sharded_general_step``): the vertex-sharded
  twin of ``core.scale_serve`` fed by *packed* labels.  Sketch rows for
  (u, v) come from the owning shard (owned-else-INF + ``pmin``); the
  sketch itself is a replicated O(B R^2) compute; the sketch-bounded
  Bi-BFS / reverse sweeps / recover chains run the ``segment_or`` relay
  on each device's local dst-owned edges with one packed-bitmap
  ``all_gather`` frontier exchange per level (the halo exchange — words
  stay packed across the wire).  Edge-source label columns are read from
  a *transient* in-program gather of the packed table, so the resident
  footprint stays one block per device (no edge-aligned label copies).
* **Landmark lanes** (``make_sharded_landmark_pair_step`` /
  ``make_sharded_onesided_step``): gather exactly the ``B`` packed rows
  of the landmark-distance table each chunk needs (one row per query
  side — never the table), then certify per local edge; the one-sided
  lane adds the same distance-bounded BFS as the replicated lane,
  sharded level-synchronously like the general lane.

Every lane ends in the same **scatter-symmetrize**: each shard writes
its locally-certified edges into the canonical ``(B, n_edges)`` mask at
their global slot *and* its reverse slot (``EdgePartition.eid`` + the
host-built reverse map), then one ``psum`` replicates the union.  Each
directed edge is dst-owned by exactly one shard, so this equals the
replicated path's ``mask | mask[:, rev_edge]`` bit-for-bit — pinned by
tests/test_sharded_index.py against the replicated oracle on emulated
8-device meshes.

Exactness caveat (same as ``core.scale_serve``): ``max_levels`` /
``max_chain`` must exceed the graph's diameter / longest recover chain;
the defaults suit the test graphs, paper-scale runs size them from the
measured diameter.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from .distributed import (
    EdgePartition,
    ShardedLabels,
    _pack_bits,
    distributed_build_sharded,
)
from .frontier import segment_or
from .graph import INF, Graph, select_landmarks
from .packing import widen_dist
from .qbs import SPGResult, _reverse_edge_map
from .sketch import compute_sketch_batch


def _scatter_symmetrize(cert, eid_l, rev_l, n_edges, axis_names):
    """Per-shard certified local edges -> replicated symmetrized global
    mask.  ``cert`` is (B, E_loc) bool over this shard's dst-owned edge
    slots; each True scatters into its global slot *and* the reverse
    slot (pad slots target the dropped column ``n_edges``).  Because a
    directed edge is owned by exactly one shard, the int8 ``psum`` union
    (contribution <= 2 per shard: safe to 63 shards) reproduces the
    replicated ``mask | mask[:, rev_edge]`` exactly."""
    b = cert.shape[0]
    m8 = cert.astype(jnp.int8)
    acc = jnp.zeros((b, n_edges + 1), jnp.int8)
    acc = acc.at[:, eid_l].max(m8).at[:, rev_l].max(m8)
    acc = jax.lax.psum(acc, axis_names)
    return acc[:, :n_edges] > 0


def make_sharded_general_step(
    mesh: Mesh,
    *,
    n_vertices: int,
    v_loc: int,
    e_max: int,
    n_edges: int,
    n_landmarks: int,
    axis_names: tuple[str, ...] | None = None,
    max_levels: int = 32,
    max_chain: int = 8,
):
    """General lane from vertex-sharded packed tables.  The phase
    structure mirrors ``core.scale_serve`` (A label rows, B sketch,
    C bounded Bi-BFS, D reverse sweeps, E recover) — see that module for
    the certificate derivations; the differences here are packed-label
    widening (``widen_dist`` in-program), the transient edge-source
    label gather, and the scatter-symmetrized replicated output.

    Inputs: sharded (src, dst_local, eid, rev_eid, vstart, nloc,
    labels_sh) + replicated (landmarks, packed meta_w/meta_dist, us, vs).
    Outputs: replicated (edge_mask (B, n_edges) bool, dist (B,) int32).
    """
    axis_names = axis_names or tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    v, r, vloc = n_vertices, n_landmarks, v_loc
    wloc = (vloc + 31) // 32
    spec_e = P(axis_names)
    rep = P()

    def body(src_sh, dst_sh, eid_sh, rev_sh, vstart_sh, nloc_sh,
             labels_sh, landmarks_j, meta_w_p, meta_dist_p, us, vs):
        src_l = src_sh[0]                    # (E,) global ids
        dst_l = dst_sh[0]                    # (E,) local dst (pad = vloc)
        eid_l = eid_sh[0]                    # (E,) global slots (pad = n_edges)
        rev_l = rev_sh[0]
        vst = vstart_sh[0]
        n_loc = nloc_sh[0]
        labels_p = labels_sh[0]              # (vloc, R) packed
        labels_loc = widen_dist(labels_p)    # (vloc, R) int32, pad rows = INF
        b = us.shape[0]

        vstart_all = jax.lax.all_gather(vstart_sh, axis_names, tiled=True)

        def to_gathered(ids):
            shard = jnp.clip(
                jnp.searchsorted(vstart_all, ids, side="right") - 1,
                0, n_shards - 1)
            return shard, ids - vstart_all[shard]

        src_shard, src_off = to_gathered(src_l)
        src_g = src_shard * vloc + src_off
        src_word = src_shard * wloc + src_off // 32
        src_bit = (src_off % 32).astype(jnp.uint32)

        dst_glob = jnp.where(dst_l < vloc, vst + dst_l, v)
        is_lm_src = src_l[:, None] == landmarks_j[None, :]
        is_lm_dst = dst_glob[:, None] == landmarks_j[None, :]
        src_lid = jnp.where(is_lm_src.any(1), jnp.argmax(is_lm_src, axis=1), -1)
        dst_lid = jnp.where(is_lm_dst.any(1), jnp.argmax(is_lm_dst, axis=1), -1)
        gm_e = (~is_lm_src.any(1)) & (~is_lm_dst.any(1)) & (dst_l < vloc)

        label_dst = jnp.concatenate(
            [labels_loc, jnp.full((1, r), INF, jnp.int32)], axis=0)[dst_l]
        # transient gather of the packed table for edge-*source* columns:
        # crosses the wire packed, widens in registers, never resident
        full_p = jax.lax.all_gather(labels_p, axis_names, tiled=False)
        label_src32 = widen_dist(full_p.reshape(n_shards * vloc, r)[src_g])

        # ---- A: endpoint label rows from the owning shard ------------------
        def fetch_rows(qs):
            loc = qs - vst
            owned = (qs >= vst) & (qs < vst + n_loc)
            rows = labels_loc[jnp.clip(loc, 0, vloc - 1)]
            rows = jnp.where(owned[:, None], rows, INF)
            return jax.lax.pmin(rows, axis_names)

        lu = fetch_rows(us)
        lv = fetch_rows(vs)

        # ---- B: sketch (replicated compute; packed meta widens inside) -----
        sk = compute_sketch_batch(lu, lv, meta_w_p, meta_dist_p,
                                  use_pallas=False)
        d_top = sk.d_top

        # ---- C: sketch-bounded bidirectional BFS ---------------------------
        def owned_depth0(qs):
            loc = qs - vst
            owned = (qs >= vst) & (qs < vst + n_loc)
            d0 = jnp.full((b, vloc + 1), INF, jnp.int32)
            idx = jnp.where(owned, loc, vloc)
            return d0.at[jnp.arange(b), idx].min(jnp.where(owned, 0, INF))

        def exchange_bits(mask_loc):
            packed = _pack_bits(mask_loc)                    # (B, wloc)
            full = jax.lax.all_gather(packed, axis_names, tiled=False)
            flat = jnp.moveaxis(full, 0, 1).reshape(b, n_shards * wloc)
            words = flat[:, src_word]
            return ((words >> src_bit[None, :]) & jnp.uint32(1)) > 0

        def relay(bits_be, extra_e_mask=None):
            m = bits_be
            if extra_e_mask is not None:
                m = m & extra_e_mask[None, :]
            return segment_or(m, dst_l, vloc + 1, acc_dtype=jnp.int8)

        def psum_i(x):
            return jax.lax.psum(x, axis_names)

        depth_u0 = owned_depth0(us)
        depth_v0 = owned_depth0(vs)

        def ball_size(depth):
            return psum_i(jnp.sum(depth[:, :vloc] < INF, axis=1))

        def cond(c):
            depth_u, depth_v, du, dv, au, av, met, it = c
            active = (~met) & (du + dv < jnp.minimum(d_top, max_levels)) & (au | av)
            return psum_i(active.any().astype(jnp.int32)) > 0

        def step(c):
            depth_u, depth_v, du, dv, au, av, met, it = c
            active = (~met) & (du + dv < jnp.minimum(d_top, max_levels)) & (au | av)
            want_u = sk.d_star_u > du
            want_v = sk.d_star_v > dv
            su = ball_size(depth_u)
            sv = ball_size(depth_v)
            pick_u = jnp.where(want_u != want_v, want_u, su <= sv)
            pick_u = jnp.where(au & av, pick_u, au)

            fr_u = (depth_u[:, :vloc] == du[:, None]) & (active & pick_u)[:, None]
            fr_v = (depth_v[:, :vloc] == dv[:, None]) & (active & ~pick_u)[:, None]
            bits = exchange_bits(fr_u | fr_v)
            msg = relay(bits, gm_e)
            grow_u = (active & pick_u)[:, None]
            grow_v = (active & ~pick_u)[:, None]
            new_u = msg & (depth_u == INF) & grow_u
            new_v = msg & (depth_v == INF) & grow_v
            depth_u = jnp.where(new_u, du[:, None] + 1, depth_u)
            depth_v = jnp.where(new_v, dv[:, None] + 1, depth_v)
            any_u = psum_i(new_u[:, :vloc].any(1).astype(jnp.int32)) > 0
            any_v = psum_i(new_v[:, :vloc].any(1).astype(jnp.int32)) > 0
            au = jnp.where(active & pick_u, any_u, au)
            av = jnp.where(active & ~pick_u, any_v, av)
            du = jnp.where(active & pick_u, du + 1, du)
            dv = jnp.where(active & ~pick_u, dv + 1, dv)
            common = (depth_u[:, :vloc] < INF) & (depth_v[:, :vloc] < INF)
            met = psum_i(common.any(1).astype(jnp.int32)) > 0
            return depth_u, depth_v, du, dv, au, av, met, it + 1

        zero_b = us * 0
        true_b = us == us
        state = (depth_u0, depth_v0, zero_b, zero_b, true_b, true_b,
                 ~true_b, jnp.int32(0) + (vst * 0))
        depth_u, depth_v, du, dv, au, av, met, _ = jax.lax.while_loop(
            cond, step, state)

        common = (depth_u[:, :vloc] < INF) & (depth_v[:, :vloc] < INF)
        sums = jnp.where(common, depth_u[:, :vloc] + depth_v[:, :vloc], INF)
        d_minus = jax.lax.pmin(jnp.min(sums, axis=1), axis_names)
        dist = jnp.minimum(d_minus, d_top)
        reverse_on = met & (d_minus <= d_top)
        recover_on = (d_top < INF) & (d_top <= d_minus)
        trivial = us == vs

        w_set = common & (sums == d_minus[:, None])

        # ---- D: reverse sweeps ---------------------------------------------
        false_e = jnp.broadcast_to((gm_e & ~gm_e)[None, :],
                                   (b, src_l.shape[0]))  # varying-typed False

        def sweep(depth, d_side):
            on = jnp.concatenate([w_set, jnp.zeros((b, 1), bool)], axis=1)
            emask = false_e

            def sbody(i, carry):
                on, emask = carry
                lvl = d_side - i
                send = on[:, :vloc] & (depth[:, :vloc] == lvl[:, None])
                bits = exchange_bits(send)
                cert = bits & gm_e[None, :] & (
                    depth[:, dst_l] == (lvl - 1)[:, None]) & (lvl > 0)[:, None]
                on = on | relay(cert)
                return on, emask | cert

            on, emask = jax.lax.fori_loop(0, int(max_levels), sbody,
                                          (on, emask))
            return emask

        rev_edges = sweep(depth_u, du) | sweep(depth_v, dv)

        # ---- E1: per-landmark side attachments ------------------------------
        rec_edges = false_e
        for ri in range(r):
            lcol = jnp.concatenate(
                [labels_loc[:, ri], jnp.full((1,), INF, jnp.int32)])
            ls_e = label_src32[:, ri]
            ld_e = label_dst[:, ri]
            for side_depth, side_land in ((depth_u, sk.du_land[:, ri]),
                                          (depth_v, sk.dv_land[:, ri])):
                sigma = side_land
                on = (side_depth < INF) & (lcol[None, :] < INF) & (
                    side_depth + lcol[None, :] == sigma[:, None]) & (
                    sigma < INF)[:, None]

                def chain(i, on):
                    bits = exchange_bits(on[:, :vloc])
                    grow = bits & gm_e[None] & (ld_e == ls_e - 1)[None] & (
                        ld_e < INF)[None]
                    return on | relay(grow)

                on = jax.lax.fori_loop(0, max_chain, chain, on)
                bits = exchange_bits(on[:, :vloc])
                interior = bits & on[:, dst_l] & gm_e[None] & (
                    ld_e == ls_e - 1)[None]
                hop_in = bits & (dst_lid == ri)[None] & (ls_e == 1)[None]
                hop_out = (src_lid == ri)[None] & on[:, dst_l] & (ld_e == 1)[None]
                rec_edges = rec_edges | interior | hop_in | hop_out

        # ---- E2: Delta edges (fully local) ----------------------------------
        meta_w32 = widen_dist(meta_w_p)
        w32 = jnp.where(meta_w32 < INF, meta_w32, INF)

        def delta_b(bi, acc):
            me = sk.meta_edge[bi]
            fin = me & (meta_w32 < INF)
            m2 = jnp.where(fin, -w32, INF).T.astype(jnp.int32)
            t1 = jnp.min(label_dst[:, :, None] + m2[None], axis=1)
            minval = jnp.min(label_src32 + t1, axis=1)
            interior = gm_e & (minval == -1)
            g1 = jnp.where(fin, w32 - 1, -1)
            hop1 = (src_lid >= 0) & (
                label_dst == g1[jnp.clip(src_lid, 0)]).any(1)
            hop2 = (dst_lid >= 0) & (
                label_src32 == g1.T[jnp.clip(dst_lid, 0)]).any(1)
            direct = (src_lid >= 0) & (dst_lid >= 0) & fin[
                jnp.clip(src_lid, 0), jnp.clip(dst_lid, 0)] & (
                w32[jnp.clip(src_lid, 0), jnp.clip(dst_lid, 0)] == 1)
            return acc.at[bi].set(interior | hop1 | hop2 | direct)

        delta_edges = jax.lax.fori_loop(0, b, delta_b, false_e)

        edge_mask = ((rev_edges & reverse_on[:, None])
                     | ((rec_edges | delta_edges) & recover_on[:, None]))
        edge_mask = edge_mask & (~trivial)[:, None] & (dst_l < vloc)[None, :]
        dist = jnp.where(trivial, 0, dist)
        mask = _scatter_symmetrize(edge_mask, eid_l, rev_l, n_edges,
                                   axis_names)
        return mask, dist

    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(spec_e, spec_e, spec_e, spec_e, spec_e, spec_e,
                      spec_e, rep, rep, rep, rep, rep),
            out_specs=(rep, rep),
        )
    )


def make_sharded_landmark_pair_step(
    mesh: Mesh,
    *,
    n_vertices: int,
    v_loc: int,
    n_edges: int,
    n_landmarks: int,
    axis_names: tuple[str, ...] | None = None,
):
    """Landmark-landmark lane from shards: distance is a replicated
    ``meta_dist`` lookup; the SPG certifies per dst-owned edge from the
    two gathered (B, V) landmark-distance rows — each chunk moves exactly
    2B packed rows across the mesh, never the table.  Bit-identical to
    ``qbs._landmark_pair_lanes`` (same formula per directed slot, then
    the shared scatter-symmetrize)."""
    axis_names = axis_names or tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    vloc = v_loc
    spec_e = P(axis_names)
    rep = P()

    def body(src_sh, dst_sh, eid_sh, rev_sh, vstart_sh, lm_sh,
             meta_dist_p, ru, rv):
        src_l = src_sh[0]
        dst_l = dst_sh[0]
        eid_l = eid_sh[0]
        rev_l = rev_sh[0]
        lm_loc = lm_sh[0]                    # (R, vloc) packed
        b = ru.shape[0]

        vstart_all = jax.lax.all_gather(vstart_sh, axis_names, tiled=True)
        shard = jnp.clip(
            jnp.searchsorted(vstart_all, src_l, side="right") - 1,
            0, n_shards - 1)
        src_g = shard * vloc + (src_l - vstart_all[shard])

        def rows_at_src(r_idx):
            sel = lm_loc[r_idx]                              # (B, vloc) packed
            full = jax.lax.all_gather(sel, axis_names, tiled=False)
            flat = jnp.moveaxis(full, 0, 1).reshape(b, n_shards * vloc)
            return widen_dist(flat[:, src_g])                # (B, E)

        def rows_at_dst(r_idx):
            sel = widen_dist(lm_loc[r_idx])                  # (B, vloc)
            sel = jnp.concatenate(
                [sel, jnp.full((b, 1), INF, jnp.int32)], axis=1)
            return sel[:, dst_l]                             # (B, E)

        d = jnp.minimum(widen_dist(meta_dist_p[ru, rv]), INF).astype(jnp.int32)
        cert = (rows_at_src(ru) + 1 + rows_at_dst(rv)) == d[:, None]
        cert = cert & (d < INF)[:, None] & (dst_l < vloc)[None, :]
        mask = _scatter_symmetrize(cert, eid_l, rev_l, n_edges, axis_names)
        return mask, d

    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(spec_e, spec_e, spec_e, spec_e, spec_e, spec_e,
                      rep, rep, rep),
            out_specs=(rep, rep),
        )
    )


def make_sharded_onesided_step(
    mesh: Mesh,
    *,
    n_vertices: int,
    v_loc: int,
    n_edges: int,
    n_landmarks: int,
    axis_names: tuple[str, ...] | None = None,
    max_levels: int = 32,
):
    """One-sided landmark lane from shards: d(root, landmark) reads one
    gathered packed row; the distance-bounded *full-graph* BFS from the
    root runs level-synchronously on local edges with the packed-bitmap
    halo exchange, mirroring ``frontier.bfs_depths_batch`` state-for-state
    (act/alive/bounds semantics — bit-identical depths), then certifies
    per dst-owned edge exactly like ``qbs._landmark_onesided_lanes``."""
    axis_names = axis_names or tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    v, vloc = n_vertices, v_loc
    wloc = (vloc + 31) // 32
    spec_e = P(axis_names)
    rep = P()

    def body(src_sh, dst_sh, eid_sh, rev_sh, vstart_sh, nloc_sh, lm_sh,
             roots, r_idx):
        src_l = src_sh[0]
        dst_l = dst_sh[0]
        eid_l = eid_sh[0]
        rev_l = rev_sh[0]
        vst = vstart_sh[0]
        n_loc = nloc_sh[0]
        lm_loc = lm_sh[0]                    # (R, vloc) packed
        b = roots.shape[0]

        vstart_all = jax.lax.all_gather(vstart_sh, axis_names, tiled=True)

        def to_gathered(ids):
            shard = jnp.clip(
                jnp.searchsorted(vstart_all, ids, side="right") - 1,
                0, n_shards - 1)
            return shard, ids - vstart_all[shard]

        src_shard, src_off = to_gathered(src_l)
        src_g = src_shard * vloc + src_off
        src_word = src_shard * wloc + src_off // 32
        src_bit = (src_off % 32).astype(jnp.uint32)

        # the B needed landmark-distance rows, gathered packed
        sel = lm_loc[r_idx]                                  # (B, vloc)
        full = jax.lax.all_gather(sel, axis_names, tiled=False)
        flat = jnp.moveaxis(full, 0, 1).reshape(b, n_shards * vloc)
        to_lm_src = widen_dist(flat[:, src_g])               # (B, E)
        root_sh, root_off = to_gathered(roots)
        d = widen_dist(flat[jnp.arange(b), root_sh * vloc + root_off])
        bounds = jnp.where(d < INF, d - 1, 0)

        # bounded batched BFS, sharded (mirrors bfs_depths_batch exactly)
        loc = roots - vst
        owned = (roots >= vst) & (roots < vst + n_loc)
        depth0 = jnp.full((b, vloc + 1), INF, jnp.int32)
        idx = jnp.where(owned, loc, vloc)
        depth0 = depth0.at[jnp.arange(b), idx].min(
            jnp.where(owned, 0, INF))

        def exchange_bits(mask_loc):
            packed = _pack_bits(mask_loc)
            full_b = jax.lax.all_gather(packed, axis_names, tiled=False)
            flat_b = jnp.moveaxis(full_b, 0, 1).reshape(b, n_shards * wloc)
            words = flat_b[:, src_word]
            return ((words >> src_bit[None, :]) & jnp.uint32(1)) > 0

        def active_rows(level, alive):
            return alive & (level < max_levels) & (level < bounds)

        def cond(c):
            _, level, alive = c
            return jax.lax.psum(
                active_rows(level, alive).any().astype(jnp.int32),
                axis_names) > 0

        def step(c):
            depth, level, alive = c
            act = active_rows(level, alive)
            frontier = (depth[:, :vloc] == level) & act[:, None]
            bits = exchange_bits(frontier)
            msg = segment_or(bits, dst_l, vloc + 1, acc_dtype=jnp.int8)
            new = msg & (depth == INF)
            row_new = jax.lax.psum(
                new[:, :vloc].any(axis=1).astype(jnp.int32), axis_names) > 0
            alive = jnp.where(act, row_new, alive)
            return jnp.where(new, level + 1, depth), level + 1, alive

        zero = jnp.int32(0) + (vst * 0)
        depth, _, _ = jax.lax.while_loop(
            cond, step, (depth0, zero, roots == roots))

        cert = (to_lm_src + 1 + depth[:, dst_l]) == d[:, None]
        cert = cert & (d < INF)[:, None] & (dst_l < vloc)[None, :]
        mask = _scatter_symmetrize(cert, eid_l, rev_l, n_edges, axis_names)
        return mask, d

    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(spec_e, spec_e, spec_e, spec_e, spec_e, spec_e,
                      spec_e, rep, rep),
            out_specs=(rep, rep),
        )
    )


class ShardedIndex:
    """``QbSIndex``-shaped serving facade over born-sharded tables.

    Exposes the same per-lane device steps and query delegates as
    ``QbSIndex`` (the planner/service layers run unchanged on top — the
    streaming admission seam of DESIGN.md §5 never sees the sharding),
    but every step answers from the vertex-sharded label + CSR blocks.
    ``ServingService(mesh=...)`` batch-sharding is rejected: the index
    is already mesh-resident (``is_sharded``).
    """

    is_sharded = True
    epoch = 0   # sharded tables are build-once; dynamic updates (§13) are
                # a replicated-index feature — the epoch never advances here

    def __init__(self, graph: Graph, labels: ShardedLabels,
                 part: EdgePartition, mesh: Mesh, *,
                 max_levels: int = 32, max_chain: int = 8, chunk: int = 32,
                 axis_names: tuple[str, ...] | None = None):
        self.graph = graph
        self.labels = labels
        self.part = part
        self.mesh = mesh
        self.max_levels = max_levels
        self.max_chain = max_chain
        self.chunk = chunk
        axis_names = axis_names or tuple(mesh.axis_names)
        self.axis_names = axis_names
        v = graph.n_vertices
        r = labels.n_landmarks

        lm_np = np.asarray(labels.landmarks)
        self._is_landmark_np = np.zeros((v,), bool)
        self._is_landmark_np[lm_np] = True
        self._lid_np = np.full((v,), -1, np.int32)
        self._lid_np[lm_np] = np.arange(r, dtype=np.int32)
        self._service = None

        # global slot ids + reverse slots, edge-partition-aligned (pads
        # target the dropped column n_edges)
        rev = _reverse_edge_map(np.asarray(graph.src), np.asarray(graph.dst),
                                v)
        rev_full = np.concatenate(
            [rev, np.asarray([graph.n_edges], np.int32)])
        rev_eid = rev_full[part.eid].astype(np.int32)

        shard = NamedSharding(mesh, P(axis_names))
        put = partial(jax.device_put, device=shard)
        self._src_sh = put(part.src)
        self._dst_sh = put(part.dst_local)
        self._eid_sh = put(part.eid)
        self._rev_eid_sh = put(rev_eid)
        self._vstart_sh = put(part.vstart)
        self._nloc_sh = put(labels.nloc)

        common = dict(n_vertices=v, v_loc=part.v_loc, n_edges=graph.n_edges,
                      n_landmarks=r, axis_names=axis_names)
        self._general = make_sharded_general_step(
            mesh, e_max=part.e_max, max_levels=max_levels,
            max_chain=max_chain, **common)
        self._lm_pair = make_sharded_landmark_pair_step(mesh, **common)
        self._onesided = make_sharded_onesided_step(
            mesh, max_levels=max_levels, **common)

    # -- per-lane device steps (QbSIndex contract) ---------------------------

    def serve_step(self, us, vs):
        """General lane: (B,) pairs -> replicated device ``(dist (B,),
        edge_mask (B, E))`` — already symmetrized (the scatter does it)."""
        mask, dist = self._general(
            self._src_sh, self._dst_sh, self._eid_sh, self._rev_eid_sh,
            self._vstart_sh, self._nloc_sh, self.labels.labels_sh,
            self.labels.landmarks, self.labels.meta_w, self.labels.meta_dist,
            jnp.asarray(us, jnp.int32), jnp.asarray(vs, jnp.int32))
        return dist, mask

    def landmark_pair_step(self, ru, rv):
        mask, dist = self._lm_pair(
            self._src_sh, self._dst_sh, self._eid_sh, self._rev_eid_sh,
            self._vstart_sh, self.labels.lm_sh, self.labels.meta_dist,
            jnp.asarray(ru, jnp.int32), jnp.asarray(rv, jnp.int32))
        return dist, mask

    def landmark_onesided_step(self, roots, r_idx):
        mask, dist = self._onesided(
            self._src_sh, self._dst_sh, self._eid_sh, self._rev_eid_sh,
            self._vstart_sh, self._nloc_sh, self.labels.lm_sh,
            jnp.asarray(roots, jnp.int32), jnp.asarray(r_idx, jnp.int32))
        return dist, mask

    # -- memory accounting ---------------------------------------------------

    def sharded_size_bytes(self) -> dict:
        """Per-device resident bytes vs the replicated layout the index
        replaces — the acceptance metric of the sharding work
        (benchmarks/sharded_memory.py commits ``per_device_frac`` rows;
        the gate holds them under a linear-scaling ceiling)."""
        item = self.labels.pack_dtype.itemsize
        v, r = self.labels.n_vertices, self.labels.n_landmarks
        e = self.graph.n_edges
        per_device_label = self.labels.per_device_label_bytes()
        # src + dst_local + eid + rev_eid, one edge shard each
        per_device_csr = 4 * self.part.e_max * 4
        replicated_label = (2 * v * r + 2 * r * r) * item
        replicated_csr = 3 * e * 4          # src + dst + rev_edge
        per_device = per_device_label + per_device_csr
        replicated = replicated_label + replicated_csr
        return {
            "n_shards": int(np.prod(
                [self.mesh.shape[a] for a in self.axis_names])),
            "per_device_label_bytes": per_device_label,
            "per_device_csr_bytes": per_device_csr,
            "per_device_bytes": per_device,
            "replicated_label_bytes": replicated_label,
            "replicated_csr_bytes": replicated_csr,
            "replicated_bytes": replicated,
            "per_device_frac": per_device / max(replicated, 1),
        }

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, graph: Graph, n_landmarks: int = 20,
              landmarks: np.ndarray | None = None,
              mesh: Mesh | int | None = None,
              frontier_mode: str = "bitmap", build_max_levels: int = 64,
              **kw) -> "ShardedIndex":
        """Build labels distributed (born sharded) and wrap them for
        serving.  ``mesh`` is a ``jax.sharding.Mesh`` or a device count
        (1-D mesh over the first N local devices, axis ``"shards"``);
        default: every local device."""
        if mesh is None or isinstance(mesh, int):
            n = len(jax.devices()) if mesh is None else int(mesh)
            avail = jax.devices()
            if len(avail) < n:
                raise ValueError(
                    f"mesh={n} devices requested, {len(avail)} visible")
            mesh = Mesh(np.array(avail[:n]), ("shards",))
        if landmarks is None:
            landmarks = select_landmarks(graph, n_landmarks)
        labels, part = distributed_build_sharded(
            graph, np.asarray(landmarks), mesh,
            frontier_mode=frontier_mode, max_levels=build_max_levels)
        return cls(graph, labels, part, mesh, **kw)

    # -- queries (thin delegates over the planner/service) -------------------

    def make_service(self, **kw):
        from ..serving.service import ServingService
        return ServingService(self, **kw)

    def make_stream(self, *, policy=None, **kw):
        from ..serving.stream import StreamingService
        return StreamingService(self, policy=policy, **kw)

    def _default_service(self):
        if self._service is None:
            self._service = self.make_service()
        return self._service

    def query_batch(self, us, vs) -> list[SPGResult]:
        return self._default_service().query_batch(us, vs)

    def query_batch_arrays(self, us, vs):
        return self._default_service().query_arrays(us, vs)

    def query(self, u: int, v: int) -> SPGResult:
        return self.query_batch([u], [v])[0]
