"""Baselines from the paper (§3, §6.1) plus the exact oracle.

* ``bfs_spg``      — textbook oracle: two full BFSs; an edge (x, y) lies on a
                     shortest u-v path iff d_u(x) + 1 + d_v(y) == d(u, v).
* ``bibfs_spg``    — the paper's search baseline (Bi-BFS): implemented as a
                     degenerate guided search with an empty landmark set,
                     which is exactly what QbS reduces to without a sketch.
* ``PPL``          — pruned path labelling (Algorithm 1): PLL with the
                     equal-distance pruning removed so 2-hop *path* cover
                     holds; recursive query answering.
* ``ParentPPL``    — PPL labels + per-label parent sets; parents accelerate
                     edge emission, the recursion guarantees exactness.

PPL/ParentPPL are host-side (numpy): they are comparison baselines whose
role in the paper is to demonstrate non-scalability (Tables 2-3); the
level-synchronous inner BFS is vectorized, the landmark loop is inherently
sequential because pruning depends on all previous labels.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from .frontier import bfs_depths, make_relay
from .graph import INF, Graph
from .qbs import SPGResult, _reverse_edge_map
from .search import Query, guided_search, make_search_context

# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------


def bfs_distances(graph: Graph, root: int, max_levels: int = 256,
                  backend: str = "segment") -> np.ndarray:
    return np.asarray(
        bfs_depths(make_relay(graph, backend=backend), jnp.int32(root), max_levels)
    )


def bfs_spg(graph: Graph, u: int, v: int, max_levels: int = 256,
            backend: str = "segment") -> SPGResult:
    """Exact oracle via two full BFSs (O(E) each, no pruning)."""
    engine = make_relay(graph, backend=backend)
    du = bfs_depths(engine, jnp.int32(u), max_levels)
    dv = bfs_depths(engine, jnp.int32(v), max_levels)
    d = int(du[v])
    if u == v:
        return SPGResult(u=u, v=v, dist=0, edge_ids=np.zeros((0,), np.int64), d_top=INF)
    mask = np.asarray((du[graph.src] + 1 + dv[graph.dst]) == d)
    rev = _reverse_edge_map(np.asarray(graph.src), np.asarray(graph.dst), graph.n_vertices)
    mask = mask | mask[rev]
    return SPGResult(u=u, v=v, dist=d, edge_ids=np.flatnonzero(mask), d_top=INF)


# ---------------------------------------------------------------------------
# Bi-BFS baseline = guided search with an empty landmark set
# ---------------------------------------------------------------------------


@lru_cache(maxsize=8)
def _bibfs_search_step(n_vertices: int, max_levels: int):
    """One jitted, vmapped degenerate search per (V, max_levels).  The
    search context rides in as a pytree argument, so every same-sized
    graph/backend shares this entry; constructing ``jax.jit`` inside
    ``bibfs_spg_batch`` instead would recompile on every call (QBS004)."""
    search = partial(guided_search, n_vertices=n_vertices,
                     max_levels=max_levels, max_chain=1)
    return jax.jit(jax.vmap(search, in_axes=(None, 0)))


def bibfs_spg_batch(graph: Graph, us, vs, max_levels: int = 512,
                    backend: str = "segment") -> list[SPGResult]:
    us = np.asarray(us, np.int32).reshape(-1)
    vs = np.asarray(vs, np.int32).reshape(-1)
    # empty landmark set -> G- == G, the Bi-BFS degeneration
    ctx = make_search_context(graph, None, backend=backend)
    b = us.shape[0]
    inf = jnp.int32(INF)
    zero = jnp.int32(0)
    queries = Query(
        u=jnp.asarray(us), v=jnp.asarray(vs),
        d_top=jnp.full((b,), inf),
        du_land=jnp.full((b, 1), inf), dv_land=jnp.full((b, 1), inf),
        meta_edge=jnp.zeros((b, 1, 1), bool),
        d_star_u=jnp.full((b,), zero), d_star_v=jnp.full((b,), zero),
    )
    step = _bibfs_search_step(graph.n_vertices, max_levels)
    res = step(ctx, queries)
    rev = _reverse_edge_map(np.asarray(graph.src), np.asarray(graph.dst), graph.n_vertices)
    mask = np.asarray(res.edge_mask)
    mask = mask | mask[:, rev]
    dists = np.asarray(res.dist)
    return [
        SPGResult(u=int(us[k]), v=int(vs[k]), dist=int(dists[k]),
                  edge_ids=np.flatnonzero(mask[k]), d_top=INF)
        for k in range(b)
    ]


def bibfs_spg(graph: Graph, u: int, v: int, max_levels: int = 512,
              backend: str = "segment") -> SPGResult:
    return bibfs_spg_batch(graph, [u], [v], max_levels=max_levels,
                           backend=backend)[0]


# ---------------------------------------------------------------------------
# PPL — pruned path labelling (Algorithm 1)
# ---------------------------------------------------------------------------


class PPLIndex:
    """Pruned path labelling over *all* vertices in degree order.

    Labels are a dense (V, V) int32 matrix in vertex-order index space with
    INF for pruned entries (fine at baseline scales; the paper's point is
    that this family cannot scale, which the dense footprint makes vivid).
    """

    def __init__(self, graph: Graph, store_parents: bool = False,
                 max_levels: int = 256):
        self.graph = graph
        self.store_parents = store_parents
        v = graph.n_vertices
        deg = np.asarray(graph.degrees())
        self.order = np.argsort(-deg, kind="stable").astype(np.int32)
        src = np.asarray(graph.src)
        dst = np.asarray(graph.dst)
        indptr = np.asarray(graph.indptr)
        self._adj = (indptr, dst)
        real = src != dst
        self._edge_set = set(zip(src[real].tolist(), dst[real].tolist()))

        lab = np.full((v, v), INF, np.int64)  # (vertex, landmark-rank)
        parents: dict[tuple[int, int], list[int]] = {}
        for k, vk in enumerate(self.order):
            depth = np.full((v,), INF, np.int64)
            depth[vk] = 0
            frontier = np.zeros((v,), bool)
            frontier[vk] = True
            level = 0
            while frontier.any() and level < max_levels:
                f_idx = np.flatnonzero(frontier)
                # d_{L_{k-1}}(v_k, u) via already-built labels
                dq = (lab[f_idx, :] + lab[vk, None, :]).min(axis=1)
                dq = np.minimum(dq, INF)
                keep = dq >= depth[f_idx]          # label unless strictly covered
                expand = dq > depth[f_idx]         # expand only if strictly better
                labelled = f_idx[keep]
                lab[labelled, k] = depth[labelled]
                if store_parents and level > 0:
                    for uu in labelled:
                        s, e = indptr[uu], indptr[uu + 1]
                        nb = dst[s:e]
                        ps = nb[depth[nb] == depth[uu] - 1]
                        if ps.size:
                            parents[(int(uu), k)] = ps.tolist()
                nxt = np.zeros((v,), bool)
                for uu in f_idx[expand]:
                    s, e = indptr[uu], indptr[uu + 1]
                    nb = dst[s:e]
                    fresh = nb[depth[nb] == INF]
                    depth[fresh] = level + 1
                    nxt[fresh] = True
                frontier = nxt
                level += 1
        self.lab = lab
        self.parents = parents
        self.rank_to_vertex = self.order
        self.vertex_to_rank = np.empty((v,), np.int64)
        self.vertex_to_rank[self.order] = np.arange(v)

    def label_entries(self) -> int:
        return int((self.lab < INF).sum())

    def dist(self, u: int, v: int) -> int:
        return int(min(np.min(self.lab[u] + self.lab[v]), INF))

    def query(self, u: int, v: int) -> SPGResult:
        """Recursive SPG answering (§3.2), memoized over sub-queries."""
        edges: set[tuple[int, int]] = set()
        memo: set[tuple[int, int]] = set()

        def solve(a: int, b: int) -> None:
            if a == b:
                return
            key = (min(a, b), max(a, b))
            if key in memo:
                return
            memo.add(key)
            d = int(min(np.min(self.lab[a] + self.lab[b]), INF))
            if d >= INF:
                return
            if d == 1:
                edges.add(key)
                return
            sums = self.lab[a] + self.lab[b]
            ranks = np.flatnonzero(sums == d)
            for k in ranks:
                r = int(self.rank_to_vertex[k])
                if r in (a, b):
                    continue
                if self.store_parents:
                    self._emit_parent_walk(a, k, edges)
                    self._emit_parent_walk(b, k, edges)
                solve(a, r)
                solve(b, r)

        solve(u, v)
        d = self.dist(u, v)
        return SPGResult(u=u, v=v, dist=d,
                         edge_ids=self._edges_to_ids(edges), d_top=INF)

    def _emit_parent_walk(self, x: int, rank: int, edges: set) -> None:
        """ParentPPL accelerator: emit tree edges along stored parent sets."""
        stack = [x]
        seen = {x}
        r = int(self.rank_to_vertex[rank])
        while stack:
            cur = stack.pop()
            if self.lab[cur, rank] == 1:
                edges.add((min(cur, r), max(cur, r)))
                continue
            for p in self.parents.get((cur, rank), ()):
                edges.add((min(cur, p), max(cur, p)))
                if p not in seen:
                    seen.add(p)
                    stack.append(p)

    def _edges_to_ids(self, edges: set[tuple[int, int]]) -> np.ndarray:
        src = np.asarray(self.graph.src)
        dst = np.asarray(self.graph.dst)
        if not edges:
            return np.zeros((0,), np.int64)
        es = np.asarray(sorted(edges), np.int64)
        keys = src.astype(np.int64) * self.graph.n_vertices + dst
        order = np.argsort(keys)
        want = np.concatenate([
            es[:, 0] * self.graph.n_vertices + es[:, 1],
            es[:, 1] * self.graph.n_vertices + es[:, 0],
        ])
        pos = np.searchsorted(keys[order], want)
        pos = np.clip(pos, 0, keys.size - 1)
        ids = order[pos]
        ok = keys[ids] == want
        return np.unique(ids[ok])

    def memory_bytes(self) -> int:
        n_labels = self.label_entries()
        per = 5  # 32-bit landmark id + 8-bit distance (paper's accounting)
        if self.store_parents:
            per += 0  # parents accounted separately below
        total = n_labels * per
        if self.store_parents:
            total += sum(4 * len(p) for p in self.parents.values())
        return total
