"""Algorithm 2 (labelling scheme construction), batched over landmarks.

The paper runs one BFS per landmark with two queues: ``Q_L`` (vertices
reached via a shortest path whose interior avoids all landmarks -> get a
label) and ``Q_N`` (reached only through some landmark -> no label).  We run
all |R| BFSs as a single level-synchronous frontier program over state

    depth[R, V]    BFS depth per landmark root (INF = unvisited)
    reach_L[R, V]  "exists a shortest path from root r whose interior
                    contains no landmark" (the Q_L membership bit)

Per level every edge relays two messages: *visited* (from any frontier
vertex) and *L* (only from frontier vertices allowed as path interior:
non-landmarks, or the root itself).  Q_L-before-Q_N priority at equal depth
in the paper is exactly the OR over same-level predecessors here.

Determinism (Lemma 5.2) is structural: the program never depends on a
landmark order, which is what licenses batching/vmapping the BFSs — the
TPU analogue of the paper's thread-level parallelism (§5.3).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .frontier import FrontierEngine, make_relay
from .graph import INF, Graph
from .packing import pad_width


class LabellingScheme(NamedTuple):
    """Labelling scheme L = (M, L) of Definition 4.2 in dense form."""

    landmarks: jax.Array    # (R,) int32 vertex ids
    lid: jax.Array          # (V,) int32 vertex -> landmark index, -1 otherwise
    is_landmark: jax.Array  # (V,) bool
    label_dist: jax.Array   # (V, R) int32; INF where no label entry exists
    meta_w: jax.Array       # (R, R) int32 meta-graph edge weights sigma; INF = no edge
    meta_dist: jax.Array    # (R, R) int32 APSP distances d_M on the meta-graph

    @property
    def n_landmarks(self) -> int:
        return int(self.landmarks.shape[0])

    def label_valid(self) -> jax.Array:
        return self.label_dist < INF

    def packed(self, lm_dist=None):
        """The packed-HBM view of this scheme (``core.packing``): uint8 or
        uint16 by measured diameter, dtype max as the INF sentinel.  The
        int32 arrays here stay the host-side build/oracle representation;
        serving reads the packed tables (``QbSIndex.packed``)."""
        from .packing import pack_labelling
        return pack_labelling(self, lm_dist=lm_dist)


def _bfs_rows(
    engine: FrontierEngine,
    roots: jax.Array,
    is_landmark: jax.Array,
    max_levels: int,
):
    """Level-synchronous (depth, reach_L) BFS rows from ``roots``.

    Each row is independent of the others (the frontier is per-row), so a
    subset of roots computes bit-identical rows to the full-R build — the
    property the incremental update path (``update_labelling``) relies on.
    """
    K = roots.shape[0]
    V = engine.n_vertices

    depth0 = jnp.full((K, V), INF, jnp.int32).at[jnp.arange(K), roots].set(0)
    reach0 = jnp.zeros((K, V), bool).at[jnp.arange(K), roots].set(True)
    # roots may relay L-messages even though they are landmarks
    is_root = jnp.zeros((K, V), bool).at[jnp.arange(K), roots].set(True)
    propagate_ok = (~is_landmark)[None, :] | is_root

    def cond(carry):
        _, _, level, alive = carry
        return alive & (level < max_levels)

    def body(carry):
        depth, reach_l, level, _ = carry
        frontier = depth == level
        prop_l = frontier & reach_l & propagate_ok
        # one fused relay for both message kinds: the per-call fixed cost
        # dominates at small K (the incremental-update path), and rows are
        # independent so stacking changes nothing
        msg = engine.relay(jnp.concatenate([frontier, prop_l], axis=0))
        msg_vis, msg_l = msg[:K], msg[K:]
        new = msg_vis & (depth == INF)
        depth = jnp.where(new, level + 1, depth)
        reach_l = reach_l | (new & msg_l)
        return depth, reach_l, level + 1, new.any()

    depth, reach_l, _, _ = jax.lax.while_loop(
        cond, body, (depth0, reach0, jnp.int32(0), jnp.bool_(True))
    )
    return depth, reach_l


@partial(jax.jit, static_argnames=("max_levels",))
def _build_labelling_arrays(
    engine: FrontierEngine,
    landmarks: jax.Array,
    is_landmark: jax.Array,
    max_levels: int,
):
    R = landmarks.shape[0]
    depth, reach_l = _bfs_rows(engine, landmarks, is_landmark, max_levels)

    # Labels only for non-landmarks reached via a landmark-free path.
    valid = reach_l & (~is_landmark)[None, :]
    label_dist = jnp.where(valid, depth, INF).T.astype(jnp.int32)  # (V, R)

    # Meta edge (r_i, r_j) iff landmark j was reached from root i with the
    # L-bit set; weight = its BFS depth = d_G(r_i, r_j).
    at_land = depth[:, landmarks]          # (R, R)
    l_at_land = reach_l[:, landmarks]      # (R, R)
    meta_w = jnp.where(l_at_land, at_land, INF)
    meta_w = meta_w.at[jnp.arange(R), jnp.arange(R)].set(INF)  # no self edges
    # Determinism gives symmetry; enforce it to kill numeric asymmetry risk.
    meta_w = jnp.minimum(meta_w, meta_w.T)

    meta_dist = meta_apsp(meta_w)
    return label_dist, meta_w, meta_dist


@partial(jax.jit, static_argnames=("max_levels",))
def _build_labelling_rows(
    engine: FrontierEngine,
    roots: jax.Array,
    landmarks: jax.Array,
    is_landmark: jax.Array,
    max_levels: int,
):
    """The incremental-update slice of the build: BFS rows for a (padded)
    subset of landmark roots on the post-update graph, returning exactly the
    pieces ``update_labelling`` scatters back into the old scheme — depth
    rows ``(K, V)`` (the new lm_dist rows), label columns ``(V, K)`` and
    raw (pre-symmetrization) meta rows ``(K, R)``."""
    depth, reach_l = _bfs_rows(engine, roots, is_landmark, max_levels)
    valid = reach_l & (~is_landmark)[None, :]
    label_cols = jnp.where(valid, depth, INF).T.astype(jnp.int32)   # (V, K)
    at_land = depth[:, landmarks]
    l_at_land = reach_l[:, landmarks]
    meta_rows = jnp.where(l_at_land, at_land, INF).astype(jnp.int32)  # (K, R)
    return depth, label_cols, meta_rows


def meta_apsp(meta_w: jax.Array) -> jax.Array:
    """Min-plus APSP (Floyd-Warshall) on the meta-graph. d_M == d_G between
    landmarks (meta edges are exact distances; every landmark-to-landmark
    shortest path splits at its interior landmarks into meta edges)."""
    R = meta_w.shape[0]
    d0 = jnp.minimum(meta_w, INF).at[jnp.arange(R), jnp.arange(R)].set(0)

    def body(k, d):
        cand = d[:, k][:, None] + d[k, :][None, :]
        return jnp.minimum(d, cand)

    d = jax.lax.fori_loop(0, R, body, d0)
    return jnp.minimum(d, INF)


# Standalone jitted entry for host callers (update_labelling); the build
# path traces meta_apsp inside its own jitted program.
_meta_apsp_j = jax.jit(meta_apsp)


def build_labelling(
    graph: Graph, landmarks: np.ndarray, *, max_levels: int = 256,
    backend: str = "segment", engine: FrontierEngine | None = None,
    **engine_kw,
) -> LabellingScheme:
    landmarks = jnp.asarray(landmarks, jnp.int32)
    R = int(landmarks.shape[0])
    V = graph.n_vertices
    is_landmark = jnp.zeros((V,), bool).at[landmarks].set(True)
    lid = jnp.full((V,), -1, jnp.int32).at[landmarks].set(jnp.arange(R, dtype=jnp.int32))
    if engine is None:
        engine = make_relay(graph, backend=backend, **engine_kw)
    label_dist, meta_w, meta_dist = _build_labelling_arrays(
        engine, landmarks, is_landmark, max_levels
    )
    return LabellingScheme(
        landmarks=landmarks,
        lid=lid,
        is_landmark=is_landmark,
        label_dist=label_dist,
        meta_w=meta_w,
        meta_dist=meta_dist,
    )


# ---------------------------------------------------------------------------
# Incremental maintenance (DESIGN.md §13): affected-landmark recompute.
# ---------------------------------------------------------------------------


def affected_landmarks(
    scheme: LabellingScheme,
    lm_dist: np.ndarray,
    graph_new: Graph,
    inserts: np.ndarray | None = None,
    deletes: np.ndarray | None = None,
) -> np.ndarray:
    """``(R,)`` bool mask of landmarks whose BFS row an update batch touches.

    ``lm_dist`` is the exact pre-update ``(R, V)`` distance table,
    ``graph_new`` the post-batch graph, and ``inserts``/``deletes`` the
    *effective* delta (insert-of-absent / delete-of-present edges only —
    ``QbSIndex.apply_update`` filters).  Per landmark r and edge (a, b)
    with a the endpoint nearer r, the criteria are exact, not heuristic:

    * ``|d(r,a) - d(r,b)| >= 2`` insert: distances shorten — affected.
    * equal depths (or both INF): the edge joins or leaves no shortest
      path from r (any path through it is strictly longer) — unchanged.
    * ``diff == 1`` insert: depths are unchanged (the new path ties);
      only the shortest-path DAG gains the edge a -> b, whose reach_L
      contribution is ``reach_L(a) & propagate_ok(a)``.  The row changes
      only if that contribution is live *and* b lacked the L-bit:
      ``reach_L(a) & ok(a) & ~reach_L(b)``.
    * ``diff == 1`` delete: affected if b loses its last surviving
      shortest predecessor (checked against ``graph_new``'s CSR — so two
      deletes in one batch cannot alibi each other), or if the removed
      DAG edge carried a live L-contribution into a reached b *and* no
      surviving predecessor still contributes one:
      ``reach_L(a) & ok(a) & reach_L(b) & ~l_keep(b)``.

    reach_L is read off the existing tables: ``label_dist[x, r] < INF``
    for non-landmark x, ``meta_w[r, lid[x]] < INF`` for landmark x (row
    r's own root is True); ``propagate_ok`` is false exactly for
    non-root landmarks, which never relay L-messages.  Label sparsity is
    what makes this tight on hub-heavy graphs: most diff==1 edges hang
    off landmark-shadowed vertices and flag nothing.

    Batch-exactness: if no edge flags row r, induction over depth levels
    shows the BFS depth table and then the reach_L fixpoint of row r are
    preserved edge-by-edge (every insert ties or lands on a dead
    contribution, every delete leaves a supporting predecessor and
    removes only dead or redundant contributions).  Flagged rows are
    recomputed exactly on ``graph_new``.
    """
    lm = np.asarray(lm_dist)
    R = lm.shape[0]
    aff = np.zeros((R,), bool)
    label = np.asarray(scheme.label_dist)        # (V, R)
    meta_w = np.asarray(scheme.meta_w)           # (R, R)
    lid = np.asarray(scheme.lid)
    is_lm = np.asarray(scheme.is_landmark)
    indptr = np.asarray(graph_new.indptr)
    dst = np.asarray(graph_new.dst)

    def reach(x: int) -> np.ndarray:
        """(R,) reach_L[r, x]: a landmark-interior-free shortest r-x path."""
        if is_lm[x]:
            out = meta_w[:, lid[x]] < INF
            out[lid[x]] = True                   # own root
            return out
        return label[x, :] < INF

    def contrib(x: int) -> np.ndarray:
        """(R,) live L-contribution of x: reach_L & propagate_ok."""
        if is_lm[x]:
            out = np.zeros((R,), bool)
            out[lid[x]] = True                   # roots relay their own bit
            return out
        return label[x, :] < INF

    def _pairs(arr):
        if arr is None:
            return ()
        arr = np.asarray(arr, np.int64).reshape(-1, 2)
        return [(int(a), int(b)) for a, b in arr]

    for u, v in _pairs(inserts):
        du, dv = lm[:, u], lm[:, v]
        gap = np.abs(du - dv)
        rows = gap >= 2
        one = gap == 1
        if one.any():
            cu, cv, ru, rv = contrib(u), contrib(v), reach(u), reach(v)
            a_is_u = du < dv                     # a = nearer endpoint
            rows = rows | (one & np.where(a_is_u, cu & ~rv, cv & ~ru))
        aff |= rows

    def _pred_keep(x: int, dx: np.ndarray):
        """For farther endpoint x: (R,) has-surviving-shortest-predecessor
        and (R,) some survivor still carries a live L-contribution."""
        nb = dst[indptr[x]:indptr[x + 1]]
        nb = nb[nb != x]                         # drop self-loop padding
        if not nb.size:
            z = np.zeros((R,), bool)
            return z, z
        at_depth = lm[:, nb] == (dx - 1)[:, None]        # (R, deg)
        contrib_nb = (label[nb, :] < INF).T              # (R, deg)
        lm_nb = np.nonzero(is_lm[nb])[0]
        for j in lm_nb:                                  # landmark neighbors:
            contrib_nb[lid[nb[j]], j] = True             # roots relay own bit
        return at_depth.any(axis=1), (at_depth & contrib_nb).any(axis=1)

    for u, v in _pairs(deletes):
        du, dv = lm[:, u], lm[:, v]
        one = np.abs(du - dv) == 1               # real edges: gap <= 1
        if not one.any():
            continue
        cu, cv, ru, rv = contrib(u), contrib(v), reach(u), reach(v)
        a_is_u = du < dv
        pred_u, keep_u = _pred_keep(u, du)
        pred_v, keep_v = _pred_keep(v, dv)
        orphaned = np.where(a_is_u, ~pred_v, ~pred_u)
        l_loss = np.where(a_is_u, cu & rv & ~keep_v, cv & ru & ~keep_u)
        aff |= one & (orphaned | l_loss)
    return aff


def update_labelling(
    graph_new: Graph,
    scheme: LabellingScheme,
    lm_dist: np.ndarray,
    inserts: np.ndarray | None = None,
    deletes: np.ndarray | None = None,
    *,
    max_levels: int = 256,
    backend: str = "segment",
    engine: FrontierEngine | None = None,
    churn_threshold: float = 0.5,
    **engine_kw,
) -> tuple[LabellingScheme | None, np.ndarray | None, dict]:
    """Incrementally maintain a labelling across one edge-update batch.

    Returns ``(scheme_new, lm_dist_new, info)`` where both tables are
    bit-identical to a fresh ``build_labelling`` on ``graph_new`` (the
    property-harness contract).  When the affected fraction exceeds
    ``churn_threshold`` the incremental path loses to a rebuild; the
    function returns ``(None, None, info)`` with ``info["full_rebuild"]``
    set and the caller rebuilds.  ``info["affected"]`` holds the affected
    landmark indices either way.
    """
    lm = np.asarray(lm_dist, np.int32)
    R = scheme.n_landmarks
    aff = affected_landmarks(scheme, lm, graph_new, inserts, deletes)
    idx = np.nonzero(aff)[0].astype(np.int32)
    info = {"affected": idx, "full_rebuild": False, "n_affected": int(idx.size)}
    if idx.size == 0:
        return scheme, lm, info
    if idx.size > churn_threshold * R:
        info["full_rebuild"] = True
        return None, None, info

    if engine is None:
        engine = make_relay(graph_new, backend=backend, **engine_kw)
    # Pad the root subset to the pad_width ladder so the jit cache sees a
    # log-bounded set of shapes; duplicate rows recompute identical values,
    # so scattering the padded set (duplicates included) is exact.
    K = int(idx.size)
    k_pad = pad_width(K)
    idx_pad = np.concatenate([idx, np.full((k_pad - K,), idx[0], np.int32)])
    roots_pad = np.asarray(scheme.landmarks)[idx_pad]
    depth, label_cols, meta_rows = _build_labelling_rows(
        engine, jnp.asarray(roots_pad, jnp.int32), scheme.landmarks,
        scheme.is_landmark, max_levels)
    # label_cols stays on device: the (V, R) table is scattered in place
    # rather than round-tripped through the host.
    label_dist = jnp.asarray(scheme.label_dist).at[
        :, jnp.asarray(idx_pad)].set(label_cols)
    depth = np.asarray(depth)[:K]              # (K, V) — new lm_dist rows
    meta_rows = np.asarray(meta_rows)[:K]      # (K, R) raw, diag carries 0
    # Raw meta values are symmetric (reach_L is a symmetric property), and
    # an entry (i, j) only changes when d(r_i, r_j) or its L-bit moves —
    # which flags *both* rows.  So scattering the recomputed rows into both
    # the rows and columns of the affected set, resetting the (affected)
    # diagonal to INF and re-harmonizing with the transpose reproduces the
    # fresh build's meta_w exactly.
    meta_w = np.asarray(scheme.meta_w).copy()
    meta_w[idx, :] = meta_rows
    meta_w[:, idx] = meta_rows.T
    meta_w[idx, idx] = INF
    meta_w = np.minimum(meta_w, meta_w.T)
    meta_dist = _meta_apsp_j(jnp.asarray(meta_w))

    lm_new = lm.copy()
    lm_new[idx] = depth
    scheme_new = LabellingScheme(
        landmarks=scheme.landmarks,
        lid=scheme.lid,
        is_landmark=scheme.is_landmark,
        label_dist=label_dist,
        meta_w=jnp.asarray(meta_w),
        meta_dist=meta_dist,
    )
    return scheme_new, lm_new, info


def labelling_size_bytes(scheme: LabellingScheme) -> dict:
    """Paper's size accounting (§6.1): |R| * 8 bits per vertex for L, plus
    the meta-graph.  Distances on complex networks fit 8 bits — which is
    no longer aspirational: ``packing.packed_size_bytes`` measures the
    bytes the packed tables actually occupy in HBM."""
    v = int(scheme.label_dist.shape[0])
    r = scheme.n_landmarks
    n_meta = int(np.asarray((scheme.meta_w < INF).sum()))
    return {
        "label_bytes": v * r,                # 8 bits per (vertex, landmark)
        "meta_bytes": n_meta * (4 + 1),      # (pair id, weight)
        "n_meta_edges": n_meta,
    }
