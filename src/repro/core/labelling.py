"""Algorithm 2 (labelling scheme construction), batched over landmarks.

The paper runs one BFS per landmark with two queues: ``Q_L`` (vertices
reached via a shortest path whose interior avoids all landmarks -> get a
label) and ``Q_N`` (reached only through some landmark -> no label).  We run
all |R| BFSs as a single level-synchronous frontier program over state

    depth[R, V]    BFS depth per landmark root (INF = unvisited)
    reach_L[R, V]  "exists a shortest path from root r whose interior
                    contains no landmark" (the Q_L membership bit)

Per level every edge relays two messages: *visited* (from any frontier
vertex) and *L* (only from frontier vertices allowed as path interior:
non-landmarks, or the root itself).  Q_L-before-Q_N priority at equal depth
in the paper is exactly the OR over same-level predecessors here.

Determinism (Lemma 5.2) is structural: the program never depends on a
landmark order, which is what licenses batching/vmapping the BFSs — the
TPU analogue of the paper's thread-level parallelism (§5.3).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .frontier import FrontierEngine, make_relay
from .graph import INF, Graph


class LabellingScheme(NamedTuple):
    """Labelling scheme L = (M, L) of Definition 4.2 in dense form."""

    landmarks: jax.Array    # (R,) int32 vertex ids
    lid: jax.Array          # (V,) int32 vertex -> landmark index, -1 otherwise
    is_landmark: jax.Array  # (V,) bool
    label_dist: jax.Array   # (V, R) int32; INF where no label entry exists
    meta_w: jax.Array       # (R, R) int32 meta-graph edge weights sigma; INF = no edge
    meta_dist: jax.Array    # (R, R) int32 APSP distances d_M on the meta-graph

    @property
    def n_landmarks(self) -> int:
        return int(self.landmarks.shape[0])

    def label_valid(self) -> jax.Array:
        return self.label_dist < INF

    def packed(self, lm_dist=None):
        """The packed-HBM view of this scheme (``core.packing``): uint8 or
        uint16 by measured diameter, dtype max as the INF sentinel.  The
        int32 arrays here stay the host-side build/oracle representation;
        serving reads the packed tables (``QbSIndex.packed``)."""
        from .packing import pack_labelling
        return pack_labelling(self, lm_dist=lm_dist)


@partial(jax.jit, static_argnames=("max_levels",))
def _build_labelling_arrays(
    engine: FrontierEngine,
    landmarks: jax.Array,
    is_landmark: jax.Array,
    max_levels: int,
):
    R = landmarks.shape[0]
    V = engine.n_vertices

    depth0 = jnp.full((R, V), INF, jnp.int32).at[jnp.arange(R), landmarks].set(0)
    reach0 = jnp.zeros((R, V), bool).at[jnp.arange(R), landmarks].set(True)
    # roots may relay L-messages even though they are landmarks
    is_root = jnp.zeros((R, V), bool).at[jnp.arange(R), landmarks].set(True)
    propagate_ok = (~is_landmark)[None, :] | is_root

    def cond(carry):
        _, _, level, alive = carry
        return alive & (level < max_levels)

    def body(carry):
        depth, reach_l, level, _ = carry
        frontier = depth == level
        prop_l = frontier & reach_l & propagate_ok
        msg_vis = engine.relay(frontier)
        msg_l = engine.relay(prop_l)
        new = msg_vis & (depth == INF)
        depth = jnp.where(new, level + 1, depth)
        reach_l = reach_l | (new & msg_l)
        return depth, reach_l, level + 1, new.any()

    depth, reach_l, _, _ = jax.lax.while_loop(
        cond, body, (depth0, reach0, jnp.int32(0), jnp.bool_(True))
    )

    # Labels only for non-landmarks reached via a landmark-free path.
    valid = reach_l & (~is_landmark)[None, :]
    label_dist = jnp.where(valid, depth, INF).T.astype(jnp.int32)  # (V, R)

    # Meta edge (r_i, r_j) iff landmark j was reached from root i with the
    # L-bit set; weight = its BFS depth = d_G(r_i, r_j).
    at_land = depth[:, landmarks]          # (R, R)
    l_at_land = reach_l[:, landmarks]      # (R, R)
    meta_w = jnp.where(l_at_land, at_land, INF)
    meta_w = meta_w.at[jnp.arange(R), jnp.arange(R)].set(INF)  # no self edges
    # Determinism gives symmetry; enforce it to kill numeric asymmetry risk.
    meta_w = jnp.minimum(meta_w, meta_w.T)

    meta_dist = meta_apsp(meta_w)
    return label_dist, meta_w, meta_dist


def meta_apsp(meta_w: jax.Array) -> jax.Array:
    """Min-plus APSP (Floyd-Warshall) on the meta-graph. d_M == d_G between
    landmarks (meta edges are exact distances; every landmark-to-landmark
    shortest path splits at its interior landmarks into meta edges)."""
    R = meta_w.shape[0]
    d0 = jnp.minimum(meta_w, INF).at[jnp.arange(R), jnp.arange(R)].set(0)

    def body(k, d):
        cand = d[:, k][:, None] + d[k, :][None, :]
        return jnp.minimum(d, cand)

    d = jax.lax.fori_loop(0, R, body, d0)
    return jnp.minimum(d, INF)


def build_labelling(
    graph: Graph, landmarks: np.ndarray, *, max_levels: int = 256,
    backend: str = "segment", engine: FrontierEngine | None = None,
    **engine_kw,
) -> LabellingScheme:
    landmarks = jnp.asarray(landmarks, jnp.int32)
    R = int(landmarks.shape[0])
    V = graph.n_vertices
    is_landmark = jnp.zeros((V,), bool).at[landmarks].set(True)
    lid = jnp.full((V,), -1, jnp.int32).at[landmarks].set(jnp.arange(R, dtype=jnp.int32))
    if engine is None:
        engine = make_relay(graph, backend=backend, **engine_kw)
    label_dist, meta_w, meta_dist = _build_labelling_arrays(
        engine, landmarks, is_landmark, max_levels
    )
    return LabellingScheme(
        landmarks=landmarks,
        lid=lid,
        is_landmark=is_landmark,
        label_dist=label_dist,
        meta_w=meta_w,
        meta_dist=meta_dist,
    )


def labelling_size_bytes(scheme: LabellingScheme) -> dict:
    """Paper's size accounting (§6.1): |R| * 8 bits per vertex for L, plus
    the meta-graph.  Distances on complex networks fit 8 bits — which is
    no longer aspirational: ``packing.packed_size_bytes`` measures the
    bytes the packed tables actually occupy in HBM."""
    v = int(scheme.label_dist.shape[0])
    r = scheme.n_landmarks
    n_meta = int(np.asarray((scheme.meta_w < INF).sum()))
    return {
        "label_bytes": v * r,                # 8 bits per (vertex, landmark)
        "meta_bytes": n_meta * (4 + 1),      # (pair id, weight)
        "n_meta_edges": n_meta,
    }
