"""Query-by-Sketch facade: offline labelling + online (sketch, search) query
answering, with batched jitted execution.

Usage::

    index = QbSIndex.build(graph, n_landmarks=20)
    res = index.query(u, v)              # one SPG
    res = index.query_batch(us, vs)      # batched serving

The online path is a persistent fully-jitted pipeline: label gather ->
sketch (Eq. 3 min-plus on the Pallas kernel when ``use_pallas=True``, the
default; pure-jnp reference with ``use_pallas=False``) -> vmapped guided
search -> device-side edge-mask symmetrization through the precomputed
reverse-edge map.  Queries run in fixed-shape chunks of ``chunk`` lanes
(one jit cache entry; ragged tails are padded with a repeated query and
discarded), and each chunk costs exactly one host sync.
``query_batch_arrays`` returns the raw (dist, edge_mask) arrays for
serving; ``repro.serving.make_spg_serve_step`` exposes the jitted step
itself.  ``query_batch_legacy`` preserves the original per-chunk host
post-processing loop as the comparison baseline for benchmarks and
bit-identity tests.

Queries whose endpoint *is* a landmark are answered from the labels (the
paper leaves this corner case implicit: a landmark endpoint has no label
entries and no presence in G-).  The distance is exact from label rows +
meta-graph APSP alone — any shortest u->r path splits at its first interior
landmark r' into a labelled u->r' prefix and a meta-graph r'->r suffix, so
d(u, r) = min_i L(u, i) + d_M(i, r).  Landmark-landmark SPGs certify every
edge directly from the two label fields; one-sided queries run a single
*distance-bounded* full-graph BFS from the non-landmark endpoint (half the
relay work of the old Bi-BFS fallback) and certify against the label field
on the landmark side.  They are a |R|/|V| fraction of random queries.

All frontier relays (guided search and the landmark path's bounded BFS) go
through the pluggable ``core.frontier`` engine; ``backend=`` selects the
relay implementation at construction like ``use_pallas`` selects the
sketch kernel.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .frontier import bfs_depths, make_relay
from .graph import INF, Graph, select_landmarks
from .labelling import LabellingScheme, build_labelling
from .search import (
    Query,
    SearchResult,
    guided_search,
    make_search_context,
)
from .sketch import compute_sketch_batch


@dataclass(frozen=True)
class SPGResult:
    """One shortest-path-graph answer (host types)."""

    u: int
    v: int
    dist: int                 # INF if disconnected
    edge_ids: np.ndarray      # directed edge-slot ids, symmetrized
    d_top: int

    def edge_pairs(self, graph: Graph) -> set[tuple[int, int]]:
        s = np.asarray(graph.src)[self.edge_ids]
        d = np.asarray(graph.dst)[self.edge_ids]
        return {(int(min(a, b)), int(max(a, b))) for a, b in zip(s, d)}

    def vertices(self, graph: Graph) -> set[int]:
        s = np.asarray(graph.src)[self.edge_ids]
        d = np.asarray(graph.dst)[self.edge_ids]
        out = set(map(int, s)) | set(map(int, d))
        if self.dist == 0:
            out |= {self.u}
        return out


@jax.jit
def _symmetrize(dist, mask, rev_edge):
    """Device-side edge-mask symmetrization.  Jitted *separately* from the
    search program: fused into it, the gather makes XLA pick a slower
    layout for the loop-carried (B, E) edge mask (~25% per-chunk
    regression on CPU); as its own program the gather costs single-digit
    ms.  Module-level so all indexes share one compile cache entry —
    nothing here is instance-specific."""
    return dist, mask | mask[:, rev_edge]


def _reverse_edge_map(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    rkey = dst.astype(np.int64) * n + src.astype(np.int64)
    order = np.argsort(key, kind="stable")
    pos = np.searchsorted(key[order], rkey)
    return order[pos].astype(np.int32)


# -- landmark-endpoint serving helpers (module-level: one jit cache entry) ---


@jax.jit
def _dists_to_landmark(label_dist, meta_dist, lid, is_landmark, r_idx):
    """(V,) exact d_G(x, landmark r_idx) from label rows + meta APSP."""
    col = meta_dist[:, r_idx]                               # (R,)
    base = jnp.min(label_dist + col[None, :], axis=1)       # non-landmark rows
    at_lm = meta_dist[jnp.clip(lid, 0, None), r_idx]
    return jnp.minimum(jnp.where(is_landmark, at_lm, base), INF).astype(jnp.int32)


@jax.jit
def _certify_spg_edges(src, dst, rev_edge, du_all, dv_all, d):
    """Edge (x, y) lies on a shortest u-v path iff du(x) + 1 + dv(y) == d;
    symmetrized to both orientations like every SPG edge mask."""
    mask = (du_all[src] + 1 + dv_all[dst]) == d
    return mask | mask[rev_edge]


class QbSIndex:
    def __init__(self, graph: Graph, scheme: LabellingScheme, *,
                 max_levels: int = 512, max_chain: int = 512, chunk: int = 32,
                 use_pallas: bool = True, backend: str = "segment",
                 engine_opts: dict | None = None):
        self.graph = graph
        self.scheme = scheme
        self.max_levels = max_levels
        self.max_chain = max_chain
        self.chunk = chunk
        # Read-only records of the construction choices: the jitted pipeline
        # captures them below, so mutating these attributes has no effect —
        # rebuild the index to switch sketch paths or relay backends.
        self.use_pallas = use_pallas
        self.backend = backend

        engine_opts = engine_opts or {}
        self.ctx = make_search_context(graph, scheme, backend=backend,
                                       **engine_opts)
        # Unmasked full-graph relay for the landmark-endpoint path (those
        # shortest paths may pass *through* landmarks, so G- is wrong there).
        self._full_engine = make_relay(graph, backend=backend, **engine_opts)
        is_l = scheme.is_landmark
        self._rev_edge = _reverse_edge_map(
            np.asarray(graph.src), np.asarray(graph.dst), graph.n_vertices
        )
        self._rev_edge_j = jnp.asarray(self._rev_edge)
        self._is_landmark_np = np.asarray(is_l)
        self._lid_np = np.asarray(scheme.lid)
        self._meta_dist_np = np.asarray(scheme.meta_dist)

        v = graph.n_vertices
        searcher = partial(
            guided_search, n_vertices=v,
            max_levels=max_levels, max_chain=max_chain,
        )
        self._searcher = searcher

        def search_batch(ctx, label_dist, meta_w, meta_dist, us, vs):
            lu = label_dist[us]
            lv = label_dist[vs]
            sk = compute_sketch_batch(lu, lv, meta_w, meta_dist,
                                      use_pallas=use_pallas)
            queries = Query(
                u=us, v=vs, d_top=sk.d_top,
                du_land=sk.du_land, dv_land=sk.dv_land,
                meta_edge=sk.meta_edge,
                d_star_u=sk.d_star_u, d_star_v=sk.d_star_v,
            )
            res = jax.vmap(searcher, in_axes=(None, 0))(ctx, queries)
            return res.dist, res.edge_mask

        # Chained with the module-level _symmetrize program in serve_step:
        # two jit dispatches, everything on device, no host sync (see
        # _symmetrize for why the gather is not fused in here).
        self._search_batch = jax.jit(search_batch)
        self._run_batch_legacy_fn = None

    def serve_step(self, us, vs):
        """The persistent device pipeline for one fixed-shape query chunk:
        sketch + guided search + edge-mask symmetrization.  Takes int32
        device/host arrays ``(us, vs)`` of any fixed shape (B,) and returns
        device arrays ``(dist (B,), edge_mask (B, E) bool)`` with no host
        sync.  Public contract re-exported by
        ``repro.serving.make_spg_serve_step``; landmark-endpoint lanes are
        garbage here — ``query_batch`` answers them from the labels."""
        d, m = self._search_batch(
            self.ctx, self.scheme.label_dist, self.scheme.meta_w,
            self.scheme.meta_dist, us, vs,
        )
        return _symmetrize(d, m, self._rev_edge_j)

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, graph: Graph, n_landmarks: int = 20,
              landmarks: np.ndarray | None = None, **kw) -> "QbSIndex":
        if landmarks is None:
            landmarks = select_landmarks(graph, n_landmarks)
        scheme = build_labelling(
            graph, landmarks, backend=kw.get("backend", "segment"),
            **(kw.get("engine_opts") or {}))
        return cls(graph, scheme, **kw)

    # -- queries -------------------------------------------------------------

    def _serve_chunks(self, us: np.ndarray, vs: np.ndarray,
                      normal: np.ndarray):
        """Run the jitted pipeline over ``normal`` query indices in
        fixed-shape chunks of ``self.chunk`` lanes (ragged tails padded
        with a repeated query, pad lanes dropped).  Yields per chunk the
        host tuple (live indices, dist (L,), edge_mask (L, E)); the
        ``device_get`` per chunk is the only host sync.  Streaming chunks
        keeps peak host memory at O(chunk * E) regardless of batch size."""
        if normal.size == 0:
            return
        pad = (-normal.size) % self.chunk
        padded = np.concatenate([normal, np.repeat(normal[-1:], pad)])
        for start in range(0, padded.size, self.chunk):
            sel = padded[start:start + self.chunk]
            d, m = self.serve_step(jnp.asarray(us[sel]), jnp.asarray(vs[sel]))
            d, m = jax.device_get((d, m))
            live = min(self.chunk, normal.size - start)
            yield sel[:live], d[:live], m[:live]

    def _landmark_one(self, u: int, v: int) -> SPGResult:
        """One landmark-endpoint query answered from the labels.

        Distance is read off label rows + meta_dist (exact, see module
        docstring).  Edges: landmark-landmark queries certify from the two
        label distance fields with no search at all; one-sided queries run a
        single bounded full-graph BFS from the non-landmark endpoint.
        """
        no_edges = np.zeros((0,), np.int64)
        if u == v:
            return SPGResult(u=u, v=v, dist=0, edge_ids=no_edges, d_top=INF)
        s = self.scheme
        lu, lv = int(self._lid_np[u]), int(self._lid_np[v])
        if lu >= 0 and lv >= 0:
            d = int(min(self._meta_dist_np[lu, lv], INF))
            if d >= INF:
                return SPGResult(u=u, v=v, dist=INF, edge_ids=no_edges,
                                 d_top=INF)
            du_all = _dists_to_landmark(s.label_dist, s.meta_dist, s.lid,
                                        s.is_landmark, lu)
            dv_all = _dists_to_landmark(s.label_dist, s.meta_dist, s.lid,
                                        s.is_landmark, lv)
        else:
            # exactly one landmark endpoint r; a is the normal endpoint
            a, r_idx = (v, lu) if lu >= 0 else (u, lv)
            to_lm = _dists_to_landmark(s.label_dist, s.meta_dist, s.lid,
                                       s.is_landmark, r_idx)
            d = int(to_lm[a])
            if d >= INF:
                return SPGResult(u=u, v=v, dist=INF, edge_ids=no_edges,
                                 d_top=INF)
            depth_a = bfs_depths(self._full_engine, jnp.int32(a),
                                 self.max_levels, bound=jnp.int32(d - 1))
            # du_all = d(., u), dv_all = d(., v); undirected, so the
            # label field serves either side
            du_all, dv_all = (to_lm, depth_a) if lu >= 0 else (depth_a, to_lm)
        mask = _certify_spg_edges(self.graph.src, self.graph.dst,
                                  self._rev_edge_j, du_all, dv_all,
                                  jnp.int32(d))
        return SPGResult(u=u, v=v, dist=d,
                         edge_ids=np.flatnonzero(np.asarray(mask)), d_top=INF)

    def _landmark_fallback(self, us: np.ndarray, vs: np.ndarray,
                           lm_idx: np.ndarray) -> list[SPGResult]:
        """Label-answered landmark-endpoint queries (single place to change
        the policy for both batch entry points)."""
        return [self._landmark_one(int(us[i]), int(vs[i])) for i in lm_idx]

    def query_batch_arrays(self, us, vs) -> tuple[np.ndarray, np.ndarray]:
        """Serving fast path: answer a query batch as raw arrays
        (dist (N,) int32, edge_mask (N, E) bool, symmetrized) with no
        per-query host objects.  Landmark-endpoint queries are routed to the
        label-answered landmark path, like ``query_batch``."""
        us = np.asarray(us, np.int32).reshape(-1)
        vs = np.asarray(vs, np.int32).reshape(-1)
        landmark_q = self._is_landmark_np[us] | self._is_landmark_np[vs]
        dist = np.full((us.shape[0],), INF, np.int32)
        mask = np.zeros((us.shape[0], self.graph.n_edges), bool)
        for idx, d, m in self._serve_chunks(us, vs, np.flatnonzero(~landmark_q)):
            dist[idx] = d
            mask[idx] = m
        if landmark_q.any():
            lm_idx = np.flatnonzero(landmark_q)
            for qi, r in zip(lm_idx, self._landmark_fallback(us, vs, lm_idx)):
                dist[qi] = r.dist
                mask[qi, r.edge_ids] = True
        return dist, mask

    def query_batch(self, us, vs) -> list[SPGResult]:
        us = np.asarray(us, np.int32).reshape(-1)
        vs = np.asarray(vs, np.int32).reshape(-1)
        n = us.shape[0]
        landmark_q = self._is_landmark_np[us] | self._is_landmark_np[vs]
        normal = np.flatnonzero(~landmark_q)

        out: list[SPGResult | None] = [None] * n
        for idx, d, m in self._serve_chunks(us, vs, normal):
            for k, qi in enumerate(idx):
                out[qi] = SPGResult(
                    u=int(us[qi]), v=int(vs[qi]), dist=int(d[k]),
                    edge_ids=np.flatnonzero(m[k]),
                    d_top=int(d[k]) if d[k] < INF else INF,
                )
        if landmark_q.any():
            lm_idx = np.flatnonzero(landmark_q)
            for qi, r in zip(lm_idx, self._landmark_fallback(us, vs, lm_idx)):
                out[qi] = r
        return out  # type: ignore[return-value]

    def query(self, u: int, v: int) -> SPGResult:
        return self.query_batch([u], [v])[0]

    # -- legacy path (pre-pipeline reference; benchmarks + bit-identity) -----

    def _legacy_run_batch(self):
        if self._run_batch_legacy_fn is None:
            searcher = self._searcher

            def run_batch(ctx, label_dist, meta_w, meta_dist, us, vs):
                lu = label_dist[us]
                lv = label_dist[vs]
                sk = compute_sketch_batch(lu, lv, meta_w, meta_dist)
                queries = Query(
                    u=us, v=vs, d_top=sk.d_top,
                    du_land=sk.du_land, dv_land=sk.dv_land,
                    meta_edge=sk.meta_edge,
                    d_star_u=sk.d_star_u, d_star_v=sk.d_star_v,
                )
                return jax.vmap(searcher, in_axes=(None, 0))(ctx, queries)

            self._run_batch_legacy_fn = jax.jit(run_batch)
        return self._run_batch_legacy_fn

    def query_batch_legacy(self, us, vs) -> list[SPGResult]:
        """The seed serving loop, kept verbatim: per-chunk host gather for
        symmetrization and per-query ``np.flatnonzero`` inside the loop.
        Exists as the old-path baseline for ``benchmarks.query_time`` and as
        the bit-identity oracle for ``query_batch``."""
        us = np.asarray(us, np.int32).reshape(-1)
        vs = np.asarray(vs, np.int32).reshape(-1)
        n = us.shape[0]
        landmark_q = self._is_landmark_np[us] | self._is_landmark_np[vs]
        out: list[SPGResult | None] = [None] * n

        run = self._legacy_run_batch()
        normal = np.flatnonzero(~landmark_q)
        for start in range(0, normal.size, self.chunk):
            idx = normal[start:start + self.chunk]
            pad = self.chunk - idx.size
            cu = np.concatenate([us[idx], np.repeat(us[idx[-1:]], pad)])
            cv = np.concatenate([vs[idx], np.repeat(vs[idx[-1:]], pad)])
            res: SearchResult = run(
                self.ctx, self.scheme.label_dist, self.scheme.meta_w,
                self.scheme.meta_dist, jnp.asarray(cu), jnp.asarray(cv),
            )
            mask = np.asarray(res.edge_mask)
            mask = mask | mask[:, self._rev_edge]
            dists = np.asarray(res.dist)
            # d_top is recomputable; store dist-derived value for reporting
            for k, qi in enumerate(idx):
                out[qi] = SPGResult(
                    u=int(us[qi]), v=int(vs[qi]), dist=int(dists[k]),
                    edge_ids=np.flatnonzero(mask[k]),
                    d_top=int(dists[k]) if dists[k] < INF else INF,
                )

        if landmark_q.any():
            lm_idx = np.flatnonzero(landmark_q)
            for qi, r in zip(lm_idx, self._landmark_fallback(us, vs, lm_idx)):
                out[qi] = r
        return out  # type: ignore[return-value]
