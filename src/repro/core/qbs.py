"""Query-by-Sketch facade: offline labelling + online planner-routed
serving.

Usage::

    index = QbSIndex.build(graph, n_landmarks=20)
    res = index.query(u, v)              # one SPG
    res = index.query_batch(us, vs)      # batched serving

Online serving is a two-layer planner/executor architecture (DESIGN.md
§4).  ``serving.planner`` classifies a batch into lanes over canonical
deduplicated pairs — trivial (u == v), landmark-landmark (label-only
certify), one-sided landmark (label distance + one bounded BFS), and
general (sketch + guided search) — and ``serving.service`` executes the
lanes as fixed-shape jitted chunks with double-buffered async dispatch, an
optional LRU result cache, and an optional batch-sharded multi-device
mode.  ``query_batch`` / ``query_batch_arrays`` here are thin delegates
over a default service; this module owns the per-lane *device steps*:

* ``serve_step`` — the general lane: label gather -> sketch (Eq. 3
  min-plus on the Pallas kernel when ``use_pallas=True``, the default) ->
  vmapped guided search -> device-side edge-mask symmetrization through
  the precomputed reverse-edge map.
* ``landmark_pair_step`` / ``landmark_onesided_step`` — the vectorized
  landmark lanes.  Queries whose endpoint *is* a landmark have no label
  entries and no presence in G-, but their distance is exact from label
  rows + meta-graph APSP alone: any shortest u->r path splits at its first
  interior landmark r' into a labelled u->r' prefix and a meta-graph
  r'->r suffix, so d(u, r) = min_i L(u, i) + d_M(i, r).  Landmark-landmark
  SPGs certify every edge directly from the two label-derived distance
  fields; one-sided queries add a single *distance-bounded* full-graph BFS
  from the non-landmark endpoint, batched over the whole lane through
  ``frontier.bfs_depths_batch``.  Landmarks are the highest-degree hubs,
  so this traffic dominates under real skew — it runs as jitted
  fixed-shape lanes exactly like the general path, never a per-query host
  loop.

All frontier relays (guided search and the landmark lane's bounded BFS) go
through the pluggable ``core.frontier`` engine; ``backend=`` selects the
relay implementation at construction like ``use_pallas`` selects the
sketch kernel.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property, lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from .frontier import bfs_depths_batch, make_relay
from .graph import (
    INF,
    Graph,
    apply_edge_updates,
    edge_keys,
    edge_set,
    select_landmarks,
)
from .labelling import LabellingScheme, build_labelling, update_labelling
from .packing import pack_labelling, patch_packed, widen_dist
from .search import (
    Query,
    guided_search,
    make_search_context,
)
from .sketch import compute_sketch_batch


@dataclass(frozen=True)
class SPGResult:
    """One shortest-path-graph answer (host types)."""

    u: int
    v: int
    dist: int                 # INF if disconnected
    edge_ids: np.ndarray      # directed edge-slot ids, symmetrized
    d_top: int

    def edge_pairs(self, graph: Graph) -> set[tuple[int, int]]:
        s = np.asarray(graph.src)[self.edge_ids]
        d = np.asarray(graph.dst)[self.edge_ids]
        return {(int(min(a, b)), int(max(a, b))) for a, b in zip(s, d)}

    def vertices(self, graph: Graph) -> set[int]:
        s = np.asarray(graph.src)[self.edge_ids]
        d = np.asarray(graph.dst)[self.edge_ids]
        out = set(map(int, s)) | set(map(int, d))
        if self.dist == 0:
            out |= {self.u}
        return out


@jax.jit
def _symmetrize(dist, mask, rev_edge):
    """Device-side edge-mask symmetrization.  Jitted *separately* from the
    search program: fused into it, the gather makes XLA pick a slower
    layout for the loop-carried (B, E) edge mask (~25% per-chunk
    regression on CPU); as its own program the gather costs single-digit
    ms.  Module-level so all indexes share one compile cache entry —
    nothing here is instance-specific."""
    return dist, mask | mask[:, rev_edge]


def _reverse_edge_map(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    rkey = dst.astype(np.int64) * n + src.astype(np.int64)
    order = np.argsort(key, kind="stable")
    pos = np.searchsorted(key[order], rkey)
    return order[pos].astype(np.int32)


# -- landmark-lane device steps (module-level: one jit cache entry) ----------


@jax.jit
def _dists_to_landmark(label_dist, meta_dist, lid, is_landmark, r_idx):
    """(V,) exact d_G(x, landmark r_idx) from label rows + meta APSP.
    Dual-mode inputs: packed tables widen in-register (core.packing)."""
    label_dist = widen_dist(label_dist)
    meta_dist = widen_dist(meta_dist)
    col = meta_dist[:, r_idx]                               # (R,)
    base = jnp.min(label_dist + col[None, :], axis=1)       # non-landmark rows
    at_lm = meta_dist[jnp.clip(lid, 0, None), r_idx]
    return jnp.minimum(jnp.where(is_landmark, at_lm, base), INF).astype(jnp.int32)


@jax.jit
def _certify_spg_edges(src, dst, rev_edge, du_all, dv_all, d):
    """Edge (x, y) lies on a shortest u-v path iff du(x) + 1 + dv(y) == d;
    symmetrized to both orientations like every SPG edge mask.  The
    symmetrized mask is invariant under swapping du/dv, so callers never
    need to track which side holds the landmark."""
    mask = (du_all[src] + 1 + dv_all[dst]) == d
    return mask | mask[rev_edge]


@jax.jit
def _dists_to_landmark_batch(label_dist, meta_dist, lid, is_landmark, r_idx):
    """Vectorized lane form: (B,) landmark indices -> (B, V) distances."""
    fn = partial(_dists_to_landmark, label_dist, meta_dist, lid, is_landmark)
    return jax.vmap(fn)(r_idx)


_certify_spg_edges_batch = jax.vmap(
    _certify_spg_edges, in_axes=(None, None, None, 0, 0, 0))


@jax.jit
def _landmark_pair_lanes(lm_dist, meta_dist, src, dst, rev_edge, ru, rv):
    """Landmark-landmark lane: (B,) landmark index pairs -> (dist (B,),
    edge_mask (B, E)).  Distance is a ``meta_dist`` lookup; every SPG edge
    certifies from two rows of the precomputed (R, V) landmark-distance
    table ``lm_dist`` — no search, no per-chunk recomputation.  Both tables
    arrive packed; only the gathered rows widen (in registers)."""
    d = jnp.minimum(widen_dist(meta_dist[ru, rv]), INF).astype(jnp.int32)
    mask = _certify_spg_edges_batch(src, dst, rev_edge,
                                    widen_dist(lm_dist[ru]),
                                    widen_dist(lm_dist[rv]), d)
    return d, mask & (d < INF)[:, None]


@partial(jax.jit, static_argnames=("max_levels",))
def _landmark_onesided_lanes(engine, lm_dist, src, dst, rev_edge,
                             roots, r_idx, *, max_levels: int):
    """One-sided landmark lane: (B,) non-landmark roots + (B,) landmark
    indices -> (dist (B,), edge_mask (B, E)).  One batched full-graph BFS,
    each row bounded at its own d - 1 (those shortest paths may pass
    *through* landmarks, so the G- engine is wrong here — ``engine`` is
    the unmasked full-graph relay)."""
    to_lm = widen_dist(lm_dist[r_idx])                  # (B, V)
    d = to_lm[jnp.arange(roots.shape[0]), roots]
    bounds = jnp.where(d < INF, d - 1, 0)   # disconnected rows never expand
    depth = bfs_depths_batch(engine, roots, max_levels, bounds=bounds)
    mask = _certify_spg_edges_batch(src, dst, rev_edge, to_lm, depth, d)
    return d, mask & (d < INF)[:, None]


@lru_cache(maxsize=None)
def _make_search_batch(n_vertices: int, max_levels: int, max_chain: int,
                       use_pallas: bool):
    """General-lane search program, cached on its static configuration so
    epoch-advanced indexes (``apply_update`` — same V/E capacity, new
    tables) reuse the compiled program instead of re-jitting per index."""
    searcher = partial(
        guided_search, n_vertices=n_vertices,
        max_levels=max_levels, max_chain=max_chain,
    )

    def search_batch(ctx, label_dist, meta_w, meta_dist, us, vs):
        # gather the *packed* rows from HBM; compute_sketch_batch
        # widens them (and the packed meta tables) in registers
        lu = label_dist[us]
        lv = label_dist[vs]
        sk = compute_sketch_batch(lu, lv, meta_w, meta_dist,
                                  use_pallas=use_pallas)
        queries = Query(
            u=us, v=vs, d_top=sk.d_top,
            du_land=sk.du_land, dv_land=sk.dv_land,
            meta_edge=sk.meta_edge,
            d_star_u=sk.d_star_u, d_star_v=sk.d_star_v,
        )
        res = jax.vmap(searcher, in_axes=(None, 0))(ctx, queries)
        return res.dist, res.edge_mask

    # Chained with the module-level _symmetrize program in serve_step:
    # two jit dispatches, everything on device, no host sync (see
    # _symmetrize for why the gather is not fused in here).
    return jax.jit(search_batch)


class QbSIndex:
    is_sharded = False   # replicated tables; core.sharded.ShardedIndex flips it

    def __init__(self, graph: Graph, scheme: LabellingScheme, *,
                 max_levels: int = 512, max_chain: int = 512, chunk: int = 32,
                 use_pallas: bool = True, backend: str = "segment",
                 engine_opts: dict | None = None,
                 epoch: int = 0, lm_dist=None, packed=None):
        self.graph = graph
        self.scheme = scheme
        self.max_levels = max_levels
        self.max_chain = max_chain
        self.chunk = chunk
        # Read-only records of the construction choices: the jitted pipeline
        # captures them below, so mutating these attributes has no effect —
        # rebuild the index to switch sketch paths or relay backends.
        self.use_pallas = use_pallas
        self.backend = backend
        # Epoch of the graph this index answers for (DESIGN.md §13); an
        # ``apply_update`` batch returns a new index at ``epoch + 1``,
        # stamping how the update resolved (affected set / rebuild) here.
        self.epoch = epoch
        self.last_update_info: dict = {}

        engine_opts = engine_opts or {}
        self._engine_opts = dict(engine_opts)
        # (R, V) exact vertex-to-landmark distances, a pure function of the
        # labelling — built once here so the landmark lane steps gather
        # rows instead of re-reducing the label matrix every chunk.
        # ``apply_update`` passes the incrementally-maintained table in
        # (bit-identical: both are exact BFS distances with the same INF).
        if lm_dist is None:
            lm_dist = _dists_to_landmark_batch(
                scheme.label_dist, scheme.meta_dist, scheme.lid,
                scheme.is_landmark, jnp.arange(scheme.n_landmarks))
        self._lm_dist_host = np.asarray(lm_dist, np.int32)
        # The packed label tables (uint8/uint16 + INF sentinel, dtype chosen
        # from the measured diameter — core.packing, DESIGN.md §10) are what
        # HBM holds; every jit consumer below widens gathered rows in
        # registers.  The int32 scheme stays the host-side build artifact.
        if packed is None:
            packed = pack_labelling(scheme, lm_dist=jnp.asarray(lm_dist))
        self.packed = packed
        self._lm_dist = self.packed.lm_dist
        self.ctx = make_search_context(graph, scheme, backend=backend,
                                       packed=self.packed, **engine_opts)
        # Unmasked full-graph relay for the landmark-endpoint path (those
        # shortest paths may pass *through* landmarks, so G- is wrong there).
        self._full_engine = make_relay(graph, backend=backend, **engine_opts)
        is_l = scheme.is_landmark
        self._is_landmark_np = np.asarray(is_l)
        self._lid_np = np.asarray(scheme.lid)
        self._service = None

        self._search_batch = _make_search_batch(
            graph.n_vertices, max_levels, max_chain, use_pallas)

    @cached_property
    def _rev_edge(self) -> np.ndarray:
        """Lazy: an O(E log E) host sort the epoch-advance path defers to
        first query time (update latency should not pay for it)."""
        return _reverse_edge_map(
            np.asarray(self.graph.src), np.asarray(self.graph.dst),
            self.graph.n_vertices)

    @cached_property
    def _rev_edge_j(self) -> jax.Array:
        return jnp.asarray(self._rev_edge)

    # -- per-lane device steps ----------------------------------------------

    def serve_step(self, us, vs):
        """The general lane: one fixed-shape query chunk through sketch +
        guided search + edge-mask symmetrization.  Takes int32 device/host
        arrays ``(us, vs)`` of any fixed shape (B,) and returns device
        arrays ``(dist (B,), edge_mask (B, E) bool)`` with no host sync.
        Public contract re-exported by ``repro.serving.make_spg_serve_step``;
        landmark-endpoint lanes are garbage here — the planner routes them
        to the landmark lane steps below."""
        d, m = self._search_batch(
            self.ctx, self.packed.label_dist, self.packed.meta_w,
            self.packed.meta_dist, us, vs,
        )
        return _symmetrize(d, m, self._rev_edge_j)

    def landmark_pair_step(self, ru, rv):
        """Landmark-landmark lane step: (B,) landmark-index pairs ->
        device ``(dist (B,), edge_mask (B, E))``, label-only, no sync."""
        return _landmark_pair_lanes(
            self._lm_dist, self.packed.meta_dist,
            self.graph.src, self.graph.dst, self._rev_edge_j, ru, rv)

    def landmark_onesided_step(self, roots, r_idx):
        """One-sided landmark lane step: (B,) non-landmark roots + (B,)
        landmark indices -> device ``(dist (B,), edge_mask (B, E))``; one
        batched distance-bounded full-graph BFS, no sync."""
        return _landmark_onesided_lanes(
            self._full_engine, self._lm_dist,
            self.graph.src, self.graph.dst, self._rev_edge_j,
            roots, r_idx, max_levels=self.max_levels)

    # -- dynamic updates (DESIGN.md §13) -------------------------------------

    def apply_update(self, inserts=None, deletes=None, *,
                     churn_threshold: float = 0.5) -> "QbSIndex":
        """Apply one edge-update batch and return the index for the next
        epoch (``self`` is untouched — in-flight chunks pinned to it stay
        bit-consistent with their admission epoch).

        The landmark set is pinned at epoch 0; labels are maintained by
        recomputing only the affected landmarks' BFS rows on the post-update
        graph (``labelling.update_labelling``) and patching the packed
        tables in place (``packing.patch_packed``).  Past
        ``churn_threshold`` (affected fraction of R) the incremental path
        loses to a rebuild and we rebuild outright.  Either way the new
        index's tables are bit-identical to a fresh build on the new graph
        with the same landmarks — the property-harness contract.
        """
        # Reduce the request to its effective delta (insert-of-present and
        # delete-of-absent edges are no-ops) so phantom edges never flag a
        # landmark for recompute.
        n_v = self.graph.n_vertices
        cur = edge_set(self.graph)
        present = cur[:, 0] * np.int64(n_v) + cur[:, 1]
        ins0 = edge_keys(inserts, n_v) if inserts is not None else \
            np.zeros((0,), np.int64)
        del0 = edge_keys(deletes, n_v) if deletes is not None else \
            np.zeros((0,), np.int64)
        ins = ins0[~np.isin(ins0, present)]           # insert-of-absent only
        dels = del0[np.isin(del0, present)]           # delete-of-present only
        dels = dels[~np.isin(dels, ins0)]             # inserts win a tie
        ins_arr = np.stack([ins // n_v, ins % n_v], axis=1)
        del_arr = np.stack([dels // n_v, dels % n_v], axis=1)

        graph_new = apply_edge_updates(self.graph, ins_arr, del_arr)
        scheme_new, lm_new, info = update_labelling(
            graph_new, self.scheme, self._lm_dist_host, ins_arr, del_arr,
            backend=self.backend, churn_threshold=churn_threshold,
            **self._engine_opts)
        kw = dict(max_levels=self.max_levels, max_chain=self.max_chain,
                  chunk=self.chunk, use_pallas=self.use_pallas,
                  backend=self.backend, engine_opts=self._engine_opts,
                  epoch=self.epoch + 1)
        if scheme_new is None:  # churn above threshold: full rebuild
            scheme_new = build_labelling(
                graph_new, np.asarray(self.scheme.landmarks),
                backend=self.backend, **self._engine_opts)
            new = QbSIndex(graph_new, scheme_new, **kw)
        else:
            if info["n_affected"]:
                packed_new = patch_packed(
                    self.packed, scheme_new, lm_new, info["affected"])
            else:
                packed_new = self.packed  # labels untouched; only CSR moved
            new = QbSIndex(graph_new, scheme_new, lm_dist=lm_new,
                           packed=packed_new, **kw)
        new.last_update_info = info
        return new

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, graph: Graph, n_landmarks: int = 20,
              landmarks: np.ndarray | None = None, sharded=None, **kw):
        """Build an index.  ``sharded=`` switches to the vertex-sharded
        variant (``core.sharded.ShardedIndex``): pass a
        ``jax.sharding.Mesh``, a device count, or ``True`` (all local
        devices) — labels are then *born* sharded on that mesh and every
        serving lane answers from the shards (DESIGN.md §11).  The
        sharded index takes its own serving knobs (``max_levels``,
        ``max_chain``, ``chunk``), not this class's backend/pallas ones."""
        if sharded is not None and sharded is not False:
            from .sharded import ShardedIndex
            mesh = None if sharded is True else sharded
            return ShardedIndex.build(
                graph, n_landmarks=n_landmarks, landmarks=landmarks,
                mesh=mesh, **kw)
        if landmarks is None:
            landmarks = select_landmarks(graph, n_landmarks)
        scheme = build_labelling(
            graph, landmarks, backend=kw.get("backend", "segment"),
            **(kw.get("engine_opts") or {}))
        return cls(graph, scheme, **kw)

    # -- queries (thin delegates over the planner/service) -------------------

    def make_service(self, **kw):
        """Construct a ``serving.ServingService`` over this index (async
        depth, result cache, multi-device mesh — see its docstring)."""
        from ..serving.service import ServingService
        return ServingService(self, **kw)

    def make_stream(self, *, policy=None, **kw):
        """Construct a ``serving.StreamingService``: queries arrive over
        time (``submit``/``drain``, per-query futures) and are coalesced
        into planner batches under a deadline/QoS-aware scheduler —
        adaptive chunk width, cross-batch dedup, cache-at-submit, and
        ``qos=`` classes with ``max_wait`` deadlines + weighted shares
        (DESIGN.md §5, §8).  ``kw`` passes through to the inner
        ``ServingService``."""
        from ..serving.stream import StreamingService
        return StreamingService(self, policy=policy, **kw)

    def _default_service(self):
        if self._service is None:
            self._service = self.make_service()
        return self._service

    def query_batch(self, us, vs) -> list[SPGResult]:
        return self._default_service().query_batch(us, vs)

    def query_batch_arrays(self, us, vs) -> tuple[np.ndarray, np.ndarray]:
        """Serving fast path: answer a query batch as raw arrays
        (dist (N,) int32, edge_mask (N, E) bool, symmetrized) with no
        per-query host objects."""
        return self._default_service().query_arrays(us, vs)

    def query(self, u: int, v: int) -> SPGResult:
        return self.query_batch([u], [v])[0]
