"""Query-by-Sketch facade: offline labelling + online (sketch, search) query
answering, with batched jitted execution.

Usage::

    index = QbSIndex.build(graph, n_landmarks=20)
    res = index.query(u, v)              # one SPG
    res = index.query_batch(us, vs)      # batched serving

Queries whose endpoint *is* a landmark are routed to the exact
bidirectional-BFS path (the paper leaves this corner case implicit: a
landmark endpoint has no label entries and no presence in G-).  They are a
|R|/|V| fraction of random queries.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .graph import INF, Graph, select_landmarks
from .labelling import LabellingScheme, build_labelling
from .search import Query, SearchContext, SearchResult, guided_search
from .sketch import compute_sketch_batch


@dataclass(frozen=True)
class SPGResult:
    """One shortest-path-graph answer (host types)."""

    u: int
    v: int
    dist: int                 # INF if disconnected
    edge_ids: np.ndarray      # directed edge-slot ids, symmetrized
    d_top: int

    def edge_pairs(self, graph: Graph) -> set[tuple[int, int]]:
        s = np.asarray(graph.src)[self.edge_ids]
        d = np.asarray(graph.dst)[self.edge_ids]
        return {(int(min(a, b)), int(max(a, b))) for a, b in zip(s, d)}

    def vertices(self, graph: Graph) -> set[int]:
        s = np.asarray(graph.src)[self.edge_ids]
        d = np.asarray(graph.dst)[self.edge_ids]
        out = set(map(int, s)) | set(map(int, d))
        if self.dist == 0:
            out |= {self.u}
        return out


def _reverse_edge_map(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    rkey = dst.astype(np.int64) * n + src.astype(np.int64)
    order = np.argsort(key, kind="stable")
    pos = np.searchsorted(key[order], rkey)
    return order[pos].astype(np.int32)


class QbSIndex:
    def __init__(self, graph: Graph, scheme: LabellingScheme, *,
                 max_levels: int = 512, max_chain: int = 512, chunk: int = 32):
        self.graph = graph
        self.scheme = scheme
        self.max_levels = max_levels
        self.max_chain = max_chain
        self.chunk = chunk

        is_l = scheme.is_landmark
        self.ctx = SearchContext(
            src=graph.src,
            dst=graph.dst,
            gminus_e=(~is_l[graph.src]) & (~is_l[graph.dst]),
            is_landmark=is_l,
            lid=scheme.lid,
            label_dist=scheme.label_dist,
            meta_w=scheme.meta_w,
        )
        self._rev_edge = _reverse_edge_map(
            np.asarray(graph.src), np.asarray(graph.dst), graph.n_vertices
        )
        self._is_landmark_np = np.asarray(is_l)

        v = graph.n_vertices
        searcher = partial(
            guided_search, n_vertices=v,
            max_levels=max_levels, max_chain=max_chain,
        )

        def run_batch(ctx, label_dist, meta_w, meta_dist, us, vs):
            lu = label_dist[us]
            lv = label_dist[vs]
            sk = compute_sketch_batch(lu, lv, meta_w, meta_dist)
            queries = Query(
                u=us, v=vs, d_top=sk.d_top,
                du_land=sk.du_land, dv_land=sk.dv_land,
                meta_edge=sk.meta_edge,
                d_star_u=sk.d_star_u, d_star_v=sk.d_star_v,
            )
            return jax.vmap(searcher, in_axes=(None, 0))(ctx, queries)

        self._run_batch = jax.jit(run_batch)

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, graph: Graph, n_landmarks: int = 20,
              landmarks: np.ndarray | None = None, **kw) -> "QbSIndex":
        if landmarks is None:
            landmarks = select_landmarks(graph, n_landmarks)
        scheme = build_labelling(graph, landmarks)
        return cls(graph, scheme, **kw)

    # -- queries -------------------------------------------------------------

    def query_batch(self, us, vs) -> list[SPGResult]:
        us = np.asarray(us, np.int32).reshape(-1)
        vs = np.asarray(vs, np.int32).reshape(-1)
        n = us.shape[0]
        landmark_q = self._is_landmark_np[us] | self._is_landmark_np[vs]
        out: list[SPGResult | None] = [None] * n

        normal = np.flatnonzero(~landmark_q)
        for start in range(0, normal.size, self.chunk):
            idx = normal[start:start + self.chunk]
            pad = self.chunk - idx.size
            cu = np.concatenate([us[idx], np.repeat(us[idx[-1:]], pad)])
            cv = np.concatenate([vs[idx], np.repeat(vs[idx[-1:]], pad)])
            res: SearchResult = self._run_batch(
                self.ctx, self.scheme.label_dist, self.scheme.meta_w,
                self.scheme.meta_dist, jnp.asarray(cu), jnp.asarray(cv),
            )
            mask = np.asarray(res.edge_mask)
            mask = mask | mask[:, self._rev_edge]
            dists = np.asarray(res.dist)
            # d_top is recomputable; store dist-derived value for reporting
            for k, qi in enumerate(idx):
                out[qi] = SPGResult(
                    u=int(us[qi]), v=int(vs[qi]), dist=int(dists[k]),
                    edge_ids=np.flatnonzero(mask[k]),
                    d_top=int(dists[k]) if dists[k] < INF else INF,
                )

        if landmark_q.any():
            from .baselines import bibfs_spg_batch
            lm_idx = np.flatnonzero(landmark_q)
            results = bibfs_spg_batch(self.graph, us[lm_idx], vs[lm_idx],
                                      max_levels=self.max_levels)
            for qi, r in zip(lm_idx, results):
                out[qi] = r
        return out  # type: ignore[return-value]

    def query(self, u: int, v: int) -> SPGResult:
        return self.query_batch([u], [v])[0]
