"""Vertex-sharded distributed SPG serving for graphs too large to replicate
(labels + search state sharded over the mesh; ClueWeb09: V=1.7B).

Layout (per device, under shard_map; S shards over all mesh axes):
  vertices     contiguous block [vstart, vstart+vloc), +1 garbage row
  edges        dst-owned (same ``EdgePartition`` as distributed labelling)
  labels       labels_loc (vloc, R) int16 + *edge-aligned* source-label
               copies label_src (E_loc, R) int16 — the classic edge-attribute
               trade that makes every recover-search certificate edge-local
  queries      (B,) replicated; all per-query scalars replicated via psum

Phases (mirrors core.search, see DESIGN.md §2 for the certificates):
  A  label-row extraction for (u, v): owned-else-INF + global min-reduce
  B  sketch (replicated compute, O(B R^2))
  C  sketch-bounded bidirectional BFS: per-level packed-bitmap all_gather of
     the chosen side's frontier, edge relay into local depth
  D  reverse sweep per side: one (on & depth==l) bitmap exchange per level
  E  recover: per-landmark pointwise certificates + fixed-K chain closure
     (one bitmap exchange per iteration); Delta edges fully local via the
     edge-aligned labels (min-plus over the sketch's meta edges, looped
     over queries to bound per-device temporaries)

Exact vs the replicated-label ``QbSIndex`` path (tests/test_scale_serve.py);
the dry-run lowers it at paper scale.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from .frontier import segment_or
from .graph import INF, Graph
from .labelling import LabellingScheme
from .distributed import _pack_bits, partition_edges
from .sketch import compute_sketch_batch

INF16 = np.int16(30_000)


def make_scale_serve_step(
    mesh: Mesh,
    *,
    n_vertices: int,
    v_loc: int,
    e_max: int,
    n_landmarks: int,
    batch: int,
    axis_names: tuple[str, ...] | None = None,
    max_levels: int = 32,
    max_chain: int = 8,
):
    axis_names = axis_names or tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    v, r, vloc, b = n_vertices, n_landmarks, v_loc, batch
    wloc = (vloc + 31) // 32
    spec_e = P(axis_names)
    rep = P()

    def body(src_sh, dst_sh, vstart_sh, labels_sh, lsrc_sh,
             landmarks_j, meta_w, meta_dist, us, vs):
        src_l = src_sh[0]                    # (E,) global ids
        dst_l = dst_sh[0]                    # (E,) local dst (pad = vloc)
        vst = vstart_sh[0]
        labels_loc = labels_sh[0]            # (vloc, R) int16
        label_src = lsrc_sh[0]               # (E, R) int16

        vstart_all = jax.lax.all_gather(vstart_sh, axis_names, tiled=True)

        def to_gathered(ids):
            shard = jnp.clip(
                jnp.searchsorted(vstart_all, ids, side="right") - 1,
                0, n_shards - 1)
            return shard, ids - vstart_all[shard]

        src_shard, src_off = to_gathered(src_l)
        src_word = src_shard * wloc + src_off // 32
        src_bit = (src_off % 32).astype(jnp.uint32)

        dst_glob = jnp.where(dst_l < vloc, vst + dst_l, v)  # pad -> out of range
        is_lm_src = (src_l[:, None] == landmarks_j[None, :])
        is_lm_dst = (dst_glob[:, None] == landmarks_j[None, :])
        src_lid = jnp.where(is_lm_src.any(1), jnp.argmax(is_lm_src, axis=1), -1)
        dst_lid = jnp.where(is_lm_dst.any(1), jnp.argmax(is_lm_dst, axis=1), -1)
        gm_e = (~is_lm_src.any(1)) & (~is_lm_dst.any(1)) & (dst_l < vloc)

        label_dst = jnp.concatenate(
            [labels_loc, jnp.full((1, r), INF16, jnp.int16)], axis=0
        )[dst_l].astype(jnp.int32)                         # (E, R)
        label_dst = jnp.where(label_dst >= INF16, INF, label_dst)
        label_src32 = jnp.where(label_src >= INF16, INF,
                                label_src.astype(jnp.int32))

        # ---- A: label rows -------------------------------------------------
        def fetch_rows(qs):
            loc = qs - vst
            owned = (qs >= vst) & (loc < vloc)
            rows = labels_loc[jnp.clip(loc, 0, vloc - 1)].astype(jnp.int32)
            rows = jnp.where(owned[:, None] & (rows < INF16), rows, INF)
            return jax.lax.pmin(rows, axis_names)

        lu = fetch_rows(us)                                 # (B, R)
        lv = fetch_rows(vs)

        # ---- B: sketch (replicated) ---------------------------------------
        sk = compute_sketch_batch(lu, lv, meta_w, meta_dist)
        d_top = sk.d_top

        # ---- C: bounded bidirectional BFS ----------------------------------
        def owned_depth0(qs):
            loc = qs - vst
            owned = (qs >= vst) & (loc < vloc)
            d0 = jnp.full((b, vloc + 1), INF, jnp.int32)
            idx = jnp.where(owned, loc, vloc)
            return d0.at[jnp.arange(b), idx].min(jnp.where(owned, 0, INF))

        def exchange_bits(mask_loc):
            """(B, vloc) bool -> per-edge per-query bits (B, E)."""
            packed = _pack_bits(mask_loc)                    # (B, wloc)
            full = jax.lax.all_gather(packed, axis_names, tiled=False)
            flat = jnp.moveaxis(full, 0, 1).reshape(b, n_shards * wloc)
            words = flat[:, src_word]
            return ((words >> src_bit[None, :]) & jnp.uint32(1)) > 0

        def relay(bits_be, extra_e_mask=None):
            """(B, E) bool -> (B, vloc+1) bool via the shared frontier
            primitive (dst-keyed segment-OR over the local edge shard)."""
            m = bits_be
            if extra_e_mask is not None:
                m = m & extra_e_mask[None, :]
            return segment_or(m, dst_l, vloc + 1, acc_dtype=jnp.int8)

        def psum_i(x):
            return jax.lax.psum(x, axis_names)

        depth_u0 = owned_depth0(us)
        depth_v0 = owned_depth0(vs)

        def ball_size(depth):
            return psum_i(jnp.sum(depth[:, :vloc] < INF, axis=1))

        def cond(c):
            depth_u, depth_v, du, dv, au, av, met, it = c
            active = (~met) & (du + dv < jnp.minimum(d_top, max_levels)) & (au | av)
            return psum_i(active.any().astype(jnp.int32)) > 0

        def step(c):
            depth_u, depth_v, du, dv, au, av, met, it = c
            active = (~met) & (du + dv < jnp.minimum(d_top, max_levels)) & (au | av)
            want_u = sk.d_star_u > du
            want_v = sk.d_star_v > dv
            su = ball_size(depth_u)
            sv = ball_size(depth_v)
            pick_u = jnp.where(want_u != want_v, want_u, su <= sv)
            pick_u = jnp.where(au & av, pick_u, au)

            fr_u = (depth_u[:, :vloc] == du[:, None]) & (active & pick_u)[:, None]
            fr_v = (depth_v[:, :vloc] == dv[:, None]) & (active & ~pick_u)[:, None]
            bits = exchange_bits(fr_u | fr_v)
            msg = relay(bits, gm_e)
            grow_u = (active & pick_u)[:, None]
            grow_v = (active & ~pick_u)[:, None]
            new_u = msg & (depth_u == INF) & grow_u
            new_v = msg & (depth_v == INF) & grow_v
            depth_u = jnp.where(new_u, du[:, None] + 1, depth_u)
            depth_v = jnp.where(new_v, dv[:, None] + 1, depth_v)
            any_u = psum_i(new_u[:, :vloc].any(1).astype(jnp.int32)) > 0
            any_v = psum_i(new_v[:, :vloc].any(1).astype(jnp.int32)) > 0
            au = jnp.where(active & pick_u, any_u, au)
            av = jnp.where(active & ~pick_u, any_v, av)
            du = jnp.where(active & pick_u, du + 1, du)
            dv = jnp.where(active & ~pick_u, dv + 1, dv)
            common = (depth_u[:, :vloc] < INF) & (depth_v[:, :vloc] < INF)
            met = psum_i(common.any(1).astype(jnp.int32)) > 0
            return depth_u, depth_v, du, dv, au, av, met, it + 1

        zero_b = us * 0
        true_b = us == us
        state = (depth_u0, depth_v0, zero_b, zero_b, true_b, true_b,
                 ~true_b, jnp.int32(0) + (vst * 0))
        depth_u, depth_v, du, dv, au, av, met, _ = jax.lax.while_loop(
            cond, step, state)

        common = (depth_u[:, :vloc] < INF) & (depth_v[:, :vloc] < INF)
        sums = jnp.where(common, depth_u[:, :vloc] + depth_v[:, :vloc], INF)
        d_minus = jax.lax.pmin(jnp.min(sums, axis=1), axis_names)
        dist = jnp.minimum(d_minus, d_top)
        reverse_on = met & (d_minus <= d_top)
        recover_on = (d_top < INF) & (d_top <= d_minus)
        trivial = us == vs

        w_set = common & (sums == d_minus[:, None])

        # ---- D: reverse sweeps ---------------------------------------------
        false_e = jnp.broadcast_to((gm_e & ~gm_e)[None, :],
                                   (b, src_l.shape[0]))  # varying-typed False

        def sweep(depth, d_side):
            on = jnp.concatenate([w_set, jnp.zeros((b, 1), bool)], axis=1)
            emask = false_e

            def sbody(i, carry):
                on, emask = carry
                lvl = d_side - i                       # (B,)
                send = on[:, :vloc] & (depth[:, :vloc] == lvl[:, None])
                bits = exchange_bits(send)
                cert = bits & gm_e[None, :] & (
                    depth[:, dst_l] == (lvl - 1)[:, None]) & (lvl > 0)[:, None]
                on = on | relay(cert)
                return on, emask | cert

            steps = int(max_levels)
            on, emask = jax.lax.fori_loop(0, steps, sbody, (on, emask))
            return emask

        rev_edges = sweep(depth_u, du) | sweep(depth_v, dv)

        # ---- E1: per-landmark side attachments ------------------------------
        rec_edges = false_e
        for ri in range(r):
            lcol = jnp.where(labels_loc[:, ri] >= INF16, INF,
                             labels_loc[:, ri].astype(jnp.int32))
            lcol = jnp.concatenate([lcol, jnp.full((1,), INF, jnp.int32)])
            ls_e = label_src32[:, ri]
            ld_e = label_dst[:, ri]
            for side_depth, side_land in ((depth_u, sk.du_land[:, ri]),
                                          (depth_v, sk.dv_land[:, ri])):
                sigma = side_land                        # (B,)
                on = (side_depth < INF) & (lcol[None, :] < INF) & (
                    side_depth + lcol[None, :] == sigma[:, None]) & (
                    sigma < INF)[:, None]

                def chain(i, on):
                    bits = exchange_bits(on[:, :vloc])
                    grow = bits & gm_e[None] & (ld_e == ls_e - 1)[None] & (
                        ld_e < INF)[None]
                    return on | relay(grow)

                on = jax.lax.fori_loop(0, max_chain, chain, on)
                bits = exchange_bits(on[:, :vloc])
                interior = bits & on[:, dst_l] & gm_e[None] & (
                    ld_e == ls_e - 1)[None]
                # final hops both orientations
                hop_in = bits & (dst_lid == ri)[None] & (ls_e == 1)[None]
                hop_out = (src_lid == ri)[None] & on[:, dst_l] & (ld_e == 1)[None]
                rec_edges = rec_edges | interior | hop_in | hop_out

        # ---- E2: Delta edges (fully local) ----------------------------------
        w32 = jnp.where(meta_w < INF, meta_w, INF)

        def delta_b(bi, acc):
            me = sk.meta_edge[bi]                        # (R, R)
            fin = me & (meta_w < INF)
            m2 = jnp.where(fin, -w32, INF).T.astype(jnp.int32)   # (j, i)
            t1 = jnp.min(label_dst[:, :, None] + m2[None], axis=1)  # (E, i)
            minval = jnp.min(label_src32 + t1, axis=1)
            interior = gm_e & (minval == -1)
            g1 = jnp.where(fin, w32 - 1, -1)             # (i, j)
            hop1 = (src_lid >= 0) & (
                label_dst == g1[jnp.clip(src_lid, 0)]).any(1)
            hop2 = (dst_lid >= 0) & (
                label_src32 == g1.T[jnp.clip(dst_lid, 0)]).any(1)
            direct = (src_lid >= 0) & (dst_lid >= 0) & fin[
                jnp.clip(src_lid, 0), jnp.clip(dst_lid, 0)] & (
                w32[jnp.clip(src_lid, 0), jnp.clip(dst_lid, 0)] == 1)
            return acc.at[bi].set(interior | hop1 | hop2 | direct)

        delta_edges = jax.lax.fori_loop(0, b, delta_b, false_e)

        edge_mask = ((rev_edges & reverse_on[:, None])
                     | ((rec_edges | delta_edges) & recover_on[:, None]))
        edge_mask = edge_mask & (~trivial)[:, None] & (dst_l < vloc)[None, :]
        dist = jnp.where(trivial, 0, dist)
        return edge_mask[None], dist

    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(spec_e, spec_e, spec_e, spec_e, spec_e,
                      rep, rep, rep, rep, rep),
            out_specs=(spec_e, rep),
        )
    )


def build_scale_inputs(graph: Graph, scheme: LabellingScheme, n_shards: int):
    """Host-side: partition edges and build vertex-sharded + edge-aligned
    label arrays for the scale-serve program."""
    part = partition_edges(graph, n_shards)
    labels = np.asarray(scheme.label_dist)
    labels16 = np.where(labels >= INF, INF16, labels).astype(np.int16)
    v = graph.n_vertices
    r = labels.shape[1]
    vloc = part.v_loc
    vend = np.concatenate([part.vstart[1:], [v]])
    labels_sh = np.full((n_shards, vloc, r), INF16, np.int16)
    for s in range(n_shards):
        n_loc = vend[s] - part.vstart[s]
        labels_sh[s, :n_loc] = labels16[part.vstart[s]:vend[s]]
    lsrc = labels16[np.clip(part.src, 0, v - 1)]   # (S, E, R)
    return part, labels_sh, lsrc


def scale_serve(graph: Graph, scheme: LabellingScheme, mesh: Mesh, us, vs,
                **kw):
    """Run the vertex-sharded serving step on a real graph (test path).
    Returns (set of undirected edge pairs per query, dist array)."""
    axis_names = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    part, labels_sh, lsrc = build_scale_inputs(graph, scheme, n_shards)
    us = np.asarray(us, np.int32)
    step = make_scale_serve_step(
        mesh, n_vertices=graph.n_vertices, v_loc=part.v_loc,
        e_max=part.e_max, n_landmarks=scheme.n_landmarks,
        batch=us.shape[0], **kw)
    mask_sh, dist = step(
        jnp.asarray(part.src), jnp.asarray(part.dst_local),
        jnp.asarray(part.vstart), jnp.asarray(labels_sh), jnp.asarray(lsrc),
        scheme.landmarks, scheme.meta_w, scheme.meta_dist,
        jnp.asarray(us), jnp.asarray(vs, jnp.int32))
    mask_np = np.asarray(mask_sh)      # (S, B, E)
    dist = np.asarray(dist)
    vend = np.concatenate([part.vstart[1:], [graph.n_vertices]])
    pairs = [set() for _ in range(us.shape[0])]
    for s in range(n_shards):
        dst_glob = part.dst_local[s] + part.vstart[s]
        valid = part.dst_local[s] < part.v_loc
        for b in range(us.shape[0]):
            sel = mask_np[s, b] & valid
            for a_, c_ in zip(part.src[s][sel], dst_glob[sel]):
                pairs[b].add((int(min(a_, c_)), int(max(a_, c_))))
    return pairs, dist
