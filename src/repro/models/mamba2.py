"""Mamba2 (SSD) block in the chunked matmul form.

The recurrence  h_t = a_t * h_{t-1} + dt_t * B_t x_t^T,  y_t = C_t h_t + D x_t
(scalar decay a_t per head, as in Mamba2) is evaluated as:

  * intra-chunk: a masked decay-weighted (C_t . B_s) attention-like matmul
  * inter-chunk: an O(n_chunks) scan over per-chunk summarized states

which keeps the MXU busy instead of emitting a length-S sequential loop —
the standard TPU-native SSD decomposition.  The single-step ``decode`` path
updates the (heads, head_dim, state) recurrent state directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, dtype_of


def ssm_dims(cfg):
    d_inner = cfg.d_model * cfg.ssm_expand
    heads = cfg.ssm_heads or max(1, d_inner // 64)
    hd = d_inner // heads
    return d_inner, heads, hd


def init_mamba2(key, cfg) -> dict:
    d_inner, heads, hd = ssm_dims(cfg)
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg.dtype)
    d = cfg.d_model
    return {
        # projections: [z | x | B | C | dt]
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * n * heads + heads, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_inner), jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((d_inner,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads, dtype=jnp.float32)),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm_g": jnp.ones((d_inner,), dt),
        "out_proj": dense_init(ks[2], d_inner, d, dt),
    }


def _split_proj(p, x, cfg):
    d_inner, heads, hd = ssm_dims(cfg)
    n = cfg.ssm_state
    zxbcdt = x @ p["in_proj"]
    z, xs, bc, dt_ = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + 2 * n * heads], axis=-1
    )
    b_, s = x.shape[0], x.shape[1]
    bmat = bc[..., : n * heads].reshape(b_, s, heads, n)
    cmat = bc[..., n * heads:].reshape(b_, s, heads, n)
    dt_ = jax.nn.softplus(dt_.astype(jnp.float32) + p["dt_bias"])  # (B, S, H)
    return z, xs, bmat, cmat, dt_


def _conv(p, xs, cfg, conv_state=None):
    """Short causal depthwise conv; returns (out, new_conv_state)."""
    k = cfg.ssm_conv
    if conv_state is None:
        pad = jnp.zeros((xs.shape[0], k - 1, xs.shape[-1]), xs.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xs], axis=1)
    new_state = xp[:, -(k - 1):, :]
    w = p["conv_w"]
    out = sum(xp[:, i: xp.shape[1] - (k - 1) + i, :] * w[i] for i in range(k))
    return jax.nn.silu(out + p["conv_b"]), new_state


def mamba2_chunked(p: dict, x: jax.Array, cfg, chunk: int = 256,
                   state=None, return_state: bool = False):
    """x: (B, S, D). Optional initial state (B, H, hd, N)."""
    d_inner, heads, hd = ssm_dims(cfg)
    n = cfg.ssm_state
    b_, s, _ = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    z, xs, bmat, cmat, dt_ = _split_proj(p, x, cfg)
    conv_state = None if state is None else state["conv"]
    xs, new_conv = _conv(p, xs, cfg, conv_state)
    xh = xs.reshape(b_, s, heads, hd).astype(jnp.float32)

    a = -jnp.exp(p["a_log"])                       # (H,) negative
    la = dt_ * a[None, None, :]                    # log decay per step (B,S,H)
    la = la.reshape(b_, nc, chunk, heads)
    dt_c = dt_.reshape(b_, nc, chunk, heads)
    xc = xh.reshape(b_, nc, chunk, heads, hd)
    bc_ = bmat.reshape(b_, nc, chunk, heads, n).astype(jnp.float32)
    cc = cmat.reshape(b_, nc, chunk, heads, n).astype(jnp.float32)

    cum = jnp.cumsum(la, axis=2)                   # (B,nc,L,H) log decay to t
    # intra-chunk: y[t] += sum_{s<=t} exp(cum[t]-cum[s]) dt[s] (C_t.B_s) x[s]
    scores = jnp.einsum("bnlhs,bnmhs->bnhlm", cc, bc_)          # (B,nc,H,L,L)
    decay = cum[..., :, None, :] - cum[..., None, :, :]          # (B,nc,L,L,H)
    decay = jnp.moveaxis(decay, -1, 2)                           # (B,nc,H,L,L)
    li = jnp.arange(chunk)
    mask = li[:, None] >= li[None, :]
    # mask the *exponent* (not the product): exp of the masked upper triangle
    # would overflow and poison gradients through 0 * inf
    decay = jnp.where(mask[None, None, None], decay, -1e9)
    w = jnp.exp(decay) * scores
    y = jnp.einsum("bnhlm,bnmh,bnmhd->bnlhd", w, dt_c, xc)

    # chunk summary states and inter-chunk scan
    tail = cum[..., -1:, :] - cum                                # decay to end
    gk = jnp.exp(tail)                                           # (B,nc,L,H)
    chunk_state = jnp.einsum("bnlh,bnlh,bnlhs,bnlhd->bnhds",
                             gk, dt_c, bc_, xc)                  # (B,nc,H,hd,N)
    chunk_decay = jnp.exp(cum[..., -1, :])                       # (B,nc,H)

    s0 = jnp.zeros((b_, heads, hd, n), jnp.float32) if state is None \
        else state["ssm"].astype(jnp.float32)

    def scan_fn(h, inp):
        cs, cd = inp
        h_out = h                                   # state entering this chunk
        h = h * cd[:, :, None, None] + cs
        return h, h_out

    (h_last, h_in) = jax.lax.scan(
        scan_fn,
        s0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                              # (B,nc,H,hd,N)
    # inter-chunk contribution: C_t . (decay_to_t * h_in)
    y = y + jnp.einsum("bnlhs,bnlh,bnhds->bnlhd", cc, jnp.exp(cum), h_in)

    y = y + p["d_skip"][None, None, :, None] * xc.reshape(b_, nc, chunk, heads, hd)
    y = y.reshape(b_, s, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    # grouped RMS norm
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + cfg.norm_eps)
    y = (y * p["norm_g"].astype(jnp.float32)).astype(x.dtype)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"ssm": h_last.astype(jnp.float32), "conv": new_conv}
    return out


def mamba2_decode(p: dict, x: jax.Array, cfg, state):
    """One-step recurrence. x: (B, 1, D); state {ssm (B,H,hd,N), conv}."""
    d_inner, heads, hd = ssm_dims(cfg)
    z, xs, bmat, cmat, dt_ = _split_proj(p, x, cfg)
    xs, new_conv = _conv(p, xs, cfg, state["conv"])
    xh = xs.reshape(x.shape[0], heads, hd).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt_[:, 0, :] * a[None, :])                   # (B,H)
    bm = bmat[:, 0].astype(jnp.float32)                          # (B,H,N)
    cm = cmat[:, 0].astype(jnp.float32)
    h = state["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhd->bhdn", dt_[:, 0], bm, xh
    )
    y = jnp.einsum("bhn,bhdn->bhd", cm, h)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(x.shape[0], 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + cfg.norm_eps)
    y = (y * p["norm_g"].astype(jnp.float32)).astype(x.dtype)
    return y @ p["out_proj"], {"ssm": h, "conv": new_conv}
