"""Shared transformer primitives: RMSNorm, RoPE, GQA attention (train /
prefill / single-step decode with KV cache), SwiGLU MLP, init helpers.

Pure-functional: params are plain dict pytrees; layer stacks are *stacked*
along a leading axis and consumed with ``lax.scan`` so the HLO is O(1) in
depth (critical for 62 big-model CPU compiles and for real compile times at
1000+ nodes).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gain: jax.Array, eps: float = 1e-5) -> jax.Array:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * gain.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> Params:
    hd = cfg.hd
    ks = jax.random.split(key, 5)
    dt = dtype_of(cfg.dtype)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    return p


def _qkv(p: Params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    b, s, _ = x.shape
    hd = cfg.hd
    return (
        q.reshape(b, s, cfg.n_heads, hd),
        k.reshape(b, s, cfg.n_kv_heads, hd),
        v.reshape(b, s, cfg.n_kv_heads, hd),
    )


def _sdpa(q, k, v, causal: bool, q_offset: jax.Array | int = 0):
    """q: (B, Sq, H, hd); k/v: (B, Sk, Hkv, hd) — GQA via head grouping."""
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(sk)[None, :]
        mask = qi >= ki
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _sdpa_chunked(q, k, v, causal: bool, chunk: int, unroll: bool = False):
    """Online-softmax attention over KV chunks (flash-style memory profile:
    logits tiles are (Sq, chunk) instead of (Sq, Sk)).  f32 accumulators."""
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    chunk = min(chunk, sk)
    n_chunks = sk // chunk
    assert n_chunks * chunk == sk, (sk, chunk)
    qg = q.reshape(b, sq, hkv, group, hd).astype(jnp.float32)
    scale = hd ** -0.5
    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk, hkv, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk, hkv, hd), 1, 0)
    qi = jnp.arange(sq)

    def body(carry, inp):
        acc, m, l, c_idx = carry
        k_c, v_c = inp
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_c.astype(jnp.float32)) * scale
        if causal:
            ki = c_idx * chunk + jnp.arange(chunk)
            mask = qi[:, None] >= ki[None, :]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p_ = jnp.exp(logits - m_new[..., None])
        l = l * alpha + p_.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p_, v_c.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l, c_idx + 1), None

    acc0 = jnp.zeros((b, hkv, group, sq, hd), jnp.float32)
    m0 = jnp.full((b, hkv, group, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    # unroll follows the depth-probe flag so HloCostAnalysis sees every chunk
    (acc, m, l, _), _ = jax.lax.scan(body, (acc0, m0, l0, jnp.int32(0)), (kc, vc),
                                     unroll=unroll)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out.reshape(b, h, sq, hd), 1, 2)
    return out.astype(q.dtype)


def attention(p: Params, x: jax.Array, cfg, *, causal: bool) -> jax.Array:
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    pos = jnp.arange(s)[None, :]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    if getattr(cfg, "attn_impl", "naive") == "chunked" and s > cfg.attn_chunk:
        out = _sdpa_chunked(q, k, v, causal, cfg.attn_chunk,
                            unroll=getattr(cfg, "scan_unroll", False))
    else:
        out = _sdpa(q, k, v, causal)
    return out.reshape(b, s, -1) @ p["wo"]


def attention_prefill(p: Params, x: jax.Array, cfg):
    """Returns (out, cache) where cache = (k, v) laid out (B, S, Hkv, hd)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    pos = jnp.arange(s)[None, :]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    out = _sdpa(q, k, v, causal=True)
    return out.reshape(b, s, -1) @ p["wo"], (k, v)


def attention_decode(p: Params, x: jax.Array, cache, cache_len: jax.Array, cfg):
    """One decoded token against a filled KV cache.

    x: (B, 1, D); cache: (k, v) each (B, S_max, Hkv, hd) possibly quantized;
    cache_len: () int32 — number of valid cache positions.
    """
    b, _, _ = x.shape
    q, k_new, v_new = _qkv(p, x, cfg)
    q = apply_rope(q, cache_len[None, None].astype(jnp.int32) * jnp.ones((b, 1), jnp.int32), cfg.rope_theta)
    k_new = apply_rope(k_new, cache_len[None, None] * jnp.ones((b, 1), jnp.int32), cfg.rope_theta)
    k_cache, v_cache = cache
    k_all, v_all = _cache_append(k_cache, v_cache, k_new, v_new, cache_len)

    kd = _dequant(k_all, k_new.dtype)
    vd = _dequant(v_all, v_new.dtype)
    sk = kd.shape[1]
    # mask out unwritten cache slots
    valid = jnp.arange(sk)[None, :] <= cache_len
    big_neg = jnp.float32(-1e30)
    b_, sq, h, hd = q.shape
    hkv = kd.shape[2]
    group = h // hkv
    qg = q.reshape(b_, sq, hkv, group, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        kd.astype(jnp.float32)) / (hd ** 0.5)
    logits = jnp.where(valid[:, None, None, None, :], logits, big_neg)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, vd.astype(jnp.float32))
    out = out.reshape(b_, sq, h * hd).astype(x.dtype)
    return out @ p["wo"], (k_all, v_all)


# -- KV cache quantization ---------------------------------------------------

def make_kv_cache(b: int, s_max: int, hkv: int, hd: int, dtype, quantized: bool):
    if quantized:
        return (
            {"q": jnp.zeros((b, s_max, hkv, hd), jnp.int8),
             "scale": jnp.zeros((b, s_max, hkv, 1), jnp.float32)},
            {"q": jnp.zeros((b, s_max, hkv, hd), jnp.int8),
             "scale": jnp.zeros((b, s_max, hkv, 1), jnp.float32)},
        )
    return (
        jnp.zeros((b, s_max, hkv, hd), dtype),
        jnp.zeros((b, s_max, hkv, hd), dtype),
    )


def _quant(x: jax.Array):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0 + 1e-8
    return {"q": jnp.round(x / scale).astype(jnp.int8), "scale": scale}


def _dequant(c, dtype):
    if isinstance(c, dict):
        return (c["q"].astype(jnp.float32) * c["scale"]).astype(dtype)
    return c


def _cache_append(k_cache, v_cache, k_new, v_new, cache_len):
    if isinstance(k_cache, dict):
        kq = _quant(k_new)
        vq = _quant(v_new)
        k_cache = {
            "q": jax.lax.dynamic_update_slice_in_dim(k_cache["q"], kq["q"], cache_len, 1),
            "scale": jax.lax.dynamic_update_slice_in_dim(k_cache["scale"], kq["scale"], cache_len, 1),
        }
        v_cache = {
            "q": jax.lax.dynamic_update_slice_in_dim(v_cache["q"], vq["q"], cache_len, 1),
            "scale": jax.lax.dynamic_update_slice_in_dim(v_cache["scale"], vq["scale"], cache_len, 1),
        }
        return k_cache, v_cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), cache_len, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), cache_len, 1)
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: int | None = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = dtype_of(cfg.dtype)
    return {
        "wg": dense_init(ks[0], cfg.d_model, d_ff, dt),
        "wu": dense_init(ks[1], cfg.d_model, d_ff, dt),
        "wd": dense_init(ks[2], d_ff, cfg.d_model, dt),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
