"""Model configuration for the assigned architecture pool."""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "onehot"   # onehot (GShard baseline) | sort (optimized)
    moe_group_size: int = 0        # tokens per dispatch group (0 = one global
                                   # group = naive GShard; 1024 = optimized)
    # Anchor MoE expert buffers to EP sharding (axis "model") so GSPMD lowers
    # the sort-dispatch scatter locally instead of replicating it.
    moe_ep_anchor: bool = False
    # Attention implementation: "naive" materializes (S, S) logits (baseline);
    # "chunked" = online-softmax over KV chunks (flash-style memory profile).
    attn_impl: str = "naive"
    attn_chunk: int = 512
    # "layer": jax.checkpoint each scan body — saves only per-layer inputs,
    # recomputes activations in backward.  "none" stashes everything.
    remat_policy: str = "none"
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0           # 0 -> d_model * expand // 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # hybrid (zamba2-style shared attention block)
    hybrid_period: int = 0       # apply shared attn block every k core layers
    # rwkv6
    rwkv_head_dim: int = 64
    # structure
    encoder_only: bool = False
    frontend: str = "none"       # none | audio_frames | vision_patches
    frontend_dim: int = 0        # stub modality embedding width
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # Fully unroll layer scans (dry-run depth probes: makes per-layer cost
    # visible to HloCostAnalysis, which visits a while-loop body only once).
    scan_unroll: bool = False
    # Per-block activation sharding anchor, e.g. (("pod","data"), None, None)
    # for Megatron-style DP-only activations or (("pod","data"), "model", None)
    # for sequence-parallel.  None = let GSPMD choose (baseline).
    act_spec: tuple | None = None

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """May run the long_500k decode shape (SSM/hybrid/linear-attn)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            n_layers=2 if self.hybrid_period == 0 else 2 * self.hybrid_period,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab_size=128,
            head_dim=16,
        )
        if self.moe_experts:
            base["moe_experts"] = 4
            base["moe_top_k"] = min(self.moe_top_k, 2)
        if self.ssm_state:
            base["ssm_state"] = 16
            base["ssm_heads"] = 4
        if self.frontend_dim:
            base["frontend_dim"] = 32
        base.update(overrides)
        return replace(self, **base)


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the assigned grid."""

    name: str              # train_4k | prefill_32k | decode_32k | long_500k
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """The brief's skip rules; reason recorded in EXPERIMENTS.md."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: no sub-quadratic path at 500k"
    return True, ""
