"""RWKV-6 "Finch" block: time-mix with data-dependent per-channel decay and
channel-mix, in chunked matmul form.

Recurrence (per head, d_k x d_v state S):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Chunked evaluation: within a chunk of length L the contribution of step s to
step t (s < t) carries the decay  prod_{s<r<t} w_r  (note: *exclusive* of t
— y_t reads S_{t-1}), which factorizes as  cumw_{t-1} / cumw_s  so

    y_t = (r_t . cw_t) @ sum_s ((k_s / cw'_s) v_s^T)   (masked, per chunk)
        + bonus diag(u) current-token term
        + (r_t . cw_t) @ S_chunk_in

with f32 internals and L = 64 to bound the dynamic range of the cumulative
decays (the flash-linear-attention recipe).  Decode is the plain one-step
recurrence.  Data-dependent decay w_t = exp(-exp(w0 + lora(x_t))) and the
token-shift mixers follow the RWKV-6 formulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, dtype_of


def rwkv_dims(cfg):
    heads = cfg.d_model // cfg.rwkv_head_dim
    return heads, cfg.rwkv_head_dim


def init_rwkv6(key, cfg) -> dict:
    d = cfg.d_model
    heads, hd = rwkv_dims(cfg)
    ks = jax.random.split(key, 12)
    dt = dtype_of(cfg.dtype)
    lora = 64
    return {
        # token-shift interpolation weights (5 mixers: r,k,v,w,g)
        "mix": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(jnp.float32),
        "wr": dense_init(ks[1], d, d, dt),
        "wk": dense_init(ks[2], d, d, dt),
        "wv": dense_init(ks[3], d, d, dt),
        "wg": dense_init(ks[4], d, d, dt),
        "wo": dense_init(ks[5], d, d, dt),
        "w0": jnp.zeros((d,), jnp.float32) - 0.6,
        "w_lora_a": dense_init(ks[6], d, lora, jnp.float32, scale=0.01),
        "w_lora_b": dense_init(ks[7], lora, d, jnp.float32, scale=0.01),
        "u": (jax.random.normal(ks[8], (heads, hd), jnp.float32) * 0.1),
        "ln_g": jnp.ones((d,), jnp.float32),
        # channel mix
        "ck": dense_init(ks[9], d, cfg.d_ff, dt),
        "cv": dense_init(ks[10], cfg.d_ff, d, dt),
        "cr": dense_init(ks[11], d, d, dt),
        "cmix": jnp.full((2, d), 0.5, jnp.float32),
    }


def _token_shift(x, last):
    """shift right by one; ``last`` (B, 1, D) is the previous block state."""
    return jnp.concatenate([last, x[:, :-1, :]], axis=1)


def _time_mix_inputs(p, x, last):
    xs = _token_shift(x, last)
    mix = p["mix"][:, None, None, :]
    feats = x[None] * mix + xs[None] * (1.0 - mix)   # (5, B, S, D)
    r = feats[0] @ p["wr"]
    k = feats[1] @ p["wk"]
    v = feats[2] @ p["wv"]
    g = feats[4] @ p["wg"]
    wln = jnp.tanh(feats[3].astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    # log decay in [-e, -3e-4]: bounded so a 64-step chunk's cumulative decay
    # range (<= 64 * e ~ 174) stays factorizable in f32 after centering.
    logw = -jnp.exp(jnp.clip(p["w0"] + wln, -8.0, 1.0))
    return r, k, v, g, logw


def rwkv6_time_mix(p: dict, x: jax.Array, cfg, chunk: int = 64,
                   state=None, return_state: bool = False):
    b_, s, d = x.shape
    heads, hd = rwkv_dims(cfg)
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    last = jnp.zeros((b_, 1, d), x.dtype) if state is None else state["shift"]
    r, k, v, g, logw = _time_mix_inputs(p, x, last)

    rh = r.reshape(b_, nc, chunk, heads, hd).astype(jnp.float32)
    kh = k.reshape(b_, nc, chunk, heads, hd).astype(jnp.float32)
    vh = v.reshape(b_, nc, chunk, heads, hd).astype(jnp.float32)
    lw = logw.reshape(b_, nc, chunk, heads, hd)

    cum = jnp.cumsum(lw, axis=2)          # inclusive log cumdecay within chunk
    cum_ex = cum - lw                     # exclusive (decay applied before t)
    # intra-chunk pair decay: exp(cum_ex[t] - cum[s])  (<= 1, but the naive
    # exp(cum_ex) * exp(-cum) factors overflow f32 for strong decays).
    # Center both exponents by half the chunk's total log decay so each
    # factor is bounded by exp(range/2) <= exp(87); clip for safety margin —
    # clipped terms correspond to pair decays < e^-160 ~ 0.
    shift = 0.5 * cum[..., -1:, :, :]                          # (B,nc,1,H,hd)
    q_dec = rh * jnp.exp(jnp.clip(cum_ex - shift, -80.0, 80.0))
    k_dec = kh * jnp.exp(jnp.clip(shift - cum, -80.0, 80.0))
    scores = jnp.einsum("bnlhd,bnmhd->bnhlm", q_dec, k_dec)
    li = jnp.arange(chunk)
    mask = li[:, None] > li[None, :]                           # strict s < t
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    y = jnp.einsum("bnhlm,bnmhd->bnlhd", scores, vh)
    # bonus current-token term: r_t . diag(u) k_t v_t
    bonus = jnp.einsum("bnlhd,hd,bnlhd->bnlh", rh, p["u"], kh)
    y = y + bonus[..., None] * vh

    # inter-chunk state scan: S_chunk_end = diag(prod w) S_in + sum_s decay k_s v_s
    tail = cum[..., -1:, :, :] - cum                            # decay s -> end
    kv = jnp.einsum("bnlhd,bnlhe->bnhde", kh * jnp.exp(tail), vh)  # (B,nc,H,hd,hd)
    cdecay = jnp.exp(cum[..., -1, :, :])                        # (B,nc,H,hd)

    s0 = jnp.zeros((b_, heads, hd, hd), jnp.float32) if state is None \
        else state["wkv"].astype(jnp.float32)

    def scan_fn(h, inp):
        kv_c, dec_c = inp
        h_in = h
        h = h * dec_c[:, :, :, None] + kv_c
        return h, h_in

    h_last, h_in = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(kv, 1, 0), jnp.moveaxis(cdecay, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                             # (B,nc,H,hd,hd)
    # inter-chunk readout uses the *uncentered* decay (<= 1, overflow-free)
    q_inter = rh * jnp.exp(cum_ex)
    y = y + jnp.einsum("bnlhd,bnhde->bnlhe", q_inter, h_in)

    y = y.reshape(b_, s, d)
    # group norm per head then gate
    yh = y.reshape(b_, s, heads, hd)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, axis=-1, keepdims=True) + cfg.norm_eps)
    y = (yh.reshape(b_, s, d) * p["ln_g"]) * jax.nn.silu(g.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["wo"]
    if return_state:
        return out, {"wkv": h_last, "shift": x[:, -1:, :]}
    return out


def rwkv6_time_mix_decode(p: dict, x: jax.Array, cfg, state):
    """x: (B, 1, D); state {wkv (B,H,hd,hd), shift (B,1,D)}."""
    b_, _, d = x.shape
    heads, hd = rwkv_dims(cfg)
    r, k, v, g, logw = _time_mix_inputs(p, x, state["shift"])
    rh = r.reshape(b_, heads, hd).astype(jnp.float32)
    kh = k.reshape(b_, heads, hd).astype(jnp.float32)
    vh = v.reshape(b_, heads, hd).astype(jnp.float32)
    w = jnp.exp(logw[:, 0].reshape(b_, heads, hd))
    s_prev = state["wkv"]
    y = jnp.einsum("bhd,bhde->bhe", rh, s_prev) + \
        jnp.einsum("bhd,hd,bhd,bhe->bhe", rh, p["u"], kh, vh)
    s_new = s_prev * w[..., None] + jnp.einsum("bhd,bhe->bhde", kh, vh)
    yh = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + cfg.norm_eps)
    yv = (yh.reshape(b_, 1, d) * p["ln_g"]) * jax.nn.silu(g.astype(jnp.float32))
    out = yv.astype(x.dtype) @ p["wo"]
    return out, {"wkv": s_new, "shift": x}


def rwkv6_channel_mix(p: dict, x: jax.Array, state=None, return_state: bool = False):
    b_, s, d = x.shape
    last = jnp.zeros((b_, 1, d), x.dtype) if state is None else state
    xs = _token_shift(x, last)
    mix = p["cmix"][:, None, None, :]
    fk = x * mix[0].astype(x.dtype) + xs * (1 - mix[0]).astype(x.dtype)
    fr = x * mix[1].astype(x.dtype) + xs * (1 - mix[1]).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(fk @ p["ck"]))
    out = jax.nn.sigmoid((fr @ p["cr"]).astype(jnp.float32)).astype(x.dtype) * (kk @ p["cv"])
    if return_state:
        return out, x[:, -1:, :]
    return out
