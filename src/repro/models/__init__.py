"""Architecture zoo: dense/MoE GQA transformers, Mamba2 SSD, RWKV6,
zamba2-style hybrid, encoder-only audio, VLM — all scan-over-layers."""
from .config import SHAPES, ModelConfig, ShapeCell, cell_applicable
from .registry import (
    Model,
    batch_pspecs,
    build_model,
    cache_pspecs,
    input_specs,
    param_pspecs,
    sanitize_pspecs,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeCell",
    "cell_applicable",
    "Model",
    "batch_pspecs",
    "build_model",
    "cache_pspecs",
    "input_specs",
    "param_pspecs",
    "sanitize_pspecs",
]
