"""Model facade + sharding rules + ShapeDtypeStruct input specs.

``build_model(cfg)`` returns pure functions; ``param_pspecs`` /
``batch_pspecs`` / ``cache_pspecs`` give the PartitionSpec trees used by the
launcher (Megatron-style TP on ``model``, DP over the remaining axes, EP for
MoE experts, recurrent-state sharding for SSM families).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import transformer as T
from .config import ModelConfig, ShapeCell

N_VLM_PATCHES = 256  # static patch-prefix length for the [vlm] stub frontend


class Model(NamedTuple):
    cfg: ModelConfig
    init: Any
    loss: Any
    forward: Any
    prefill: Any
    decode: Any
    init_decode_cache: Any


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=partial(T.init_params, cfg=cfg),
        loss=partial(T.loss_fn, cfg=cfg),
        forward=partial(T.forward, cfg=cfg),
        prefill=partial(T.prefill, cfg=cfg),
        decode=partial(T.decode_step, cfg=cfg),
        init_decode_cache=partial(T.init_decode_cache, cfg),
    )


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

_COL = {"wq", "wk", "wv", "wg", "wu", "ck", "cr", "in_proj", "head",
        "frontend", "conv_w", "wr"}
_ROW = {"wo", "wd", "cv", "out_proj"}
_BIAS_TP = {"bq", "bk", "bv"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def param_pspecs(cfg: ModelConfig, params) -> Any:
    """PartitionSpec tree for params (TP on 'model'; leading stack dims
    replicated). MoE expert tensors are expert-sharded (EP == TP axis)."""

    def rule(path, leaf):
        name = _leaf_name(path)
        keys = [str(e.key) for e in path if hasattr(e, "key")]
        nd = leaf.ndim
        if name == "embed":
            return P("model", None)
        if "moe" in keys and name in {"wg", "wu", "wd"}:
            return P(*([None] * (nd - 3) + ["model", None, None]))
        if name in _COL:
            return P(*([None] * (nd - 2) + [None, "model"]))
        if name in _ROW:
            return P(*([None] * (nd - 2) + ["model", None]))
        if name in _BIAS_TP:
            return P(*([None] * (nd - 1) + ["model"]))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, params)


def batch_pspecs(cfg: ModelConfig, batch, dp_axes) -> Any:
    def rule(path, leaf):
        return P(*([dp_axes] + [None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch)


def cache_pspecs(cfg: ModelConfig, cache, dp_axes) -> Any:
    """KV caches: batch on DP, head_dim on 'model' (always divisible, unlike
    kv-head counts e.g. qwen32b kv=40 on TP16); SSM/RWKV states: heads on
    'model'."""

    def full_rule(path, leaf):
        # Caches are stacked along leading lax.scan layer dims; classify by
        # the trailing 3/4 dims and replicate the stack dims.
        name = _leaf_name(path)
        nd = leaf.ndim
        if name in {"shift", "cm", "conv"}:              # (..., B, k, D) states
            return P(*([None] * (nd - 3) + [dp_axes, None, "model"]))
        if nd >= 4 and name in {"wkv", "ssm"}:          # (B, H, hd, {hd|N})
            return P(*([None] * (nd - 4) + [dp_axes, "model", None, None]))
        if nd >= 4 and name == "scale":                  # int8 KV scales (B,S,Hkv,1)
            return P(*([None] * (nd - 4) + [dp_axes, None, None, None]))
        if nd >= 4:                                      # KV (B, S, Hkv, hd) / int8 q
            return P(*([None] * (nd - 4) + [dp_axes, None, None, "model"]))
        if nd >= 3:                                      # conv/shift states (B, k, D)
            return P(*([None] * (nd - 3) + [dp_axes, None, "model"]))
        return P(*([dp_axes] + [None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(full_rule, cache)


def sanitize_pspecs(spec_tree, shape_tree, axis_sizes: dict[str, int]):
    """Drop mesh axes from any dimension they don't divide (e.g. hubert's
    vocab=504 on a 16-way model axis) — the leaf stays sharded on the other
    dims instead of failing at lowering."""

    def fix(spec, leaf):
        dims = list(spec)
        out = []
        for i, d in enumerate(dims):
            if d is None:
                out.append(None)
                continue
            axes = (d,) if isinstance(d, str) else tuple(d)
            prod = 1
            for a in axes:
                prod *= axis_sizes.get(a, 1)
            out.append(d if leaf.shape[i] % prod == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map(
        fix, spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeCell, *, kv_quant: bool = False) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        if cfg.frontend == "audio_frames":
            return {"batch": {
                "features": sds((b, s, cfg.frontend_dim), f32),
                "targets": sds((b, s), i32),
                "loss_mask": sds((b, s), jnp.bool_),
            }}
        if cfg.frontend == "vision_patches":
            return {"batch": {
                "patches": sds((b, N_VLM_PATCHES, cfg.frontend_dim), f32),
                "tokens": sds((b, s - N_VLM_PATCHES), i32),
            }}
        return {"batch": {"tokens": sds((b, s), i32)}}

    if shape.kind == "prefill":
        if cfg.frontend == "audio_frames":  # encoder forward pass
            return {"batch": {
                "features": sds((b, s, cfg.frontend_dim), f32),
                "targets": sds((b, s), i32),
                "loss_mask": sds((b, s), jnp.bool_),
            }}
        if cfg.frontend == "vision_patches":
            return {"batch": {
                "patches": sds((b, N_VLM_PATCHES, cfg.frontend_dim), f32),
                "tokens": sds((b, s - N_VLM_PATCHES), i32),
            }}
        return {"batch": {"tokens": sds((b, s), i32)}}

    # decode: one new token against a seq_len cache
    model = build_model(cfg)
    cache_shapes = jax.eval_shape(
        lambda: model.init_decode_cache(b, s, kv_quant=kv_quant)
    )
    return {
        "cache": cache_shapes,
        "cache_len": jax.ShapeDtypeStruct((), i32),
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
    }
