"""Model assembly for the architecture pool.

One functional LM with per-family block types, always consumed via
``lax.scan`` over *stacked* layer params (HLO depth-independent):

  dense / moe       pre-norm GQA attention + SwiGLU MLP (or MoE)
  ssm (rwkv6)       time-mix + channel-mix
  hybrid (zamba2)   groups of Mamba2 layers + one *shared* attention block
                    applied after every group (weights reused, zamba2-style
                    concat(h, first-layer input) conditioning)
  audio / vlm       stub modality frontends (precomputed frame/patch
                    embeddings per the brief) feeding the dense stack;
                    audio is encoder-only (bidirectional, no decode path)

Three entry points per model: ``loss`` (training), ``prefill`` (build KV /
recurrent caches), ``decode`` (one token against filled caches).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba2 as M2
from . import moe as MOE
from . import rwkv6 as R6
from .config import ModelConfig

Params = Any


def _anchor(h, cfg):
    """Optional per-block activation sharding anchor (cfg.act_spec)."""
    if cfg.act_spec is None:
        return h
    from jax.sharding import PartitionSpec as P

    spec = P(*(tuple(a) if isinstance(a, (list, tuple)) else a
               for a in cfg.act_spec))
    return jax.lax.with_sharding_constraint(h, spec)


def _maybe_remat(body, cfg):
    """Per-layer activation checkpointing: the scan body saves only its
    inputs; intra-layer activations are recomputed during backward."""
    if getattr(cfg, "remat_policy", "none") == "layer":
        return jax.checkpoint(body)
    return body


# ---------------------------------------------------------------------------
# block init / apply (dense & moe)
# ---------------------------------------------------------------------------

def _init_dense_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": L.init_attention(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.moe_experts:
        p["moe"] = MOE.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg)
    return p


def _dense_block(p, x, cfg, *, causal: bool, train: bool = False):
    """MoE routing is capacity-dropping only when ``train=True``; every
    serving entry point (eval forward, prefill, decode) is dropless so
    prefill+decode reproduces the full-sequence forward (see models.moe)."""
    h = x + L.attention(p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, causal=causal)
    if cfg.moe_experts:
        out, aux = MOE.moe(p["moe"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg,
                           dropless=not train)
        return h + out, aux
    return h + L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps)), jnp.float32(0)


def _dense_block_prefill(p, x, cfg):
    hn = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, cache = L.attention_prefill(p["attn"], hn, cfg)
    h = x + a
    if cfg.moe_experts:
        out, _ = MOE.moe(p["moe"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg,
                         dropless=True)
        return h + out, cache
    return h + L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps)), cache


def _dense_block_decode(p, x, cache, cache_len, cfg):
    hn = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, cache = L.attention_decode(p["attn"], hn, cache, cache_len, cfg)
    h = x + a
    if cfg.moe_experts:
        out, _ = MOE.moe(p["moe"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg,
                         dropless=True)
        return h + out, cache
    return h + L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps)), cache


# ---------------------------------------------------------------------------
# rwkv6 block
# ---------------------------------------------------------------------------

def _init_rwkv_block(key, cfg):
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "tm": R6.init_rwkv6(key, cfg),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
    }


def _rwkv_block(p, x, cfg):
    h = x + R6.rwkv6_time_mix(p["tm"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg)
    h = h + R6.rwkv6_channel_mix(p["tm"], L.rmsnorm(h, p["ln2"], cfg.norm_eps))
    return h


def _rwkv_block_prefill(p, x, cfg):
    tm_out, tm_state = R6.rwkv6_time_mix(
        p["tm"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, return_state=True)
    h = x + tm_out
    cm_out, cm_state = R6.rwkv6_channel_mix(
        p["tm"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), return_state=True)
    return h + cm_out, {"tm": tm_state, "cm": cm_state}


def _rwkv_block_decode(p, x, cache, cfg):
    tm_out, tm_state = R6.rwkv6_time_mix_decode(
        p["tm"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, cache["tm"])
    h = x + tm_out
    cm_out, cm_state = R6.rwkv6_channel_mix(
        p["tm"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cache["cm"], return_state=True)
    return h + cm_out, {"tm": tm_state, "cm": cm_state}


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    dt = L.dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    p: dict = {
        "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab_size, dt)
    if cfg.frontend != "none":
        p["frontend"] = L.dense_init(keys[2], cfg.frontend_dim, cfg.d_model, dt)

    if cfg.family == "ssm":
        lkeys = jax.random.split(keys[3], cfg.n_layers)
        p["blocks"] = jax.vmap(lambda k: _init_rwkv_block(k, cfg))(lkeys)
    elif cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.n_layers // period
        gkeys = jax.random.split(keys[3], n_groups * period).reshape(n_groups, period, 2)
        p["blocks"] = jax.vmap(jax.vmap(lambda k: {
            "ln": jnp.ones((cfg.d_model,), jnp.float32),
            "mamba": M2.init_mamba2(k, cfg),
        }))(gkeys)
        # one shared attention block conditioned on concat(h, x_emb)
        sk = jax.random.split(keys[4], 3)
        p["shared"] = {
            "in_proj": L.dense_init(sk[0], 2 * cfg.d_model, cfg.d_model, dt),
            "block": _init_dense_block(sk[1], cfg),
            "out_proj": L.dense_init(sk[2], cfg.d_model, cfg.d_model, dt),
        }
    else:  # dense / moe / audio / vlm
        lkeys = jax.random.split(keys[3], cfg.n_layers)
        p["blocks"] = jax.vmap(lambda k: _init_dense_block(k, cfg))(lkeys)
    return p


def _unembed(p, cfg, h):
    h = L.rmsnorm(h, p["ln_f"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["head"]
    return (h @ w).astype(jnp.float32)


# ---------------------------------------------------------------------------
# forward (train) per family
# ---------------------------------------------------------------------------

def forward(params: Params, cfg: ModelConfig, batch: dict,
            *, train: bool = False) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V) f32, aux_loss).  ``train`` selects the MoE
    dispatch mode (capacity-dropping vs dropless); the default is the
    inference semantics that prefill+decode reproduces exactly."""
    causal = not cfg.encoder_only
    if cfg.frontend == "audio_frames":
        x = batch["features"].astype(L.dtype_of(cfg.dtype)) @ params["frontend"]
    elif cfg.frontend == "vision_patches":
        pe = batch["patches"].astype(L.dtype_of(cfg.dtype)) @ params["frontend"]
        te = params["embed"][batch["tokens"]]
        x = jnp.concatenate([pe, te], axis=1)
    else:
        x = params["embed"][batch["tokens"]]

    if cfg.family == "ssm":
        def body(h, blk):
            return _rwkv_block(blk, h, cfg), None
        h, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["blocks"],
                            unroll=cfg.scan_unroll)
        return _unembed(params, cfg, h), jnp.float32(0)

    if cfg.family == "hybrid":
        x0 = x

        def group(h, grp):
            def inner(h, blk):
                return h + M2.mamba2_chunked(
                    blk["mamba"], L.rmsnorm(h, blk["ln"], cfg.norm_eps), cfg), None
            h, _ = jax.lax.scan(_maybe_remat(inner, cfg), h, grp,
                                unroll=cfg.scan_unroll)
            h = _shared_apply(params["shared"], h, x0, cfg, train=train)
            return h, None

        h, _ = jax.lax.scan(group, x, params["blocks"], unroll=cfg.scan_unroll)
        return _unembed(params, cfg, h), jnp.float32(0)

    def body(carry, blk):
        h, aux = carry
        h, a = _dense_block(blk, h, cfg, causal=causal, train=train)
        return (_anchor(h, cfg), aux + a), None

    (h, aux), _ = jax.lax.scan(_maybe_remat(body, cfg), (x, jnp.float32(0)),
                               params["blocks"], unroll=cfg.scan_unroll)
    return _unembed(params, cfg, h), aux / cfg.n_layers


def _shared_apply(sp, h, x0, cfg, *, train: bool = False):
    z = jnp.concatenate([h, x0], axis=-1) @ sp["in_proj"]
    z, _ = _dense_block(sp["block"], z, cfg, causal=not cfg.encoder_only,
                        train=train)
    return h + z @ sp["out_proj"]


def _shared_prefill(sp, h, x0, cfg):
    z = jnp.concatenate([h, x0], axis=-1) @ sp["in_proj"]
    z, cache = _dense_block_prefill(sp["block"], z, cfg)
    return h + z @ sp["out_proj"], cache


def _shared_decode(sp, h, x0, cache, cache_len, cfg):
    z = jnp.concatenate([h, x0], axis=-1) @ sp["in_proj"]
    z, cache = _dense_block_decode(sp["block"], z, cache, cache_len, cfg)
    return h + z @ sp["out_proj"], cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(params: Params, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, cfg, batch, train=True)
    if cfg.encoder_only:
        targets = batch["targets"]
        mask = batch["loss_mask"].astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    else:
        tokens = batch["tokens"]
        if cfg.frontend == "vision_patches":
            n_patch = batch["patches"].shape[1]
            logits = logits[:, n_patch:, :]
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        mask = jnp.ones_like(nll) if mask is None else mask[:, 1:].astype(jnp.float32)
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    metrics = {"nll": loss, "aux": aux}
    return loss + 0.01 * aux, metrics


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ModelConfig, batch: dict):
    """Run the full prompt; returns (last-position logits, cache pytree)."""
    if cfg.encoder_only:
        raise ValueError("encoder-only model has no autoregressive serving path")
    if cfg.frontend == "vision_patches":
        pe = batch["patches"].astype(L.dtype_of(cfg.dtype)) @ params["frontend"]
        te = params["embed"][batch["tokens"]]
        x = jnp.concatenate([pe, te], axis=1)
    else:
        x = params["embed"][batch["tokens"]]

    if cfg.family == "ssm":
        def body(h, blk):
            h, st = _rwkv_block_prefill(blk, h, cfg)
            return h, st
        h, caches = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
        cache = {"layers": caches, "x0_last": None}
    elif cfg.family == "hybrid":
        x0 = x
        shared_caches = []

        def group(h, grp):
            def inner(h, blk):
                out, st = M2.mamba2_chunked(
                    blk["mamba"], L.rmsnorm(h, blk["ln"], cfg.norm_eps), cfg,
                    return_state=True)
                return h + out, st
            h, sts = jax.lax.scan(inner, h, grp, unroll=cfg.scan_unroll)
            h, att_cache = _shared_prefill(params["shared"], h, x0, cfg)
            return h, (sts, att_cache)

        h, (mamba_states, attn_caches) = jax.lax.scan(group, x, params["blocks"], unroll=cfg.scan_unroll)
        cache = {"mamba": mamba_states, "attn": attn_caches}
    else:
        def body(h, blk):
            h, c = _dense_block_prefill(blk, h, cfg)
            return h, c
        h, caches = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
        cache = {"layers": caches}

    logits = _unembed(params, cfg, h[:, -1:, :])
    return logits, cache


def init_decode_cache(cfg: ModelConfig, batch_size: int, max_seq: int,
                      *, kv_quant: bool = False):
    """Fresh caches sized for ``max_seq`` (decode dry-run entry point)."""
    dt = L.dtype_of(cfg.dtype)
    hd = cfg.hd
    if cfg.family == "ssm":
        heads, rhd = R6.rwkv_dims(cfg)

        def one(_):
            return {
                "tm": {"wkv": jnp.zeros((batch_size, heads, rhd, rhd), jnp.float32),
                       "shift": jnp.zeros((batch_size, 1, cfg.d_model), dt)},
                "cm": jnp.zeros((batch_size, 1, cfg.d_model), dt),
            }
        return {"layers": jax.vmap(one)(jnp.arange(cfg.n_layers))}
    if cfg.family == "hybrid":
        d_inner, heads, mhd = M2.ssm_dims(cfg)
        n_groups = cfg.n_layers // cfg.hybrid_period

        def one_m(_):
            return {
                "ssm": jnp.zeros((batch_size, heads, mhd, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((batch_size, cfg.ssm_conv - 1, d_inner), dt),
            }
        mamba = jax.vmap(jax.vmap(one_m))(
            jnp.zeros((n_groups, cfg.hybrid_period)))

        def one_a(_):
            return L.make_kv_cache(batch_size, max_seq, cfg.n_kv_heads, hd, dt, kv_quant)
        attn = jax.vmap(one_a)(jnp.arange(n_groups))
        return {"mamba": mamba, "attn": attn,
                "x0_last": jnp.zeros((batch_size, 1, cfg.d_model), dt)}

    def one(_):
        return L.make_kv_cache(batch_size, max_seq, cfg.n_kv_heads, hd, dt, kv_quant)
    return {"layers": jax.vmap(one)(jnp.arange(cfg.n_layers))}


def decode_step(params: Params, cfg: ModelConfig, cache, cache_len, tokens):
    """One token: tokens (B, 1) int32 -> (logits (B,1,V), new cache)."""
    if cfg.encoder_only:
        raise ValueError("encoder-only model has no decode step")
    x = params["embed"][tokens]

    if cfg.family == "ssm":
        def body(h, blk_cache):
            blk, c = blk_cache
            h, c2 = _rwkv_block_decode(blk, h, c, cfg)
            return h, c2
        h, new_caches = jax.lax.scan(body, x, (params["blocks"], cache["layers"]), unroll=cfg.scan_unroll)
        return _unembed(params, cfg, h), {"layers": new_caches}

    if cfg.family == "hybrid":
        x0 = x

        def group(h, grp_cache):
            grp, mstates, acache = grp_cache

            def inner(h, blk_state):
                blk, st = blk_state
                out, st2 = M2.mamba2_decode(
                    blk["mamba"], L.rmsnorm(h, blk["ln"], cfg.norm_eps), cfg, st)
                return h + out, st2
            h, msts = jax.lax.scan(inner, h, (grp, mstates), unroll=cfg.scan_unroll)
            h, ac2 = _shared_decode(params["shared"], h, x0, acache, cache_len, cfg)
            return h, (msts, ac2)

        h, (msts, acs) = jax.lax.scan(
            group, x, (params["blocks"], cache["mamba"], cache["attn"]),
            unroll=cfg.scan_unroll)
        return _unembed(params, cfg, h), {
            "mamba": msts, "attn": acs, "x0_last": cache["x0_last"]}

    def body(h, blk_cache):
        blk, c = blk_cache
        h, c2 = _dense_block_decode(blk, h, c, cache_len, cfg)
        return h, c2
    h, new_caches = jax.lax.scan(body, x, (params["blocks"], cache["layers"]), unroll=cfg.scan_unroll)
    return _unembed(params, cfg, h), {"layers": new_caches}
