"""Mixture-of-Experts layer: top-k router with GShard-style capacity
dispatch (one-hot dispatch/combine einsums — the TPU-native formulation:
dense matmuls instead of data-dependent gathers, EP-shardable on the
``model`` axis).

FLOPs scale with tokens * top_k (not tokens * n_experts): each token is
copied into at most ``top_k`` expert capacity slots; overflow tokens are
dropped from the expert path (standard capacity-factor routing), which at
capacity_factor 1.25 affects a negligible tail and keeps every shape
static.

Capacity dropping is a *training* throughput trade.  At inference
(``dropless=True``) capacity covers every routed assignment, because a
token's expert output must not depend on how many other tokens share its
batch: with dropping, prefill+decode could not reproduce the full-sequence
forward (the decode token always has a fresh capacity buffer while the
same token inside a longer forward competes for slots).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, dtype_of


def init_moe(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg.dtype)
    e = cfg.moe_experts
    d, f = cfg.d_model, cfg.d_ff
    scale = d ** -0.5
    return {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=0.02),
        "wg": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dt),
        "wu": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dt),
        "wd": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * (f ** -0.5)).astype(dt),
    }


def moe_capacity(cfg, n_tokens: int) -> int:
    cap = int(n_tokens * cfg.moe_top_k * cfg.moe_capacity_factor / cfg.moe_experts)
    return max(8, (cap + 7) // 8 * 8)  # 8-aligned for TPU lanes


def moe(p: dict, x: jax.Array, cfg, *, dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    # dropless always dispatches via sort: the one-hot dispatch tensor
    # scales quadratically with the dropless capacity.  The static dropless
    # bound is cap = n per expert (top_k indices are distinct, so one token
    # contributes at most one slot per expert), giving O(e*n*d) expert
    # buffers — e-times the capacity-routed footprint; acceptable for
    # decode/prefill shapes, and the tightest bound static shapes allow.
    if dropless or getattr(cfg, "moe_dispatch", "onehot") == "sort":
        return moe_sort(p, x, cfg, dropless=dropless)
    return moe_onehot(p, x, cfg)


def moe_onehot(p: dict, x: jax.Array, cfg, *, dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss). Dispatch via one-hot einsums.

    ``moe_group_size=0`` is the naive single-group GShard baseline: capacity
    scales with N, so the dispatch einsum is O(N^2 k d / G) — it dominates
    compute at train shapes (EXPERIMENTS.md §Perf).  ``moe_group_size=m``
    routes within groups of m tokens (GShard's G dimension): dispatch cost
    drops by N/m with identical semantics up to per-group capacity dropping.
    """
    b, s, d = x.shape
    n = b * s
    e, k = cfg.moe_experts, cfg.moe_top_k
    m = cfg.moe_group_size or n
    m = min(m, n)
    g = n // m
    if g * m != n:
        g, m = 1, n
    # dropless: top_k indices are distinct per token, so an expert receives
    # at most one slot per token -> cap = m is the tight static bound
    cap = m if dropless else moe_capacity(cfg, m)
    xt = x.reshape(g, m, d)

    logits = xt.astype(jnp.float32) @ p["router"]            # (G, m, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (G, m, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balancing auxiliary loss (Switch-style, global)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((e,)).at[gate_idx.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(me * ce)

    # position of each (token, choice) inside its expert's per-group buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)    # (G, m, k, E)
    flat = onehot.reshape(g, m * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) * flat - 1      # (G, m*k, E)
    pos = pos_in_expert.max(axis=-1).reshape(g, m, k)        # (G, m, k)
    keep = (pos < cap) & (pos >= 0)
    gate_vals = gate_vals * keep

    # dispatch tensor (G, m, k) -> (G, E, cap) one-hot combine
    pos_c = jnp.clip(pos, 0, cap - 1)
    disp = (
        jax.nn.one_hot(gate_idx, e, dtype=xt.dtype)[..., None]
        * jax.nn.one_hot(pos_c, cap, dtype=xt.dtype)[:, :, :, None, :]
        * keep[..., None, None].astype(xt.dtype)
    )                                                        # (G, m, k, E, cap)
    disp_tok = disp.sum(axis=2)                              # (G, m, E, cap)
    xe = jnp.einsum("gmd,gmec->gecd", xt, disp_tok)          # (G, E, cap, D)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["wu"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])            # (G, E, cap, D)

    combine = jnp.einsum("gmkec,gmk->gmec", disp, gate_vals.astype(xt.dtype))
    out = jnp.einsum("gmec,gecd->gmd", combine, ye)
    return out.reshape(b, s, d), aux


def moe_sort(p: dict, x: jax.Array, cfg, *, dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    """Sort/scatter-based dispatch: O(N*k*d) data movement, no N^2 one-hot
    matmuls.  Identical routing semantics to ``moe_onehot`` (stable argsort
    preserves the per-expert token order, so the same overflow tokens drop).
    """
    b, s, d = x.shape
    n = b * s
    e, k = cfg.moe_experts, cfg.moe_top_k
    # tight static dropless bound: distinct top_k => <= n slots per expert
    cap = n if dropless else moe_capacity(cfg, n)
    xt = x.reshape(n, d)

    logits = xt.astype(jnp.float32) @ p["router"]            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (N, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    me = probs.mean(axis=0)
    ce = jnp.zeros((e,)).at[gate_idx.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(me * ce)

    e_flat = gate_idx.reshape(-1)                            # (N*k,)
    g_flat = gate_vals.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    tok_of = order // k
    start = jnp.searchsorted(sorted_e, jnp.arange(e))        # (E,)
    pos = jnp.arange(n * k) - start[sorted_e]
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, e * cap)    # overflow slot

    def anchor(t, spec):
        if not getattr(cfg, "moe_ep_anchor", False):
            return t
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(t, P(*spec))

    xe = jnp.zeros((e * cap + 1, d), xt.dtype).at[dest].set(xt[tok_of])
    xe = anchor(xe[:-1].reshape(e, cap, d), ("model", None, None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"])
    ye = anchor(ye, ("model", None, None)).reshape(e * cap, d)

    contrib = ye[jnp.clip(dest, 0, e * cap - 1)] * (
        g_flat[order] * keep).astype(ye.dtype)[:, None]
    out = jnp.zeros((n, d), ye.dtype).at[tok_of].add(contrib)
    return out.reshape(b, s, d).astype(x.dtype), aux
