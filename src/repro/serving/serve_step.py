"""Serving steps: the QbS shortest-path-graph query pipeline and the LM
prefill/decode units.

**SPG serving** (``make_spg_serve_step`` / ``serve_spg_batch``): the
persistent, fully-jitted batched pipeline over a built ``QbSIndex`` —
label gather -> sketch (min-plus on the Pallas kernel when the index was
built with ``use_pallas=True``, the default) -> vmapped guided search ->
device-side edge-mask symmetrization.  The step is fixed-shape (``B =
index.chunk`` lanes), returns device arrays with no host sync, and serves
the general (non-landmark-endpoint) lane; ``serve_spg_batch`` answers
arbitrary batches through the planner/service stack (``serving.planner``
routes lanes, ``serving.service`` executes them with double-buffered
async dispatch — same as ``QbSIndex.query_batch``).

**LM serving**: prefill and single-token decode (the units the dry-run
lowers for the decode_* / prefill_* shape cells), plus a simple batched
greedy-decode driver for the examples.  KV caches support bf16 and int8
(per-position scales, see ``models.layers``); int8 halves the decode
memory term — the default for the 32k/500k cells where cache bytes
dominate the roofline.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models.registry import Model, build_model


# ---------------------------------------------------------------------------
# QbS shortest-path-graph serving
# ---------------------------------------------------------------------------


def make_spg_serve_step(index) -> Callable:
    """Return the persistent jitted SPG serving step of a ``QbSIndex``.

    The step maps int32 query arrays ``(us, vs)`` of shape ``(B,)`` (any
    fixed B; reuse one B for one compile cache entry — ``index.chunk`` is
    the canonical choice) to device arrays ``(dist (B,), edge_mask (B, E)
    bool)``.  The edge mask is already symmetrized through the reverse-edge
    map, so callers never touch the host ``(B, E)`` gather the legacy path
    paid per chunk.  No host sync happens inside the step (two chained jit
    dispatches: search program + symmetrization program; see
    ``QbSIndex.__init__`` for why they are separate).

    The label tables the step reads are the index's *packed* uint8/uint16
    arrays (``QbSIndex.packed``, DESIGN.md §10): gathered rows widen to
    int32 in registers inside the jit program, so HBM label traffic is
    ~4x cheaper than the int32 layout while results stay bit-identical.

    Landmark-endpoint queries are *not* handled here (they have no label
    entries; the pipeline returns garbage lanes for them) — route them to
    the vectorized landmark lane steps (``QbSIndex.landmark_pair_step`` /
    ``landmark_onesided_step``) as the planner does.

    A vertex-sharded index (``core.sharded.ShardedIndex``,
    ``QbSIndex.build(..., sharded=...)``) satisfies the same contract:
    its step runs the general lane over the mesh-resident label/CSR
    blocks and returns the same replicated, already-symmetrized arrays —
    callers cannot tell the layouts apart (DESIGN.md §11).
    """
    return index.serve_step


def serve_spg_batch(index, us, vs) -> tuple[np.ndarray, np.ndarray]:
    """Answer an arbitrary-size query batch through the planner/service
    stack (lane routing, dedup, double-buffered chunk dispatch).  Returns
    ``(dist (N,) int32, edge_mask (N, E) bool)``.
    """
    return index.query_batch_arrays(us, vs)


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        if model.cfg.encoder_only:
            # encoder "prefill" = the full forward pass (no cache exists)
            logits, _ = model.forward(params, batch=batch)
            return logits, None
        return model.prefill(params, batch=batch)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, cache_len, tokens):
        return model.decode(params, cache=cache, cache_len=cache_len, tokens=tokens)

    return decode_step


@lru_cache(maxsize=32)
def _generate_steps(cfg):
    """Jitted prefill/decode pair per ``ModelConfig`` (frozen, hashable).
    The model facade is pure functions of the config, so rebuilding it
    here yields the same computation — and caching on the config keeps
    one jit (and one compile cache) per architecture instead of a fresh
    one per ``greedy_generate`` call (QBS004 recompile churn)."""
    model = build_model(cfg)
    return jax.jit(model.prefill), jax.jit(make_decode_step(model))


def greedy_generate(model: Model, params, prompt_tokens, n_new: int,
                    *, kv_quant: bool = False):
    """Host loop driver: prefill the prompt then decode n_new tokens."""
    b, s = prompt_tokens.shape
    prefill, decode = _generate_steps(model.cfg)
    logits, pre_cache = prefill(
        params, batch={"tokens": jnp.asarray(prompt_tokens)})
    if model.cfg.family in ("ssm",):
        cache = pre_cache
        cache_len = jnp.int32(s)
    elif model.cfg.family == "hybrid":
        # copy before any write: mutating the dict returned by
        # init_decode_cache would alias whatever the model cached internally
        cache = dict(model.init_decode_cache(b, s + n_new, kv_quant=kv_quant))
        k_pre, v_pre = pre_cache["attn"]
        k_buf, v_buf = cache["attn"]
        cache["mamba"] = pre_cache["mamba"]
        cache["attn"] = (
            k_buf.at[:, :, :s].set(k_pre.astype(k_buf.dtype))
            if not isinstance(k_buf, dict) else k_buf,
            v_buf.at[:, :, :s].set(v_pre.astype(v_buf.dtype))
            if not isinstance(v_buf, dict) else v_buf,
        )
        cache_len = jnp.int32(s)
    else:
        cache = model.init_decode_cache(b, s + n_new, kv_quant=kv_quant)
        k_pre, v_pre = pre_cache["layers"]
        k_buf, v_buf = cache["layers"]
        if isinstance(k_buf, dict):
            # re-prefill through the quantized path: write positions 0..s-1
            from ..models.layers import _quant
            kq = jax.tree_util.tree_map(lambda x: x, _quant(k_pre))
            vq = _quant(v_pre)
            k_buf = {"q": k_buf["q"].at[:, :, :s].set(kq["q"]),
                     "scale": k_buf["scale"].at[:, :, :s].set(kq["scale"])}
            v_buf = {"q": v_buf["q"].at[:, :, :s].set(vq["q"]),
                     "scale": v_buf["scale"].at[:, :, :s].set(vq["scale"])}
        else:
            k_buf = k_buf.at[:, :, :s].set(k_pre.astype(k_buf.dtype))
            v_buf = v_buf.at[:, :, :s].set(v_pre.astype(v_buf.dtype))
        cache = {"layers": (k_buf, v_buf)}
        cache_len = jnp.int32(s)

    out = [jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)]
    for i in range(n_new - 1):
        logits, cache = decode(params, cache, cache_len + i, out[-1][:, None])
        out.append(jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32))
    return jnp.stack(out, axis=1)
