"""Query planner: lane classification and batch canonicalization for SPG
serving (DESIGN.md §4).

Every serving entry point answers an arbitrary ``(us, vs)`` batch through
the same two steps: *plan* (this module, host-side numpy) and *execute*
(``serving.service``).  The planner owns all routing policy:

* **Canonicalize + dedup.**  SPGs on an undirected graph are orientation-
  and repetition-invariant, so queries are keyed on ``(min(u, v),
  max(u, v))`` and deduplicated; the executor answers each *unique* pair
  once and the plan's ``inv`` map fans results back out.  Real traffic is
  heavily skewed toward hub pairs (the Pruned-Landmark-Labeling /
  Hub-Accelerator observation), so dedup is a first-order win, and the
  canonical key is exactly the result-cache key.
* **Lanes.**  Each unique pair lands in one of four lanes, in decreasing
  strictness:

  - ``LANE_TRIVIAL``        ``u == v``: dist 0, no edges, no device work.
  - ``LANE_LANDMARK_PAIR``  both endpoints are landmarks: distance is a
    ``meta_dist`` lookup and every SPG edge certifies label-only
    (``QbSIndex.landmark_pair_step``); no search at all.
  - ``LANE_ONE_SIDED``      exactly one landmark endpoint: label-derived
    distance + one *distance-bounded* full-graph BFS from the non-landmark
    side, batched over the whole lane
    (``QbSIndex.landmark_onesided_step``).
  - ``LANE_GENERAL``        no landmark endpoint: the sketch + guided
    search pipeline (``QbSIndex.serve_step``).

Each device lane runs in fixed-shape chunks (``chunk_padded``; ragged
tails repeat the last live entry and the pad lanes are discarded), so
every lane has one jit cache entry per chunk width, like the seed general
path.  The planner never touches a device: it is pure host-side
classification, cheap relative to any lane's execution.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

import numpy as np

LANE_TRIVIAL = 0
LANE_LANDMARK_PAIR = 1
LANE_ONE_SIDED = 2
LANE_GENERAL = 3
N_LANES = 4

LANE_NAMES = ("trivial", "landmark_pair", "one_sided", "general")


class QueryPlan(NamedTuple):
    """Routed batch: unique canonical pairs + per-lane index sets.

    ``cu``/``cv`` are the canonical (min, max) endpoints of the unique
    pairs; ``inv`` maps each of the ``n`` original queries to its unique
    row; ``lane`` assigns each unique row a lane id; ``lanes[k]`` lists the
    unique-row indices of lane ``k`` in first-appearance order.
    """

    n: int                       # original batch size
    cu: np.ndarray               # (U,) int32 canonical min endpoint
    cv: np.ndarray               # (U,) int32 canonical max endpoint
    inv: np.ndarray              # (n,) intp query -> unique row
    lane: np.ndarray             # (U,) int8
    lanes: tuple[np.ndarray, ...]  # per-lane unique-row indices
    cls: np.ndarray | None = None  # (U,) int16 QoS class id (None: untagged)

    @property
    def n_unique(self) -> int:
        return int(self.cu.shape[0])


def classify_lanes(cu: np.ndarray, cv: np.ndarray,
                   is_landmark: np.ndarray) -> np.ndarray:
    """Lane id per canonical pair (the one routing rule, shared by every
    plan constructor)."""
    lm_u = is_landmark[cu]
    lm_v = is_landmark[cv]
    return np.where(
        cu == cv, LANE_TRIVIAL,
        np.where(lm_u & lm_v, LANE_LANDMARK_PAIR,
                 np.where(lm_u ^ lm_v, LANE_ONE_SIDED, LANE_GENERAL)),
    ).astype(np.int8)


def d_top_of(lane: int, dist: int, inf: int) -> int:
    """The one d_top reporting convention (seed pipeline): general-lane
    answers report the dist-derived d_top; planner-answered lanes
    (trivial, both landmark lanes, cache hits thereof) report ``inf``
    because no sketch ran for them.  Shared by the one-shot service and
    every streaming resolution path so the convention cannot drift."""
    return dist if (lane == LANE_GENERAL and dist < inf) else inf


def plan_queries(us: np.ndarray, vs: np.ndarray,
                 is_landmark: np.ndarray,
                 cls: np.ndarray | None = None) -> QueryPlan:
    """Classify a query batch into lanes over canonical unique pairs.

    ``cls`` optionally tags each *original* query with a QoS class id;
    the unique row keeps the class of its first appearance (the class
    that got the pair admitted — later duplicates join, they don't
    re-route)."""
    us = np.asarray(us, np.int32).reshape(-1)
    vs = np.asarray(vs, np.int32).reshape(-1)
    n = us.shape[0]
    cu = np.minimum(us, vs)
    cv = np.maximum(us, vs)
    # stable dedup: unique rows keep first-appearance order so execution
    # order (and thus device dispatch order) is reproducible
    # int64 on purpose: the dedup key is a (u * (V+1) + v) product that can
    # exceed int32 for large V — it is transient, never a resident table
    key = cu.astype(np.int64) * (int(is_landmark.shape[0]) + 1) + cv  # qbslint: disable=QBS007
    _, first, inv = np.unique(key, return_index=True, return_inverse=True)
    order = np.argsort(first, kind="stable")
    rank = np.empty_like(order)
    rank[order] = np.arange(order.size)
    first = first[order]
    inv = rank[inv]
    cu, cv = cu[first], cv[first]

    lane = classify_lanes(cu, cv, is_landmark)
    lanes = tuple(np.flatnonzero(lane == k) for k in range(N_LANES))
    u_cls = (None if cls is None
             else np.asarray(cls, np.int16).reshape(-1)[first])
    return QueryPlan(n=n, cu=cu, cv=cv, inv=inv.astype(np.intp), lane=lane,
                     lanes=lanes, cls=u_cls)


def plan_from_pairs(cu: np.ndarray, cv: np.ndarray,
                    is_landmark: np.ndarray,
                    cls: np.ndarray | None = None) -> QueryPlan:
    """Plan a set of *already canonical, already unique* pairs (``cu <=
    cv``, no repeats) without re-running canonicalization or dedup.

    The streaming scheduler (``serving.stream``) keys its pending and
    in-flight state on canonical pairs, so by the time it admits a batch
    the dedup work is already done; ``inv`` is the identity.  ``cls``
    carries the per-pair QoS class lane the scheduler selected from."""
    cu = np.asarray(cu, np.int32).reshape(-1)
    cv = np.asarray(cv, np.int32).reshape(-1)
    lane = classify_lanes(cu, cv, is_landmark)
    lanes = tuple(np.flatnonzero(lane == k) for k in range(N_LANES))
    u_cls = None if cls is None else np.asarray(cls, np.int16).reshape(-1)
    return QueryPlan(n=cu.shape[0], cu=cu, cv=cv,
                     inv=np.arange(cu.shape[0], dtype=np.intp), lane=lane,
                     lanes=lanes, cls=u_cls)


def merge_plans(plans: list[QueryPlan],
                is_landmark: np.ndarray) -> QueryPlan:
    """Coalesce several planned batches into one plan, re-deduplicating
    *across* plan boundaries — the admission-control primitive: queries
    arriving at different times fold into a single planner batch, and a
    pair appearing in two admissions executes once.

    The merged ``inv`` indexes the concatenation of the source plans'
    original queries (in plan order), so per-query fan-out survives the
    merge.  QoS class tags survive it too (first appearance wins, like
    the dedup itself); plans without tags contribute class 0."""
    if not plans:
        return plan_queries(np.zeros((0,), np.int32), np.zeros((0,), np.int32),
                            is_landmark)
    if len(plans) == 1:
        return plans[0]
    # reconstruct each plan's original canonical stream and re-plan; the
    # pairs are already canonical (cu <= cv), so plan_queries' min/max
    # canonicalization is a no-op and only the cross-plan dedup bites
    cu = np.concatenate([p.cu[p.inv] for p in plans])
    cv = np.concatenate([p.cv[p.inv] for p in plans])
    cls = None
    if any(p.cls is not None for p in plans):
        cls = np.concatenate([
            (p.cls[p.inv] if p.cls is not None
             else np.zeros((p.n,), np.int16)) for p in plans])
    return plan_queries(cu, cv, is_landmark, cls=cls)


def chunk_padded(idx: np.ndarray, chunk: int) -> Iterator[tuple[np.ndarray, int]]:
    """Yield fixed-shape ``(sel (chunk,), live)`` index chunks of ``idx``;
    the ragged tail repeats the last live entry (pad lanes are computed
    and discarded — the fixed shape is what keeps one jit cache entry per
    lane)."""
    for start in range(0, idx.size, chunk):
        sel = idx[start:start + chunk]
        live = sel.size
        if live < chunk:
            sel = np.concatenate([sel, np.repeat(sel[-1:], chunk - live)])
        yield sel, live


def onesided_roots(cu: np.ndarray, cv: np.ndarray, is_landmark: np.ndarray,
                   lid: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Split one-sided-lane pairs into (non-landmark root, landmark index)."""
    u_is = is_landmark[cu]
    roots = np.where(u_is, cv, cu).astype(np.int32)
    r_idx = lid[np.where(u_is, cu, cv)].astype(np.int32)
    return roots, r_idx
