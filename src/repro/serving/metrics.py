"""Observability layer for the serving tier (DESIGN.md §12).

The scheduler already *has* the numbers — ``StreamingService.stats`` /
``qos_stats``, ``admission_log`` round compositions, ``ResultCache``
hit/eviction/byte counters — but they live as internal fields read by
tests.  This module makes them operational:

* ``LatencyHistogram`` — fixed log2-bucket (microsecond) histogram of
  submit-to-resolution latency, recorded per QoS class at *future
  resolution time* on the injectable clock (``serving.clock``), so under
  ``ManualClock`` every count is a deterministic function of the trace
  and p50/p99 become gateable CI numbers (``benchmarks/trace_replay.py``
  + ``scripts/bench_gate.py --p99-ceiling-us``).  Counts are plain
  Python ints (no numpy scalars on the host path — QBS007's spirit) and
  quantiles return the conservative *upper edge* of the hit bucket.
* ``MetricsRegistry`` — named sources (``StreamingService`` instances,
  e.g. every replica of a ``ReplicaRouter``) snapshotted into one
  structured dict for tests and one Prometheus-style text exposition for
  scraping.  Every read happens under the owning service's lock, so the
  registry can be scraped while submit/timer threads run — the QBS005
  discipline extends to readers.
* ``serve_metrics`` — the scrape endpoint: a stdlib ``http.server``
  serving ``GET /metrics`` on a daemon thread (``launch/serve.py
  --metrics-port``).  No wall-clock reads here: rendering only snapshots
  counters, so the module stays QBS002-clean.
"""
from __future__ import annotations

import http.server
import math
import threading
from typing import Callable, Iterable

# bucket 0: us < 1; bucket b in [1, 31]: 2^(b-1) <= us < 2^b;
# bucket 32: us >= 2^31 (overflow).  Upper edges are 2^b; the overflow
# bucket's is +inf — a quantile landing there reports inf rather than
# inventing a finite number.
N_BUCKETS = 33
_OVERFLOW = N_BUCKETS - 1


def bucket_of(us: float) -> int:
    """Bucket index for a latency in microseconds (log2 edges)."""
    if us < 1.0:
        return 0
    return min(int(us).bit_length(), _OVERFLOW)


def bucket_upper_us(i: int) -> float:
    """Exclusive upper edge of bucket ``i`` in microseconds."""
    return math.inf if i >= _OVERFLOW else float(1 << i)


class LatencyHistogram:
    """Fixed log2-bucket latency histogram (microseconds).

    ``check`` is an optional zero-arg callable asserted before every
    mutation — the runtime sanitizer's lock-ownership probe
    (``serving.debug.Sanitizer.check``), wired in by the owning
    ``StreamingService`` so off-lock observations fail loudly under
    ``QBS_SANITIZE=1``."""

    __slots__ = ("counts", "total", "sum_us", "_check")

    def __init__(self, check: Callable[[], None] | None = None):
        self.counts: list[int] = [0] * N_BUCKETS
        self.total = 0
        self.sum_us = 0.0
        self._check = check

    def observe(self, us: float) -> None:
        if self._check is not None:
            self._check()
        us = float(us)
        self.counts[bucket_of(us)] += 1
        self.total += 1
        self.sum_us += us

    def quantile(self, q: float) -> float:
        """Conservative quantile: the upper edge of the bucket holding
        the rank-``ceil(q * total)`` observation (0.0 when empty)."""
        if self.total == 0:
            return 0.0
        rank = min(self.total, max(1, math.ceil(q * self.total)))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return bucket_upper_us(i)
        return math.inf                      # unreachable: cum == total

    def snapshot(self) -> dict:
        return {
            "counts": list(self.counts),
            "total": self.total,
            "sum_us": self.sum_us,
            "p50_us": self.quantile(0.50),
            "p99_us": self.quantile(0.99),
        }


class MetricsRegistry:
    """Named serving sources -> one structured snapshot / text exposition.

    ``register`` accepts anything with the ``StreamingService`` counter
    surface (``stats``, ``qos_stats``, ``lat_hist``, ``admission_log``,
    ``service.cache``, ``_lock``); a ``ReplicaRouter`` registers each
    replica under its own name so per-replica partitioning stays visible
    in the scrape."""

    def __init__(self):
        self._sources: list[tuple[str, object]] = []
        self._reg_lock = threading.Lock()

    def register(self, name: str, service) -> None:
        with self._reg_lock:
            if any(n == name for n, _ in self._sources):
                raise ValueError(f"duplicate metrics source name {name!r}")
            self._sources.append((name, service))

    def sources(self) -> list[tuple[str, object]]:
        with self._reg_lock:
            return list(self._sources)

    def _snapshot_one(self, st) -> dict:
        # one consistent cut per source: everything below reads under the
        # service's own lock, the same lock its mutators hold (QBS005)
        with st._lock:
            qos = {}
            for name, cs in st.qos_stats.items():
                qos[name] = {k: v for k, v in cs.items() if k != "waits"}
                qos[name]["n_waits"] = len(cs["waits"])
            rounds = list(st.admission_log)
            out = {
                "stats": dict(st.stats),
                "qos": qos,
                "latency_us": {name: h.snapshot()
                               for name, h in st.lat_hist.items()},
                "admission": {
                    "rounds": len(rounds),
                    "expired_rounds": sum(
                        1 for r in rounds if r["expired"]),
                    "slots": sum(r["n"] for r in rounds),
                },
                "chunk": st._chunk,
                "n_pending": st._n_pending,
                "n_inflight": len(st._inflight),
            }
        cache = st.service.cache
        if cache is not None:
            out["cache"] = {
                "hits": cache.hits, "misses": cache.misses,
                "evictions": cache.evictions, "bytes": cache.bytes,
                "entries": len(cache),
            }
        return out

    def snapshot(self) -> dict:
        """Structured dict, one entry per registered source — the form
        the tests assert against."""
        return {name: self._snapshot_one(st) for name, st in self.sources()}

    def render_text(self) -> str:
        """Prometheus-style text exposition (counters + cumulative-``le``
        histogram series) built from ``snapshot``."""
        snap = self.snapshot()
        lines: list[str] = []
        for name, s in sorted(snap.items()):
            lab = f'service="{name}"'
            for k, v in sorted(s["stats"].items()):
                lines.append(f"qbs_{k}_total{{{lab}}} {v}")
            lines.append(f"qbs_pending{{{lab}}} {s['n_pending']}")
            lines.append(f"qbs_inflight{{{lab}}} {s['n_inflight']}")
            lines.append(f"qbs_chunk_width{{{lab}}} {s['chunk']}")
            for k in ("rounds", "expired_rounds", "slots"):
                lines.append(
                    f"qbs_admission_{k}_total{{{lab}}} {s['admission'][k]}")
            for cls, cs in sorted(s["qos"].items()):
                clab = f'{lab},qos="{cls}"'
                for k, v in sorted(cs.items()):
                    lines.append(f"qbs_qos_{k}_total{{{clab}}} {v}")
            for cls, h in sorted(s["latency_us"].items()):
                clab = f'{lab},qos="{cls}"'
                cum = 0
                for i, c in enumerate(h["counts"]):
                    cum += c
                    edge = bucket_upper_us(i)
                    le = "+Inf" if math.isinf(edge) else f"{int(edge)}"
                    lines.append(
                        f'qbs_latency_us_bucket{{{clab},le="{le}"}} {cum}')
                lines.append(f"qbs_latency_us_count{{{clab}}} {h['total']}")
                lines.append(f"qbs_latency_us_sum{{{clab}}} {h['sum_us']}")
            if "cache" in s:
                for k, v in sorted(s["cache"].items()):
                    lines.append(f"qbs_cache_{k}{{{lab}}} {v}")
        return "\n".join(lines) + "\n"


class _MetricsHandler(http.server.BaseHTTPRequestHandler):
    registry: MetricsRegistry = None  # bound per server class below

    def do_GET(self):                               # noqa: N802 (stdlib API)
        if self.path.rstrip("/") not in ("", "/metrics"):
            self.send_error(404)
            return
        body = self.registry.render_text().encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):                   # quiet scrapes
        pass


def serve_metrics(registry: MetricsRegistry, port: int = 0,
                  host: str = "127.0.0.1") -> http.server.ThreadingHTTPServer:
    """Start the scrape endpoint on a daemon thread; returns the server
    (``server.server_address[1]`` is the bound port — ``port=0`` picks an
    ephemeral one; stop with ``server.shutdown()``)."""
    handler = type("BoundMetricsHandler", (_MetricsHandler,),
                   {"registry": registry})
    server = http.server.ThreadingHTTPServer((host, port), handler)
    server.daemon_threads = True
    thread = threading.Thread(target=server.serve_forever,
                              name="qbs-metrics", daemon=True)
    thread.start()
    return server


def merged_latency(hists: Iterable[LatencyHistogram]) -> LatencyHistogram:
    """Sum several histograms (e.g. one QoS class across all replicas)
    into a fresh one — bucket edges are shared, so merging is exact."""
    out = LatencyHistogram()
    for h in hists:
        for i, c in enumerate(h.counts):
            out.counts[i] += c
        out.total += h.total
        out.sum_us += h.sum_us
    return out
