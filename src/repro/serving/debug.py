"""Opt-in concurrency sanitizer for the streaming scheduler.

The static side of the lock discipline lives in ``tools/qbslint``
(QBS005: every mutation of a ``_QBS_GUARDED_FIELDS`` member happens
under ``with self._lock``).  Static analysis cannot see *dynamic* call
paths — a helper reached both with and without the lock, a callback
fired from a clock thread — so this module supplies the runtime half:

* ``OwnedRLock`` — an ``threading.RLock`` wrapper that records the
  owning thread, so ``owned()`` answers "does *this* thread hold it?".
* ``Sanitizer`` — factories for guarded ``dict``/``deque``/``list``
  subclasses whose mutators assert lock ownership before mutating, plus
  ``assert_owned`` for scalar attribute rebinds.
* ``ConcurrencyViolation`` — the ``AssertionError`` raised on a guarded
  mutation by a thread that does not hold the lock.

Enablement: ``StreamingService(..., sanitize=True)`` explicitly, or
``QBS_SANITIZE=1`` in the environment (``scripts/tier1.sh`` exports it,
so the whole tier-1 suite runs sanitized in CI).  Disabled, the service
uses plain builtins and an ordinary ``RLock`` — zero overhead.

Known gap: ``heapq``'s C implementation mutates lists through the
concrete ``PyList`` API, bypassing subclass methods, so pushes onto the
scheduler heap are only covered statically (QBS005 knows the ``heapq``
functions), not at runtime.
"""
from __future__ import annotations

import os
import threading
from collections import deque


class ConcurrencyViolation(AssertionError):
    """A guarded structure was mutated off-lock (see serving.debug)."""


def enabled() -> bool:
    """True when the ``QBS_SANITIZE`` env var asks for the sanitizer."""
    return os.environ.get("QBS_SANITIZE", "").strip().lower() in (
        "1", "true", "yes", "on")


class OwnedRLock:
    """Reentrant lock that tracks its owning thread.

    ``owned()`` is read without the lock held: the owner field is only
    written by the holder, so a racing reader either sees its own ident
    (it holds the lock) or someone else's/None (it does not) — exactly
    the answer the assertion needs.
    """

    __slots__ = ("_lock", "_owner", "_depth")

    def __init__(self):
        self._lock = threading.RLock()
        self._owner: int | None = None
        self._depth = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._lock.acquire(blocking, timeout)
        if got:
            self._owner = threading.get_ident()
            self._depth += 1
        return got

    def release(self) -> None:
        self._depth -= 1
        if self._depth == 0:
            self._owner = None
        self._lock.release()

    def __enter__(self) -> "OwnedRLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def owned(self) -> bool:
        return self._owner == threading.get_ident()


def _checked(base, name):
    orig = getattr(base, name)

    def method(self, *args, **kwargs):
        self._qbs_check()
        return orig(self, *args, **kwargs)

    method.__name__ = name
    method.__qualname__ = name
    return method


def _guarded_type(base, mutators):
    def __init__(self, qbs_check, *args, **kwargs):
        base.__init__(self, *args, **kwargs)
        self._qbs_check = qbs_check

    ns = {"__init__": __init__}
    for name in mutators:
        ns[name] = _checked(base, name)
    return type(f"Guarded{base.__name__.capitalize()}", (base,), ns)


_DICT_MUTATORS = ("__setitem__", "__delitem__", "pop", "popitem", "clear",
                  "update", "setdefault")
_DEQUE_MUTATORS = ("__setitem__", "__delitem__", "append", "appendleft",
                   "extend", "extendleft", "insert", "pop", "popleft",
                   "remove", "rotate", "clear")
_LIST_MUTATORS = ("__setitem__", "__delitem__", "append", "extend", "insert",
                  "pop", "remove", "sort", "reverse", "clear")

GuardedDict = _guarded_type(dict, _DICT_MUTATORS)
GuardedDeque = _guarded_type(deque, _DEQUE_MUTATORS)
GuardedList = _guarded_type(list, _LIST_MUTATORS)


class Sanitizer:
    """One lock + the guarded-container factories bound to it."""

    def __init__(self, lock: OwnedRLock | None = None):
        self.lock = lock or OwnedRLock()

    def assert_owned(self, what: str) -> None:
        if not self.lock.owned():
            raise ConcurrencyViolation(
                f"unlocked mutation of {what}: the mutating thread "
                f"(ident {threading.get_ident()}) does not hold the "
                f"service lock")

    def _check_for(self, what: str):
        # stored as an *instance* attribute on the guarded container, so
        # it is never descriptor-bound: a plain zero-arg closure
        def check():
            self.assert_owned(what)
        return check

    def check(self, what: str):
        """Public zero-arg ownership probe for guarded state that is not
        a container subclass (e.g. ``metrics.LatencyHistogram`` counts):
        the owner passes it as the object's ``check=`` hook."""
        return self._check_for(what)

    def dict(self, *args, what: str = "a guarded dict", **kwargs):
        return GuardedDict(self._check_for(what), *args, **kwargs)

    def deque(self, *args, what: str = "a guarded deque", **kwargs):
        return GuardedDeque(self._check_for(what), *args, **kwargs)

    def list(self, *args, what: str = "a guarded list", **kwargs):
        return GuardedList(self._check_for(what), *args, **kwargs)


class _Plain:
    """Disabled-path factories: plain builtins, an ordinary RLock."""

    def __init__(self):
        self.lock = None

    def assert_owned(self, what: str) -> None:
        pass

    def dict(self, *args, what: str = "", **kwargs):
        return dict(*args, **kwargs)

    def deque(self, *args, what: str = "", **kwargs):
        return deque(*args, **kwargs)

    def list(self, *args, what: str = "", **kwargs):
        return list(*args, **kwargs)


PLAIN = _Plain()


def sanitizer(explicit: bool | None = None) -> Sanitizer | None:
    """The service-facing switch: ``Sanitizer()`` when asked for
    (explicitly or via ``QBS_SANITIZE``), else ``None``."""
    on = enabled() if explicit is None else bool(explicit)
    return Sanitizer() if on else None
