from .planner import (
    LANE_GENERAL,
    LANE_LANDMARK_PAIR,
    LANE_NAMES,
    LANE_ONE_SIDED,
    LANE_TRIVIAL,
    QueryPlan,
    plan_queries,
)
from .serve_step import (
    greedy_generate,
    make_decode_step,
    make_prefill_step,
    make_spg_serve_step,
    serve_spg_batch,
)
from .service import ResultCache, ServingService

__all__ = [
    "LANE_GENERAL",
    "LANE_LANDMARK_PAIR",
    "LANE_NAMES",
    "LANE_ONE_SIDED",
    "LANE_TRIVIAL",
    "QueryPlan",
    "ResultCache",
    "ServingService",
    "greedy_generate",
    "make_decode_step",
    "make_prefill_step",
    "make_spg_serve_step",
    "serve_spg_batch",
    "plan_queries",
]
