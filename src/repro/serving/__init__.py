from .serve_step import greedy_generate, make_decode_step, make_prefill_step

__all__ = ["greedy_generate", "make_decode_step", "make_prefill_step"]
