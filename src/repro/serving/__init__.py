from .serve_step import (
    greedy_generate,
    make_decode_step,
    make_prefill_step,
    make_spg_serve_step,
    serve_spg_batch,
)

__all__ = [
    "greedy_generate",
    "make_decode_step",
    "make_prefill_step",
    "make_spg_serve_step",
    "serve_spg_batch",
]
