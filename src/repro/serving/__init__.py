from .planner import (
    LANE_GENERAL,
    LANE_LANDMARK_PAIR,
    LANE_NAMES,
    LANE_ONE_SIDED,
    LANE_TRIVIAL,
    QueryPlan,
    merge_plans,
    plan_from_pairs,
    plan_queries,
)
from .serve_step import (
    greedy_generate,
    make_decode_step,
    make_prefill_step,
    make_spg_serve_step,
    serve_spg_batch,
)
from .clock import ManualClock, SystemClock
from .metrics import (
    LatencyHistogram,
    MetricsRegistry,
    merged_latency,
    serve_metrics,
)
from .replicas import ReplicaRouter
from .service import ResultCache, ServingService, round_chunk_to_shards
from .stream import AdmissionPolicy, QoSClass, QueryFuture, StreamingService

__all__ = [
    "AdmissionPolicy",
    "LatencyHistogram",
    "ManualClock",
    "MetricsRegistry",
    "QoSClass",
    "ReplicaRouter",
    "SystemClock",
    "LANE_GENERAL",
    "LANE_LANDMARK_PAIR",
    "LANE_NAMES",
    "LANE_ONE_SIDED",
    "LANE_TRIVIAL",
    "QueryFuture",
    "QueryPlan",
    "ResultCache",
    "ServingService",
    "StreamingService",
    "greedy_generate",
    "make_decode_step",
    "make_prefill_step",
    "make_spg_serve_step",
    "merge_plans",
    "merged_latency",
    "plan_from_pairs",
    "plan_queries",
    "round_chunk_to_shards",
    "serve_metrics",
    "serve_spg_batch",
]
