"""Deadline- and QoS-aware streaming scheduler over the serving service
(DESIGN.md §5 admission, §8 scheduling).

The planner/executor pipeline (``serving.planner`` / ``serving.service``)
answers one complete batch at a time: the caller decides what constitutes
a batch.  Real traffic doesn't arrive that way — queries trickle and
burst, and different submitters deserve different treatment — so this
module owns the *when* and the *who*: a ``StreamingService`` accepts
queries as they arrive (``submit`` / ``submit_batch`` returning per-query
``QueryFuture``s, or the ``serve`` iterator), tags each with a QoS class
(``qos=``), and admits coalesced planner batches under a deficit-weighted,
deadline-bounded scheduler:

* **QoS classes** (``QoSClass``).  Each class carries a ``max_wait``
  wall-clock admission deadline and a scheduling ``weight``.  Untagged
  traffic rides the first (default) class, which has neither — the seed
  single-backlog behavior.
* **Deadline flush.**  A pending pair is admitted no later than
  ``submit_time + max_wait``: submissions and an idle-backlog timer
  (armed through the injectable ``clock`` — ``SystemClock`` in
  production, ``ManualClock`` in tests, see ``serving.clock``) both pump
  the scheduler, and a deadline firing also *syncs* the in-flight window
  so the overdue future resolves.  A query sitting alone in the backlog
  with no further traffic is therefore bounded by its class deadline
  instead of waiting forever on the next driver call.
* **Deficit-weighted class shares.**  Each admission round fills at most
  one chunk width of slots; classes with backlog split those slots in
  proportion to their weights via deficit round-robin (fractional
  entitlements carry over; deadline-expired pairs are taken first and
  debited against their class), so a flooding bulk tenant cannot starve
  interactive traffic, while an idle class's share is never wasted.
* **Adaptive chunk size.**  As before (§5): the padded chunk width walks
  a power-of-two ladder tracking the backlog, bounding jit cache entries.
* **Cross-batch coalescing + dedup.**  A submitted pair whose canonical
  key is already pending or *in flight* joins the existing computation's
  waiter list; a join from a tighter-deadline class *promotes* the pair's
  deadline (never its class weight accounting).
* **Result cache.**  Consulted at submit (hits resolve immediately) and
  filled as chunks drain through ``ServingService.cache_put`` — which
  applies the cache *admission* policy (``cache_admission="reuse"``:
  don't insert predicted one-shot cold pairs).
* **Edge updates / epochs** (DESIGN.md §13).  ``submit_update`` applies
  an edge insert/delete batch: the next epoch's index is computed by
  incremental label maintenance (``QbSIndex.apply_update``) and swapped
  in under the scheduler lock (``install_index`` — the hook the replica
  tier fans a precomputed epoch out through).  Admission pins the epoch:
  every dispatched chunk records the epoch it was admitted under and its
  futures resolve from that epoch's tables (``_flight`` is keyed by
  ``(pair, epoch)``), a submission only *joins* in-flight work of the
  current epoch (an older epoch's flight is stale for it — it goes
  pending and recomputes), and cache keys carry the epoch end-to-end, so
  a stale SPG can never be served.

Dispatch reuses the service's lane machinery (``_chunks``) and its
double-buffered window across admissions.  ``ServingService.query_batch``
remains the one-shot wrapper; ``StreamingService.query_batch``
(submit-all-then-drain) matches it bit-for-bit, and with the default
single-class QoS config every pre-existing admission behavior is
unchanged.
"""
from __future__ import annotations

import heapq
import itertools
import math
import threading
from collections import deque
from dataclasses import dataclass
from typing import Iterable, Iterator, Sequence

import jax
import numpy as np

from ..core.graph import INF
from . import debug
from .clock import ManualClock, SystemClock  # noqa: F401  (re-export)
from .metrics import LatencyHistogram
from .planner import (
    LANE_GENERAL,
    LANE_LANDMARK_PAIR,
    LANE_ONE_SIDED,
    N_LANES,
    d_top_of,
    plan_from_pairs,
)
from .service import ServingService, _NO_EDGES


@dataclass(frozen=True)
class QoSClass:
    """One quality-of-service class (tenant / traffic tier).

    ``max_wait`` is the wall-clock admission deadline in seconds: a pair
    submitted under this class is dispatched to the device lanes at most
    ``max_wait`` after submission (0 = flush immediately at submit;
    ``None`` = no deadline, the pair waits for the size trigger or a
    drain).  ``weight`` is the deficit-round-robin share of admission
    slots when several classes have backlog."""

    name: str
    max_wait: float | None = None
    weight: float = 1.0

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError("QoS weight must be positive")
        if self.max_wait is not None and self.max_wait < 0:
            raise ValueError("max_wait must be >= 0 (or None)")


@dataclass(frozen=True)
class AdmissionPolicy:
    """Knobs of the streaming admission layer.

    ``chunk`` seeds the width ladder (``None``: the index's build-time
    chunk, clamped into ``[min_chunk, max_chunk]``).  With
    ``adaptive=False`` the width is pinned there — the fixed-chunk
    baseline every adaptive row benchmarks against."""

    adaptive: bool = True
    chunk: int | None = None
    min_chunk: int = 4
    max_chunk: int = 128

    def __post_init__(self):
        if self.min_chunk < 1:
            raise ValueError("min_chunk must be positive")
        # snap both bounds onto the power-of-two ladder the adaptive walk
        # uses (min up, max down — never past the caller's stated cap), so
        # halving/doubling can neither escape [min, max] nor mint widths
        # off the ladder
        object.__setattr__(self, "min_chunk",
                           1 << (self.min_chunk - 1).bit_length())
        object.__setattr__(self, "max_chunk",
                           1 << (max(1, self.max_chunk).bit_length() - 1))
        if self.max_chunk < self.min_chunk:
            raise ValueError(
                f"max_chunk rounds to {self.max_chunk} on the power-of-two "
                f"ladder, below min_chunk={self.min_chunk}")

    def initial_chunk(self, default: int) -> int:
        c = default if self.chunk is None else int(self.chunk)
        c = max(self.min_chunk, min(self.max_chunk, c))
        # both bounds sit on the ladder, so the round-up stays in range
        return 1 << (c - 1).bit_length()


class QueryFuture:
    """Handle for one submitted query; resolves when its canonical pair
    is answered (shared by every duplicate submission of that pair).
    ``qos`` records the class this submission rode in under and
    ``t_submit`` its submit instant on the injected clock — the anchor
    the per-class latency histogram measures resolution against.
    ``epoch`` is stamped at resolution with the graph epoch the answer
    was computed under (DESIGN.md §13) — ``None`` while unresolved."""

    __slots__ = ("u", "v", "qos", "t_submit", "epoch", "_stream", "_result")

    def __init__(self, u: int, v: int, stream: "StreamingService",
                 qos: str = "default", t_submit: float = 0.0):
        self.u = int(u)
        self.v = int(v)
        self.qos = qos
        self.t_submit = float(t_submit)
        self.epoch: int | None = None
        self._stream = stream
        self._result = None

    def done(self) -> bool:
        return self._result is not None

    def result(self):
        """The ``SPGResult``; drains the stream first if still unresolved
        (so ``.result()`` never deadlocks on an unflushed admission)."""
        if self._result is None:
            self._stream.drain()
        dist, eids, d_top = self._result
        from ..core.qbs import SPGResult
        return SPGResult(u=self.u, v=self.v, dist=dist, edge_ids=eids,
                         d_top=d_top)

    def _resolve(self, dist: int, eids: np.ndarray, d_top: int) -> None:
        self._result = (dist, eids, d_top)


class StreamingService:
    """Deadline/QoS-scheduled streaming front-end over a ``ServingService``.

    Event-loop style with one lock: ``submit`` buffers into per-class
    backlogs, the scheduler pumps admission rounds inline (size trigger),
    at deadlines (timer through the injected ``clock``), and on ``drain``.
    All execution policy below the admission layer (async window, cache +
    cache admission, mesh) belongs to the inner service — pass its kwargs
    through (``cache_size=``, ``cache_policy=``, ``cache_admission=``,
    ``mesh=`` ...).

    Lock discipline: every field named in ``_QBS_GUARDED_FIELDS`` is
    mutated only under ``with self._lock`` — enforced statically by
    qbslint rule QBS005 (internal helpers reached with the lock already
    held carry ``# qbslint: locked``) and, when ``sanitize=True`` or
    ``QBS_SANITIZE=1``, at runtime by ``serving.debug`` (guarded
    containers + an owner-tracking lock that raise
    ``ConcurrencyViolation`` on an off-lock mutation).
    """

    _QBS_GUARDED_FIELDS = (
        "_queues", "_cls_backlog", "_deficit", "_pending", "_n_pending",
        "_deadline", "_heap", "_waiting", "_flight", "_inflight", "_timer",
        "_timer_token", "_armed_for", "_chunk", "stats", "qos_stats",
        "admission_log", "lat_hist",
    )

    def __init__(self, index, *, policy: AdmissionPolicy | None = None,
                 qos: Sequence[QoSClass] | None = None, clock=None,
                 service: ServingService | None = None,
                 sanitize: bool | None = None, **service_kw):
        if service is not None and service_kw:
            raise ValueError("pass either service= or service kwargs")
        # arm the __setattr__ guard only once construction is done
        object.__setattr__(self, "_qbs", None)
        san = debug.sanitizer(sanitize)
        box = san if san is not None else debug.PLAIN
        self.service = service or ServingService(index, **service_kw)
        self.index = self.service.index
        self.policy = policy or AdmissionPolicy()
        self.clock = clock if clock is not None else SystemClock()
        self._chunk = self.policy.initial_chunk(self.service.chunk)

        self._classes: tuple[QoSClass, ...] = (
            tuple(qos) if qos else (QoSClass("default"),))
        if len({c.name for c in self._classes}) != len(self._classes):
            raise ValueError("duplicate QoS class names")
        self._cls_index = {c.name: i for i, c in enumerate(self._classes)}
        # per-class FIFO backlog of (key, seq); entries are lazily
        # invalidated (skipped) when the key's _pending seq moved on, so
        # _cls_backlog carries the exact live count per class
        self._queues: list[deque] = [
            box.deque(what=f"StreamingService._queues[{c.name}]")
            for c in self._classes]
        self._cls_backlog = box.list([0] * len(self._classes),
                                     what="StreamingService._cls_backlog")
        self._deficit = box.list([0.0] * len(self._classes),
                                 what="StreamingService._deficit")
        # canonical key -> (class idx, submit time, seq) while *pending*
        self._pending: dict[tuple[int, int], tuple[int, float, int]] = \
            box.dict(what="StreamingService._pending")
        self._n_pending = 0
        # canonical key -> earliest admission/resolution deadline while
        # the key is unresolved (pending or in flight); _heap holds
        # (deadline, seq, key) entries, stale ones dropped lazily
        self._deadline: dict[tuple[int, int], float] = \
            box.dict(what="StreamingService._deadline")
        self._heap: list[tuple[float, int, tuple[int, int]]] = \
            box.list(what="StreamingService._heap")
        self._seq = itertools.count()
        self._timer = None
        self._timer_token = None
        self._armed_for: float | None = None
        # serializes submit/drain/poll against clock-thread deadline fires
        self._lock = san.lock if san is not None else threading.RLock()
        # canonical key -> [QueryFuture, ...]; present iff *pending* (not
        # yet admitted) — admission moves the list into _flight under the
        # epoch it dispatched at
        self._waiting: dict[tuple[int, int], list[QueryFuture]] = \
            box.dict(what="StreamingService._waiting")
        # canonical key -> {admission epoch -> [QueryFuture, ...]} while
        # in flight: an update can land between two admissions of the
        # same pair, so one key can legitimately be in flight under two
        # epochs at once, each resolving against its own tables (§13)
        self._flight: dict[tuple[int, int], dict[int, list[QueryFuture]]] = \
            box.dict(what="StreamingService._flight")
        self._inflight: deque = box.deque(
            what="StreamingService._inflight")  # (plan, sel, live, epoch, out)
        self.stats = box.dict({
            "submitted": 0,        # queries accepted
            "trivial": 0,          # resolved at submit (u == v)
            "cache_hits": 0,       # resolved at submit from the cache
            "joined": 0,           # joined a pending/in-flight computation
            "admissions": 0,       # flushes dispatched (1 plan each; the
                                   # per-round detail lives in admission_log)
            "admitted_pairs": 0,   # unique pairs dispatched to lanes
            "chunks": 0,           # device chunks dispatched
            "padded_rows": 0,      # dead rows padded into those chunks
            "deadline_flushes": 0,  # flushes containing an expired pair
            "handed_off": 0,       # pending pairs exported to a peer
                                   # replica (handoff_pending)
            "updates": 0,          # epoch advances installed (§13)
        }, what="StreamingService.stats")
        # waits are wall-clock (injected-clock) seconds from submit to
        # admission — the queueing latency the deadline bounds; bounded
        # deques so a long-running service cannot grow host memory
        self.qos_stats = box.dict({
            c.name: box.dict(
                {"submitted": 0, "trivial": 0, "cache_hits": 0,
                 "joined": 0, "admitted": 0, "expired": 0,
                 "waits": box.deque(
                     maxlen=65536,
                     what=f"StreamingService.qos_stats[{c.name}].waits")},
                what=f"StreamingService.qos_stats[{c.name}]")
            for c in self._classes}, what="StreamingService.qos_stats")
        # one entry per admission round: composition + backlog snapshot
        # (the observability the fairness tests and benchmarks read)
        self.admission_log: deque = box.deque(
            maxlen=4096, what="StreamingService.admission_log")
        # per-class submit->resolution latency histograms, recorded at
        # future-resolution time on the injected clock (metrics layer,
        # DESIGN.md §12); the sanitizer probe guards their counts like
        # every other field in _QBS_GUARDED_FIELDS
        self.lat_hist = box.dict({
            c.name: LatencyHistogram(
                check=(san.check(f"StreamingService.lat_hist[{c.name}]")
                       if san is not None else None))
            for c in self._classes}, what="StreamingService.lat_hist")
        # arm the runtime sanitizer's attribute guard (None when off)
        self._qbs = san

    def __setattr__(self, name, value):
        # runtime half of QBS005 for plain-attribute rebinds (_chunk,
        # _n_pending, the timer trio): guarded containers police their
        # own mutators, this polices `self.<field> = ...`
        qbs = self.__dict__.get("_qbs")
        if qbs is not None and name in self._QBS_GUARDED_FIELDS:
            qbs.assert_owned(f"StreamingService.{name}")
        object.__setattr__(self, name, value)

    # -- introspection -------------------------------------------------------

    @property
    def chunk(self) -> int:
        """Current adaptive chunk width."""
        with self._lock:
            return self._chunk

    @property
    def n_pending(self) -> int:
        with self._lock:
            return self._n_pending

    @property
    def n_inflight(self) -> int:
        with self._lock:
            return len(self._inflight)

    @property
    def qos_classes(self) -> tuple[QoSClass, ...]:
        return self._classes

    # -- submission ----------------------------------------------------------

    def submit(self, u: int, v: int, qos: str | None = None) -> QueryFuture:
        return self.submit_batch([u], [v], qos=qos)[0]

    def submit_batch(self, us, vs, qos: str | None = None) -> list[QueryFuture]:
        """Accept a group of queries that arrived together under one QoS
        class (``None``: the default class); returns one future per query
        (duplicates share a resolution).  May fire admission rounds inline
        when the backlog reaches the chunk width or a deadline (including
        ``max_wait=0``: flush now) expires."""
        us = np.asarray(us, np.int32).reshape(-1)
        vs = np.asarray(vs, np.int32).reshape(-1)
        with self._lock:
            if qos is None:
                ci = 0
            elif qos in self._cls_index:
                ci = self._cls_index[qos]
            else:
                raise ValueError(
                    f"unknown qos class {qos!r}; configured: "
                    f"{[c.name for c in self._classes]}")
            cls = self._classes[ci]
            cstat = self.qos_stats[cls.name]
            now = self.clock.now()
            deadline = None if cls.max_wait is None else now + cls.max_wait
            cache = self.service.cache
            # the epoch this submission answers for: joins, cache lookups
            # and fresh pendings all pin to it (it can only advance under
            # this lock, so one read covers the whole batch)
            ep = self.index.epoch
            futs = []
            for u, v in zip(us.tolist(), vs.tolist()):
                fut = QueryFuture(u, v, self, qos=cls.name, t_submit=now)
                futs.append(fut)
                self.stats["submitted"] += 1
                cstat["submitted"] += 1
                if u == v:
                    fut.epoch = ep
                    fut._resolve(0, _NO_EDGES, INF)
                    self.lat_hist[cls.name].observe(0.0)
                    self.stats["trivial"] += 1
                    cstat["trivial"] += 1
                    # lane_served semantics match the one-shot service:
                    # unique per batch, so per-arrival resolutions (trivial,
                    # cache hits) count once each and re-arrivals recount
                    self.service.lane_served[0] += 1
                    continue
                key = (min(u, v), max(u, v))
                waiters = self._waiting.get(key)
                if waiters is None:
                    # in flight *at this epoch*: its pending result is
                    # exactly what this submission would compute — join.
                    # An older epoch's flight is stale for us: fall
                    # through and go pending (recompute at ep).
                    flight = self._flight.get(key)
                    if flight is not None:
                        waiters = flight.get(ep)
                if waiters is not None:      # pending or in flight: join it
                    waiters.append(fut)
                    self.stats["joined"] += 1
                    cstat["joined"] += 1
                    if deadline is not None and \
                            deadline < self._deadline.get(key, math.inf):
                        # promote the deadline (tighter class joined a
                        # pending/in-flight pair); weight accounting keeps
                        # the admitting class
                        self._deadline[key] = deadline
                        heapq.heappush(self._heap,
                                       (deadline, next(self._seq), key))
                    continue
                if cache is not None:
                    got = cache.get((key[0], key[1], ep))
                    if got is not None:
                        lane = self._lane_of(key)
                        fut.epoch = ep
                        fut._resolve(got[0], got[1],
                                     d_top_of(lane, got[0], INF))
                        self.lat_hist[cls.name].observe(0.0)
                        self.stats["cache_hits"] += 1
                        cstat["cache_hits"] += 1
                        self.service.lane_served[lane] += 1
                        continue
                self._waiting[key] = [fut]
                seq = next(self._seq)
                self._pending[key] = (ci, now, seq)
                self._queues[ci].append((key, seq))
                self._cls_backlog[ci] += 1
                self._n_pending += 1
                if deadline is not None and \
                        deadline < self._deadline.get(key, math.inf):
                    # min-merge, not overwrite: the same key may still be
                    # in flight under an older epoch with a tighter bound
                    self._deadline[key] = deadline
                    heapq.heappush(self._heap, (deadline, seq, key))
            self._pump()
            self._arm_timer()
        return futs

    def serve(self, pairs: Iterable[tuple[int, int]],
              qos: str | None = None) -> Iterator:
        """Streaming iterator entry point: consume ``(u, v)`` pairs as
        they arrive, yield ``SPGResult``s in arrival order as they
        resolve; drains whatever remains when the input ends."""
        out: deque[QueryFuture] = deque()
        for u, v in pairs:
            out.append(self.submit(u, v, qos=qos))
            while out and out[0].done():
                yield out.popleft().result()
        self.drain()
        while out:
            yield out.popleft().result()

    def query_batch(self, us, vs) -> list:
        """One-shot wrapper: submit everything, drain, collect — matches
        ``ServingService.query_batch`` bit-for-bit."""
        futs = self.submit_batch(us, vs)
        self.drain()
        return [f.result() for f in futs]

    def drain(self) -> None:
        """Admit every pending pair and resolve all in-flight work."""
        with self._lock:
            self._pump(force=True)
            self._sync_until(0)
            self._arm_timer()

    def poll(self) -> None:
        """Deadline tick for external drivers: admit whatever is due at
        the current (injected) clock without submitting new traffic.  A
        no-op on an empty backlog — stale timer wakeups are safe."""
        with self._lock:
            self._pump()
            self._arm_timer()

    # -- replica handoff (ReplicaRouter rolling restarts) --------------------

    def handoff_pending(self) -> list:
        """Atomically export every *pending* (not yet admitted) pair for
        adoption by a peer replica: ``[(key, futures, qos name, t_enq,
        deadline | None), ...]``.  In-flight pairs stay — they resolve
        here on the caller's ``drain()`` — so no future is ever dropped
        or double-resolved across a handoff.  Backlog queue entries are
        left to lazy invalidation (their ``_pending`` seq is gone), the
        deadline heap likewise; ``stats['handed_off']`` counts exported
        pairs so the accounting identity stays exact:
        ``admitted_pairs == submitted - trivial - cache_hits - joined -
        handed_off``."""
        with self._lock:
            out = []
            while self._pending:
                key, (ci, t_enq, _seq) = self._pending.popitem()
                futs = self._waiting.pop(key)
                self._n_pending -= 1
                self._cls_backlog[ci] -= 1
                deadline = self._deadline.pop(key, None)
                out.append((key, futs, self._classes[ci].name, t_enq,
                            deadline))
            self.stats["handed_off"] += len(out)
            self._arm_timer()
            return out

    def adopt(self, key: tuple[int, int], futures: list, *, qos: str,
              t_enq: float, deadline: float | None = None) -> None:
        """Absorb one handed-off pair from a draining peer.  The futures
        re-target this stream (their ``result()`` drains here), keep
        their original submit times (latency spans the handoff), and the
        pair re-enters this scheduler through the same resolution paths
        a fresh submission would take: join an existing waiter list,
        resolve from this replica's cache, or go pending with the
        original deadline re-armed."""
        if qos not in self._cls_index:
            raise ValueError(
                f"cannot adopt under unknown qos class {qos!r}; replicas "
                f"must share one QoS config")
        with self._lock:
            ci = self._cls_index[qos]
            cstat = self.qos_stats[qos]
            now = self.clock.now()
            ep = self.index.epoch
            for fut in futures:
                fut._stream = self
            self.stats["submitted"] += len(futures)
            cstat["submitted"] += len(futures)
            waiters = self._waiting.get(key)
            if waiters is None:
                flight = self._flight.get(key)
                if flight is not None:         # current-epoch flight only
                    waiters = flight.get(ep)
            if waiters is not None:            # pending/in flight here: join
                waiters.extend(futures)
                self.stats["joined"] += len(futures)
                cstat["joined"] += len(futures)
                if deadline is not None and \
                        deadline < self._deadline.get(key, math.inf):
                    self._deadline[key] = deadline
                    heapq.heappush(self._heap,
                                   (deadline, next(self._seq), key))
            else:
                cache = self.service.cache
                got = (cache.get((key[0], key[1], ep))
                       if cache is not None else None)
                if got is not None:
                    lane = self._lane_of(key)
                    d_top = d_top_of(lane, got[0], INF)
                    for fut in futures:
                        fut.epoch = ep
                        fut._resolve(got[0], got[1], d_top)
                        self.lat_hist[fut.qos].observe(
                            (now - fut.t_submit) * 1e6)
                    self.stats["cache_hits"] += len(futures)
                    cstat["cache_hits"] += len(futures)
                    self.service.lane_served[lane] += len(futures)
                    self._arm_timer()
                    return
                self._waiting[key] = list(futures)
                seq = next(self._seq)
                self._pending[key] = (ci, t_enq, seq)
                self._queues[ci].append((key, seq))
                self._cls_backlog[ci] += 1
                self._n_pending += 1
                # one creator per fresh pair, like submit_batch duplicates
                self.stats["joined"] += len(futures) - 1
                cstat["joined"] += len(futures) - 1
                if deadline is not None and \
                        deadline < self._deadline.get(key, math.inf):
                    self._deadline[key] = deadline
                    heapq.heappush(self._heap, (deadline, seq, key))
            self._pump()
            self._arm_timer()

    def export_cache(self, pred=None, *, remove: bool = False) -> list:
        """Export packed result-cache entries under the scheduler lock
        (``ResultCache.export_packed``): the router's warm-handoff hook,
        so cache residency moves with key ownership on drain/restore
        instead of re-warming from cold.  ``pred`` filters on the full
        epoched key; ``remove=True`` makes it a move."""
        with self._lock:
            cache = self.service.cache
            if cache is None:
                return []
            return cache.export_packed(pred, remove=remove)

    def import_cache(self, entries) -> None:
        """Absorb packed cache entries exported by a peer replica."""
        with self._lock:
            cache = self.service.cache
            if cache is not None:
                cache.import_packed(entries)

    # -- dynamic updates (DESIGN.md §13) -------------------------------------

    def submit_update(self, inserts=None, deletes=None, *,
                      churn_threshold: float = 0.5):
        """Apply one edge insert/delete batch to the served graph and
        advance the epoch.  The next epoch's index is computed *outside*
        the scheduler lock (incremental label maintenance —
        ``QbSIndex.apply_update`` — can take many milliseconds; serving
        keeps running on the current epoch meanwhile), then swapped in
        atomically via ``install_index``.  Returns the new index.

        Consistency: chunks already dispatched resolve under their
        admission epoch (their device programs hold the old tables);
        pairs still pending admit under the new epoch at their next
        flush; the caches never cross epochs (keys carry the epoch)."""
        new = self.index.apply_update(inserts=inserts, deletes=deletes,
                                      churn_threshold=churn_threshold)
        self.install_index(new)
        return new

    def install_index(self, index) -> None:
        """Install a pre-computed next-epoch index under the scheduler
        lock — the fan-out hook ``ReplicaRouter.apply_update`` uses to
        advance every replica to the *same* index without computing the
        update batch N times."""
        with self._lock:
            self.service.install_index(index)
            self.index = index
            self.stats["updates"] += 1

    def close(self) -> None:
        """Drain outstanding work and disarm the deadline timer, so no
        clock-thread callback outlives the service.  Idempotent, and the
        service stays usable — a later ``submit`` re-arms the timer."""
        self.drain()
        with self._lock:
            if self._timer is not None:
                self._timer.cancel()
                self._timer = None
            self._timer_token = None
            self._armed_for = None

    def __enter__(self) -> "StreamingService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- the scheduler -------------------------------------------------------

    def _adapt_chunk(self, backlog: int) -> None:  # qbslint: locked
        """Track the arrival rate: double while the backlog outruns the
        width, halve while it would fit in half of it."""
        if not self.policy.adaptive or backlog <= 0:
            return
        c = self._chunk
        while backlog > c and c < self.policy.max_chunk:
            c <<= 1
        while backlog <= (c >> 1) and c > self.policy.min_chunk:
            c >>= 1
        self._chunk = c

    def _pump(self, force: bool = False) -> None:  # qbslint: locked
        """The admission loop.  Triggers: an expired deadline (flush the
        overdue pairs now, plus a weighted fill of the rest of the
        round), the size trigger (backlog reached the chunk width), or
        ``force`` (drain).  Once *any* trigger fires, scheduling rounds
        repeat until the backlog drains — the §5 flush-everything
        semantics, so a burst's sub-chunk tail is never stranded behind
        the size trigger — with each round's slots still split by class
        weight: under contention the weights shape dispatch *order*,
        never total work.  The rounds of one flush dispatch as a single
        dense planner batch (``_admit_flush``).  A deadline-triggered
        flush also syncs the in-flight window so the overdue futures
        *resolve* within their bound, not just dispatch."""
        now = self.clock.now()
        expired, expired_inflight = self._pop_expired(now)
        if not (force or expired or self._n_pending >= self._chunk):
            if expired_inflight:
                self._sync_until(0)
            return
        self._adapt_chunk(self._n_pending + len(expired))
        # rounds are the *scheduling* unit (weighted slot accounting,
        # admission_log); the whole flush then plans and dispatches as
        # ONE batch so lanes pack densely across round boundaries — a
        # mixed-lane flush pays per-lane padding once, not per round
        rounds: list[tuple[list, int]] = []
        batch = expired + self._drr_select(self._chunk - len(expired))
        while batch:
            self._log_round(batch, now, n_expired=len(expired))
            rounds.append((batch, len(expired)))
            expired = []
            batch = self._drr_select(self._chunk)
        if rounds:
            self._admit_flush(rounds, now)
        if (rounds and rounds[0][1]) or expired_inflight:
            self._sync_until(0)

    def _pop_expired(self, now: float):  # qbslint: locked
        """Pop every deadline due at ``now``.  Returns the expired
        *pending* entries (removed from the backlog, ready to admit) and
        whether any expired key is already in flight (its round must end
        in a full sync so the overdue future resolves)."""
        expired, expired_inflight = [], False
        while self._heap and self._heap[0][0] <= now:
            dl, _, key = heapq.heappop(self._heap)
            if self._deadline.get(key) != dl:
                continue                          # stale (promoted/resolved)
            del self._deadline[key]
            ent = self._pending.get(key)
            if ent is not None:
                ci, t_enq, _ = ent
                del self._pending[key]
                self._n_pending -= 1
                self._cls_backlog[ci] -= 1
                # charged outside its share; debt is clamped to one round
                # so a long quiet trickle of expiries cannot bank enough
                # debt to suppress the class's weighted share for ages
                self._deficit[ci] = max(self._deficit[ci] - 1.0,
                                        -float(self._chunk))
                self.qos_stats[self._classes[ci].name]["expired"] += 1
                expired.append((key, ci, t_enq))
            elif key in self._flight:
                expired_inflight = True           # joined an in-flight pair
        return expired, expired_inflight

    def _take_from(self, ci: int):  # qbslint: locked
        """Pop the oldest valid pending key of class ``ci`` (skipping
        entries invalidated by expiry-admission or re-submission), or
        None when the class backlog is empty."""
        q = self._queues[ci]
        while q:
            key, seq = q.popleft()
            ent = self._pending.get(key)
            if ent is not None and ent[2] == seq:
                del self._pending[key]
                self._n_pending -= 1
                self._cls_backlog[ci] -= 1
                # the deadline entry stays until *resolution*: if this
                # pair lingers un-synced in the async window, the timer
                # still fires and syncs it within its bound
                return (key, ci, ent[1])
        return None

    def _drr_select(self, budget: int) -> list:  # qbslint: locked
        """Deficit-weighted round-robin: split ``budget`` admission slots
        across the classes that have backlog, in proportion to their
        weights.  Fractional entitlements accumulate in per-class deficit
        counters (so small weights still get served), a class's deficit
        resets when its backlog empties (no hoarding while idle), and any
        slots left by short queues top up from the remaining classes —
        a full round is never under-filled while backlog exists."""
        sel: list = []
        if budget <= 0 or self._n_pending == 0:
            return sel
        active = [i for i, n in enumerate(self._cls_backlog) if n > 0]
        total_w = sum(self._classes[i].weight for i in active)
        for i in active:
            self._deficit[i] += budget * self._classes[i].weight / total_w
        empty = set()
        progress = True
        while len(sel) < budget and progress and self._n_pending:
            progress = False
            for i in active:
                if len(sel) >= budget:
                    break
                if i in empty or self._deficit[i] < 1.0:
                    continue
                got = self._take_from(i)
                if got is None:
                    empty.add(i)
                    self._deficit[i] = 0.0
                    continue
                sel.append(got)
                self._deficit[i] -= 1.0
                progress = True
        # top-up: deficits all fractional (or negative after expiry debits)
        # but slots and backlog remain — grant the largest-deficit class
        while len(sel) < budget and self._n_pending:
            live = [i for i in active if i not in empty]
            if not live:
                break
            i = max(live, key=lambda j: self._deficit[j])
            got = self._take_from(i)
            if got is None:
                empty.add(i)
                self._deficit[i] = 0.0
                continue
            sel.append(got)
            self._deficit[i] = max(self._deficit[i] - 1.0,
                                   -float(self._chunk))
        # no hoarding while idle: a class whose backlog just drained must
        # not bank this round's unspent entitlement for a later flood
        # (the in-loop resets only fire when a take is *attempted*)
        for i in active:
            if self._cls_backlog[i] == 0:
                self._deficit[i] = 0.0
        return sel

    def _log_round(self, batch: list, now: float, n_expired: int) -> None:  # qbslint: locked
        """One admission_log entry per scheduling round, recorded at
        selection time so the backlog snapshot is the round's live
        leftover — the signal the fairness analyses key on."""
        per_class: dict[str, int] = {}
        for _, ci, _ in batch:
            name = self._classes[ci].name
            per_class[name] = per_class.get(name, 0) + 1
        self.admission_log.append({
            "t": now, "n": len(batch), "chunk": self._chunk,
            "expired": n_expired, "per_class": per_class,
            # live counts, not queue lengths: lazily-invalidated entries
            # must not make an idle class look contended
            "backlog": {c.name: self._cls_backlog[i]
                        for i, c in enumerate(self._classes)},
        })

    def _admit_flush(self, rounds: list, now: float) -> None:  # qbslint: locked
        """Dispatch a whole flush — the concatenated scheduling rounds,
        each ``[(key, class idx, submit time), ...]`` — as one planner
        batch through the service's lane machinery at the current chunk
        width, keeping at most ``async_depth`` chunks un-synced in
        flight.  Row order is round order, so the weighted schedule
        decides intra-lane dispatch (and thus resolution) order.

        Epoch pinning (§13): every key admitted here moves from
        ``_waiting`` into ``_flight[key][epoch]`` and every dispatched
        chunk records the epoch — the device programs capture the
        current index's tables at dispatch, and an ``install_index``
        racing this flush is excluded by the scheduler lock, so chunk
        results and the recorded epoch can never disagree."""
        svc = self.service
        ep = self.index.epoch
        batch = [entry for b, _ in rounds for entry in b]
        for key, _, _ in batch:
            self._flight.setdefault(key, {})[ep] = self._waiting.pop(key)
        cu = np.fromiter((k[0][0] for k in batch), np.int32, len(batch))
        cv = np.fromiter((k[0][1] for k in batch), np.int32, len(batch))
        cls = np.fromiter((k[1] for k in batch), np.int16, len(batch))
        plan = plan_from_pairs(cu, cv, self.index._is_landmark_np, cls=cls)
        self.stats["admissions"] += 1
        self.stats["admitted_pairs"] += plan.n_unique
        if any(n_expired for _, n_expired in rounds):
            self.stats["deadline_flushes"] += 1
        # per-class accounting reads the *plan's* class tags — the thing
        # the lanes actually dispatch — so a planner cls-propagation bug
        # surfaces here (waits still need the submit times from batch)
        for (_, _, t_enq), ci in zip(batch, plan.cls.tolist()):
            cstat = self.qos_stats[self._classes[ci].name]
            cstat["admitted"] += 1
            cstat["waits"].append(now - t_enq)
        for k in range(1, N_LANES):
            svc.lane_served[k] += int(plan.lanes[k].size)
        for sel, live, dispatch in svc._chunks(plan, chunk=self._chunk):
            self._inflight.append((plan, sel, live, ep, dispatch()))
            self.stats["chunks"] += 1
            self.stats["padded_rows"] += sel.shape[0] - live
            self._sync_until(svc.async_depth - 1)

    # -- deadline timer ------------------------------------------------------

    def _earliest_deadline(self) -> float | None:  # qbslint: locked
        heap = self._heap
        while heap and self._deadline.get(heap[0][2]) != heap[0][0]:
            heapq.heappop(heap)                   # drop stale entries
        return heap[0][0] if heap else None

    def _arm_timer(self) -> None:  # qbslint: locked
        due = self._earliest_deadline()
        if due == self._armed_for:
            return
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        self._armed_for = due
        if due is not None:
            # the token identifies THIS arming: a SystemClock timer that
            # already fired and is waiting on the lock while another
            # thread re-arms must not clobber the newer timer's tracking
            token = object()
            self._timer_token = token
            self._timer = self.clock.call_at(
                due, lambda: self._on_timer(token))

    def _on_timer(self, token) -> None:
        with self._lock:
            if token is self._timer_token:
                self._timer = None
                self._armed_for = None
                self._timer_token = None
            # stale fires still pump: the wakeup is an idempotent poll
            self._pump()
            self._arm_timer()

    # -- resolution ----------------------------------------------------------

    def _sync_until(self, limit: int) -> None:  # qbslint: locked
        now = self.clock.now()
        while len(self._inflight) > limit:
            plan, sel, live, ep, out = self._inflight.popleft()
            d, m = jax.device_get(out)
            for k in range(live):
                row = int(sel[k])
                key = (int(plan.cu[row]), int(plan.cv[row]))
                eids = np.flatnonzero(m[k]).astype(np.int32)
                eids.flags.writeable = False   # shared: waiters + cache
                dist = int(d[k])
                d_top = d_top_of(int(plan.lane[row]), dist, INF)
                flight = self._flight[key]
                for fut in flight.pop(ep):
                    fut.epoch = ep
                    fut._resolve(dist, eids, d_top)
                    # resolution-time latency on the injected clock: under
                    # ManualClock this is a pure function of the trace
                    self.lat_hist[fut.qos].observe(
                        (now - fut.t_submit) * 1e6)
                if not flight:
                    del self._flight[key]
                if key not in self._waiting and key not in self._flight:
                    # the pair may have been re-submitted (pending at a
                    # newer epoch) or still be in flight under another
                    # epoch — its deadline must survive this resolution
                    self._deadline.pop(key, None)
                self.service.cache_put((key[0], key[1], ep), (dist, eids))

    def _lane_of(self, key: tuple[int, int]) -> int:
        """Scalar lane classification for submit-time (cache-hit)
        resolutions — two bool lookups, no array construction, because
        this sits on the hot path the cache exists to make fast.  Cached
        keys are never trivial (u == v resolves before the cache)."""
        is_l = self.index._is_landmark_np
        lu = bool(is_l[key[0]])
        lv = bool(is_l[key[1]])
        if lu and lv:
            return LANE_LANDMARK_PAIR
        if lu or lv:
            return LANE_ONE_SIDED
        return LANE_GENERAL
