"""Streaming admission control over the serving service (DESIGN.md §5).

The planner/executor pipeline (``serving.planner`` / ``serving.service``)
answers one complete batch at a time: the caller decides what constitutes
a batch.  Real traffic doesn't arrive that way — queries trickle and
burst — so this module owns the *when*: a ``StreamingService`` accepts
queries as they arrive (``submit`` / ``submit_batch`` returning per-query
``QueryFuture``s, or the ``serve`` iterator), coalesces them across
arrival boundaries into planner batches, and dispatches them under an
explicit ``AdmissionPolicy``:

* **Adaptive chunk size.**  The padded chunk width tracks the arrival
  rate: it grows (powers of two up to ``max_chunk``) while the backlog
  outruns it — heavy traffic pays fewer per-chunk dispatches — and
  shrinks toward ``min_chunk`` when admissions run light, so bursty
  traffic doesn't pad a trickle of live queries out to a full-width
  chunk.  Widths stay on the power-of-two ladder, so every jitted lane
  step compiles at most ``log2(max_chunk / min_chunk) + 1`` widths.
* **Cross-batch coalescing + dedup.**  Pending pairs from different
  arrivals merge into one planner batch (``planner.merge_plans``); a
  submitted pair whose canonical key is already pending or *in flight*
  joins the existing computation's waiter list instead of recomputing —
  the streaming extension of the planner's within-batch dedup.
* **Result cache.**  The inner service's canonical-pair cache
  (``cache_policy="lru"`` or the hub-skew-aware ``"hub"``) is consulted
  at submit time — hits resolve their futures immediately — and filled
  as in-flight chunks drain.

Dispatch itself reuses the service's lane machinery (``_chunks``) and its
double-buffered window: up to ``async_depth`` chunks stay un-synced in
flight **across admissions**, so device compute overlaps both host
post-processing and the next arrivals.  ``ServingService.query_batch``
remains the one-shot wrapper for callers that do have a complete batch;
``StreamingService.query_batch`` (submit-all-then-drain) matches it
bit-for-bit.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Iterable, Iterator

import jax
import numpy as np

from ..core.graph import INF
from .planner import (
    LANE_GENERAL,
    LANE_LANDMARK_PAIR,
    LANE_ONE_SIDED,
    N_LANES,
    QueryPlan,
    d_top_of,
    merge_plans,
    plan_from_pairs,
)
from .service import ServingService, _NO_EDGES


@dataclass(frozen=True)
class AdmissionPolicy:
    """Knobs of the streaming admission layer.

    ``chunk`` seeds the width ladder (``None``: the index's build-time
    chunk, clamped into ``[min_chunk, max_chunk]``).  With
    ``adaptive=False`` the width is pinned there — the fixed-chunk
    baseline every adaptive row benchmarks against."""

    adaptive: bool = True
    chunk: int | None = None
    min_chunk: int = 4
    max_chunk: int = 128

    def __post_init__(self):
        if self.min_chunk < 1:
            raise ValueError("min_chunk must be positive")
        # snap both bounds onto the power-of-two ladder the adaptive walk
        # uses (min up, max down — never past the caller's stated cap), so
        # halving/doubling can neither escape [min, max] nor mint widths
        # off the ladder
        object.__setattr__(self, "min_chunk",
                           1 << (self.min_chunk - 1).bit_length())
        object.__setattr__(self, "max_chunk",
                           1 << (max(1, self.max_chunk).bit_length() - 1))
        if self.max_chunk < self.min_chunk:
            raise ValueError(
                f"max_chunk rounds to {self.max_chunk} on the power-of-two "
                f"ladder, below min_chunk={self.min_chunk}")

    def initial_chunk(self, default: int) -> int:
        c = default if self.chunk is None else int(self.chunk)
        c = max(self.min_chunk, min(self.max_chunk, c))
        # both bounds sit on the ladder, so the round-up stays in range
        return 1 << (c - 1).bit_length()


class QueryFuture:
    """Handle for one submitted query; resolves when its canonical pair
    is answered (shared by every duplicate submission of that pair)."""

    __slots__ = ("u", "v", "_stream", "_result")

    def __init__(self, u: int, v: int, stream: "StreamingService"):
        self.u = int(u)
        self.v = int(v)
        self._stream = stream
        self._result = None

    def done(self) -> bool:
        return self._result is not None

    def result(self):
        """The ``SPGResult``; drains the stream first if still unresolved
        (so ``.result()`` never deadlocks on an unflushed admission)."""
        if self._result is None:
            self._stream.drain()
        dist, eids, d_top = self._result
        from ..core.qbs import SPGResult
        return SPGResult(u=self.u, v=self.v, dist=dist, edge_ids=eids,
                         d_top=d_top)

    def _resolve(self, dist: int, eids: np.ndarray, d_top: int) -> None:
        self._result = (dist, eids, d_top)


class StreamingService:
    """Admission-controlled streaming front-end over a ``ServingService``.

    Single-threaded event-loop style: ``submit`` buffers, admission fires
    inline once the backlog reaches the current chunk width, ``drain``
    flushes everything.  All execution policy below the admission layer
    (async window, cache, mesh) belongs to the inner service — pass its
    kwargs through (``cache_size=``, ``cache_policy=``, ``mesh=`` ...).
    """

    def __init__(self, index, *, policy: AdmissionPolicy | None = None,
                 service: ServingService | None = None, **service_kw):
        if service is not None and service_kw:
            raise ValueError("pass either service= or service kwargs")
        self.service = service or ServingService(index, **service_kw)
        self.index = self.service.index
        self.policy = policy or AdmissionPolicy()
        self._chunk = self.policy.initial_chunk(self.service.chunk)
        # one sub-plan per arrival group, planned O(group) at submit time
        # and merged once per admission (merge_plans); keys are disjoint
        # across sub-plans because _waiting dedups at submit
        self._pending_plans: list[QueryPlan] = []
        self._n_pending = 0
        # canonical key -> [QueryFuture, ...]; present iff pending/in-flight
        self._waiting: dict[tuple[int, int], list[QueryFuture]] = {}
        self._inflight: deque = deque()          # (plan, sel, live, device out)
        self.stats = {
            "submitted": 0,        # queries accepted
            "trivial": 0,          # resolved at submit (u == v)
            "cache_hits": 0,       # resolved at submit from the cache
            "joined": 0,           # joined a pending/in-flight computation
            "admissions": 0,       # admitted planner batches
            "admitted_pairs": 0,   # unique pairs dispatched to lanes
            "chunks": 0,           # device chunks dispatched
            "padded_rows": 0,      # dead rows padded into those chunks
        }

    # -- introspection -------------------------------------------------------

    @property
    def chunk(self) -> int:
        """Current adaptive chunk width."""
        return self._chunk

    @property
    def n_pending(self) -> int:
        return self._n_pending

    @property
    def n_inflight(self) -> int:
        return len(self._inflight)

    # -- submission ----------------------------------------------------------

    def submit(self, u: int, v: int) -> QueryFuture:
        return self.submit_batch([u], [v])[0]

    def submit_batch(self, us, vs) -> list[QueryFuture]:
        """Accept a group of queries that arrived together; returns one
        future per query (duplicates share a resolution).  May fire an
        admission inline when the backlog reaches the chunk width."""
        us = np.asarray(us, np.int32).reshape(-1)
        vs = np.asarray(vs, np.int32).reshape(-1)
        is_l = self.index._is_landmark_np
        cache = self.service.cache
        futs = []
        new_cu: list[int] = []
        new_cv: list[int] = []
        for u, v in zip(us.tolist(), vs.tolist()):
            fut = QueryFuture(u, v, self)
            futs.append(fut)
            self.stats["submitted"] += 1
            if u == v:
                fut._resolve(0, _NO_EDGES, INF)
                self.stats["trivial"] += 1
                # lane_served semantics match the one-shot service: unique
                # per batch, so per-arrival resolutions (trivial, cache
                # hits) count once each and re-arrivals recount
                self.service.lane_served[0] += 1
                continue
            key = (min(u, v), max(u, v))
            waiters = self._waiting.get(key)
            if waiters is not None:          # pending or in flight: join it
                waiters.append(fut)
                self.stats["joined"] += 1
                continue
            if cache is not None:
                got = cache.get(key)
                if got is not None:
                    lane = self._lane_of(key)
                    fut._resolve(got[0], got[1],
                                 d_top_of(lane, got[0], INF))
                    self.stats["cache_hits"] += 1
                    self.service.lane_served[lane] += 1
                    continue
            self._waiting[key] = [fut]
            new_cu.append(key[0])
            new_cv.append(key[1])
        if new_cu:
            fresh = plan_from_pairs(np.asarray(new_cu, np.int32),
                                    np.asarray(new_cv, np.int32), is_l)
            self._pending_plans.append(fresh)
            self._n_pending += fresh.n_unique
        if self.n_pending >= self._chunk:
            self._adapt_chunk(self.n_pending)
            self._admit()
        return futs

    def serve(self, pairs: Iterable[tuple[int, int]]) -> Iterator:
        """Streaming iterator entry point: consume ``(u, v)`` pairs as
        they arrive, yield ``SPGResult``s in arrival order as they
        resolve; drains whatever remains when the input ends."""
        out: deque[QueryFuture] = deque()
        for u, v in pairs:
            out.append(self.submit(u, v))
            while out and out[0].done():
                yield out.popleft().result()
        self.drain()
        while out:
            yield out.popleft().result()

    def query_batch(self, us, vs) -> list:
        """One-shot wrapper: submit everything, drain, collect — matches
        ``ServingService.query_batch`` bit-for-bit."""
        futs = self.submit_batch(us, vs)
        self.drain()
        return [f.result() for f in futs]

    def drain(self) -> None:
        """Admit every pending pair and resolve all in-flight work."""
        if self._pending_plans:
            self._adapt_chunk(self.n_pending)
            self._admit()
        self._sync_until(0)

    # -- admission -----------------------------------------------------------

    def _adapt_chunk(self, backlog: int) -> None:
        """Track the arrival rate: double while the backlog outruns the
        width, halve while it would fit in half of it."""
        if not self.policy.adaptive or backlog <= 0:
            return
        c = self._chunk
        while backlog > c and c < self.policy.max_chunk:
            c <<= 1
        while backlog <= (c >> 1) and c > self.policy.min_chunk:
            c >>= 1
        self._chunk = c

    def _admit(self) -> None:
        """Coalesce the pending sub-plans into one planner batch
        (``merge_plans``) and dispatch it in chunks of the current width,
        keeping at most ``async_depth`` chunks un-synced in flight."""
        plans, self._pending_plans = self._pending_plans, []
        self._n_pending = 0
        if not plans:
            return
        plan = merge_plans(plans, self.index._is_landmark_np)
        if plan.n_unique == 0:
            return
        svc = self.service
        self.stats["admissions"] += 1
        self.stats["admitted_pairs"] += plan.n_unique
        for k in range(1, N_LANES):
            svc.lane_served[k] += int(plan.lanes[k].size)
        for sel, live, dispatch in svc._chunks(plan, chunk=self._chunk):
            self._inflight.append((plan, sel, live, dispatch()))
            self.stats["chunks"] += 1
            self.stats["padded_rows"] += sel.shape[0] - live
            self._sync_until(svc.async_depth - 1)

    def _sync_until(self, limit: int) -> None:
        while len(self._inflight) > limit:
            plan, sel, live, out = self._inflight.popleft()
            d, m = jax.device_get(out)
            for k in range(live):
                row = int(sel[k])
                key = (int(plan.cu[row]), int(plan.cv[row]))
                eids = np.flatnonzero(m[k])
                eids.flags.writeable = False   # shared: waiters + cache
                dist = int(d[k])
                d_top = d_top_of(int(plan.lane[row]), dist, INF)
                for fut in self._waiting.pop(key):
                    fut._resolve(dist, eids, d_top)
                if self.service.cache is not None:
                    self.service.cache.put(key, (dist, eids))

    def _lane_of(self, key: tuple[int, int]) -> int:
        """Scalar lane classification for submit-time (cache-hit)
        resolutions — two bool lookups, no array construction, because
        this sits on the hot path the cache exists to make fast.  Cached
        keys are never trivial (u == v resolves before the cache)."""
        is_l = self.index._is_landmark_np
        lu = bool(is_l[key[0]])
        lv = bool(is_l[key[1]])
        if lu and lv:
            return LANE_LANDMARK_PAIR
        if lu or lv:
            return LANE_ONE_SIDED
        return LANE_GENERAL
