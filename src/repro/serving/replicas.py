"""Replica serving tier: N shared-nothing streaming replicas behind a
consistent-hash router (DESIGN.md §12, ROADMAP item 5).

One ``StreamingService`` process is not "millions of users".  The router
owns N replicas — each a full ``StreamingService`` over its *own*
``ServingService`` (own scheduler, own lock, own result cache, own
injectable clock) — and routes every query by consistent hashing on the
canonical ``(min, max)`` pair key.  Because the cache key *is* the
routing key, each cached pair lives on exactly one replica: the
hub-skewed repeat traffic that makes PLL-style label serving cacheable
partitions across the tier instead of duplicating into every replica's
cache (summed hot-key bytes stay at the single-service level however
many replicas run — pinned by ``tests/test_replica_router.py``).

* **Consistent hashing.**  Each replica owns ``vnodes`` points on a
  64-bit ring, positioned by a splitmix64-style integer mix (never
  Python's randomized ``hash``) so placement is deterministic across
  processes and runs.  A key routes to the first *live* replica at or
  after its ring point; draining a replica therefore re-routes only that
  replica's key range — the consistent-hashing property that makes
  rolling restarts cheap.
* **Drain/handoff.**  ``drain_replica(i)`` marks ``i`` not live (its
  range re-routes), atomically exports its pending pairs
  (``StreamingService.handoff_pending``) into their new owners
  (``adopt`` — futures re-target the adopting replica, keep their submit
  times and deadlines), then drains ``i``'s in-flight window so every
  already-dispatched future resolves in place.  No future is dropped or
  double-resolved, and the accounting identity holds per replica
  (``handed_off`` balances the exported creators).  Cache residency
  moves with ownership in both directions: drain ships ``i``'s packed
  cache entries to the covering peers, restore ships the range's
  entries back — so neither the drain nor the rejoin serves its hot set
  cold (the restored-replica p99 spike this tier used to pay).
* **Epoch fan-out** (DESIGN.md §13).  ``apply_update`` computes the next
  epoch's index once and installs it on every replica under the router
  lock, so the tier advances atomically with respect to routing — no
  two replicas ever serve the same pair from different epochs.
* **Bit-identity.**  Routing only partitions *which* replica computes a
  pair; every replica serves from the same index, so
  ``ReplicaRouter(n_replicas=N)`` is bit-identical to a single service
  on ``(dist, edge_ids)`` — pinned against the numpy oracle by the
  property fuzz harness for any interleaving of submits, clock advances,
  drains, and mid-trace replica drains/restores.

Per-replica clocks: pass ``clocks=[...]`` (one per replica — tests and
``benchmarks/trace_replay.py`` drive lockstep ``ManualClock``s) or leave
``None`` for per-replica ``SystemClock``s.  Clocks must share a time
base: handed-off submit times are compared against the adopter's clock.
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Sequence

import numpy as np

from . import debug
from .clock import SystemClock
from .stream import StreamingService

_MASK64 = (1 << 64) - 1


def mix64(x: int) -> int:
    """splitmix64 finalizer: deterministic 64-bit avalanche mix."""
    x &= _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def key_point(key: tuple[int, int]) -> int:
    """Ring position of a canonical pair key (vertex ids fit 31 bits)."""
    return mix64((key[0] << 32) | (key[1] & 0xFFFFFFFF))


class ReplicaRouter:
    """Consistent-hash front-end over N shared-nothing streaming replicas.

    Construction kwargs mirror ``StreamingService`` — ``policy=``,
    ``qos=``, plus the inner ``ServingService`` kwargs (``cache_size=``,
    ``cache_policy=``, ...) — and apply to *every* replica, so the tier
    is homogeneous (a requirement of handoff: adopted pairs must find
    their QoS class on the new owner).

    Lock discipline matches ``StreamingService``: ``_live`` and
    ``stats`` are mutated only under ``with self._lock`` (QBS005 + the
    runtime sanitizer); each replica's scheduler state stays behind its
    own lock — the router never reaches into one.
    """

    _QBS_GUARDED_FIELDS = ("_live", "stats")

    def __init__(self, index, *, n_replicas: int = 2, vnodes: int = 64,
                 clocks: Sequence | None = None, sanitize: bool | None = None,
                 **stream_kw):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if clocks is not None and len(clocks) != n_replicas:
            raise ValueError(
                f"clocks has {len(clocks)} entries for {n_replicas} replicas")
        object.__setattr__(self, "_qbs", None)
        san = debug.sanitizer(sanitize)
        box = san if san is not None else debug.PLAIN
        self.index = index
        self.replicas: tuple[StreamingService, ...] = tuple(
            StreamingService(
                index,
                clock=(clocks[i] if clocks is not None else SystemClock()),
                sanitize=sanitize, **stream_kw)
            for i in range(n_replicas))
        # the ring: vnodes points per replica, sorted once — liveness is
        # checked at lookup (a dead replica's points are skipped), so
        # drain/restore never rebuilds the ring
        points = []
        for i in range(n_replicas):
            for j in range(vnodes):
                points.append((mix64(0x9E3779B97F4A7C15 * (i + 1) + j), i))
        points.sort()
        self._ring_points = [p for p, _ in points]
        self._ring_owner = [i for _, i in points]
        self._live = box.list([True] * n_replicas,
                              what="ReplicaRouter._live")
        self.stats = box.dict({
            "routed": 0,          # queries routed to a replica
            "drains": 0,          # drain_replica calls
            "restores": 0,        # restore_replica calls
            "handoffs": 0,        # pairs re-homed by drains
            "cache_shipped": 0,   # packed cache entries moved with key
                                  # ownership (drain + restore warmups)
            "updates": 0,         # epoch advances fanned out (§13)
        }, what="ReplicaRouter.stats")
        self._lock = san.lock if san is not None else threading.RLock()
        self._qbs = san

    def __setattr__(self, name, value):
        qbs = self.__dict__.get("_qbs")
        if qbs is not None and name in self._QBS_GUARDED_FIELDS:
            qbs.assert_owned(f"ReplicaRouter.{name}")
        object.__setattr__(self, name, value)

    # -- routing -------------------------------------------------------------

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    def live_replicas(self) -> list[int]:
        with self._lock:
            return [i for i, up in enumerate(self._live) if up]

    def _owner_locked(self, key: tuple[int, int]) -> int:  # qbslint: locked
        return self._owner_of(key, self._live)

    def _owner_of(self, key: tuple[int, int], live) -> int:
        """Ring lookup against an explicit liveness vector.  ``_live``
        callers hold the lock; snapshot callers (``_owner_fn``) pass an
        immutable copy so the lookup itself is lock-free."""
        pts, owners = self._ring_points, self._ring_owner
        n = len(pts)
        start = bisect_left(pts, key_point(key)) % n
        for step in range(n):
            i = owners[(start + step) % n]
            if live[i]:
                return i
        raise RuntimeError("no live replica")

    def _owner_fn(self):
        """A pure owner-lookup closure over a liveness snapshot — safe to
        call while holding a *replica's* lock (the cache warm-handoff
        export predicates), where taking the router lock would invert
        the router->replica lock order."""
        with self._lock:
            live = tuple(self._live)
        return lambda key: self._owner_of(key, live)

    def owner_of(self, u: int, v: int) -> int:
        """Replica index currently owning the canonical pair (u, v)."""
        with self._lock:
            return self._owner_locked((min(int(u), int(v)),
                                       max(int(u), int(v))))

    # -- submission ----------------------------------------------------------

    def submit(self, u: int, v: int, qos: str | None = None):
        return self.submit_batch([u], [v], qos=qos)[0]

    def submit_batch(self, us, vs, qos: str | None = None) -> list:
        """Route a batch to its owning replicas; returns futures in the
        caller's order.  Pairs sharing an owner go down in one
        ``submit_batch`` so per-replica coalescing/dedup still sees the
        whole sub-batch."""
        us = np.asarray(us, np.int32).reshape(-1)
        vs = np.asarray(vs, np.int32).reshape(-1)
        with self._lock:
            by_owner: dict[int, list[int]] = {}
            for k, (u, v) in enumerate(zip(us.tolist(), vs.tolist())):
                i = self._owner_locked((min(u, v), max(u, v)))
                by_owner.setdefault(i, []).append(k)
            self.stats["routed"] += int(us.size)
        futs: list = [None] * us.size
        for i, rows in by_owner.items():
            got = self.replicas[i].submit_batch(us[rows], vs[rows], qos=qos)
            for k, fut in zip(rows, got):
                futs[k] = fut
        return futs

    def drain(self) -> None:
        """Drain every replica (live and draining — in-flight work on a
        drained replica still resolves here)."""
        for rep in self.replicas:
            rep.drain()

    def poll(self) -> None:
        for rep in self.replicas:
            rep.poll()

    def query_batch(self, us, vs) -> list:
        """One-shot wrapper: submit everything, drain the tier, collect
        — bit-identical to a single service on ``(dist, edge_ids)``."""
        futs = self.submit_batch(us, vs)
        self.drain()
        return [f.result() for f in futs]

    # -- rolling restarts ----------------------------------------------------

    def drain_replica(self, i: int) -> int:
        """Take replica ``i`` out of rotation for a rolling restart:
        re-route its key range, re-home its pending pairs into the new
        owners, resolve its in-flight window in place, and *move* its
        packed result-cache entries to the keys' new owners (the warm
        half of the handoff: re-routed repeat traffic keeps hitting
        instead of recomputing its hot set cold).  Returns the number of
        pairs handed off; ``restore_replica`` puts the replica back."""
        with self._lock:
            if not self._live[i]:
                raise ValueError(f"replica {i} is already draining")
            if sum(self._live) == 1:
                raise ValueError("cannot drain the last live replica")
            self._live[i] = False
            self.stats["drains"] += 1
        handoff = self.replicas[i].handoff_pending()
        for key, futures, qos, t_enq, deadline in handoff:
            with self._lock:
                j = self._owner_locked(key)
                self.stats["handoffs"] += 1
            self.replicas[j].adopt(key, futures, qos=qos, t_enq=t_enq,
                                   deadline=deadline)
        self.replicas[i].drain()       # in-flight pairs resolve in place
        self._ship_cache_from(i)
        return len(handoff)

    def restore_replica(self, i: int) -> None:
        """Return a drained replica to rotation: its key range routes
        back on the next lookup (keys handed off while draining finish
        where they were adopted), and the range's packed cache entries
        ship back from the covering peers — without this the restored
        replica rejoins *cold* and every repeat pair in its range pays a
        full recompute (the post-restore p99 spike pinned by
        ``benchmarks/trace_replay.py``)."""
        with self._lock:
            if self._live[i]:
                raise ValueError(f"replica {i} is already live")
            self._live[i] = True
            self.stats["restores"] += 1
        # peers covered i's range while it was out; with i live again,
        # any entry now owned by i moves home (cache keys are epoched
        # (u, v, epoch) — routing reads the pair, key[:2])
        owner = self._owner_fn()
        moved = 0
        for j, rep in enumerate(self.replicas):
            if j == i:
                continue
            entries = rep.export_cache(
                pred=lambda key: owner(key[:2]) == i, remove=True)
            if entries:
                self.replicas[i].import_cache(entries)
                moved += len(entries)
        with self._lock:
            self.stats["cache_shipped"] += moved

    def _ship_cache_from(self, i: int) -> None:
        """Move every packed cache entry off replica ``i`` to its key's
        current owner (``i`` is live=False here, so its range re-routes).
        Entries land with their packed payloads intact, so the adopting
        replicas serve the drained hot set from cache immediately."""
        owner = self._owner_fn()
        moved = self.replicas[i].export_cache(remove=True)
        by_owner: dict[int, list] = {}
        for key, entry in moved:
            by_owner.setdefault(owner(key[:2]), []).append((key, entry))
        for j, entries in by_owner.items():
            self.replicas[j].import_cache(entries)
        with self._lock:
            self.stats["cache_shipped"] += len(moved)

    # -- dynamic updates (DESIGN.md §13) -------------------------------------

    def apply_update(self, inserts=None, deletes=None, *,
                     churn_threshold: float = 0.5):
        """Advance the whole tier one epoch: compute the next index
        *once* (incremental label maintenance on the routed index) and
        install it on every replica — live and draining alike, so a
        restored replica is never behind the tier's epoch.  Serialized
        under the router lock: concurrent updates install in epoch order
        on every replica (router -> replica is the tier's one lock
        order).  Returns the new index."""
        with self._lock:
            new = self.index.apply_update(inserts=inserts, deletes=deletes,
                                          churn_threshold=churn_threshold)
            self.index = new
            for rep in self.replicas:
                rep.install_index(new)
            self.stats["updates"] += 1
        return new

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        for rep in self.replicas:
            rep.close()

    def __enter__(self) -> "ReplicaRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
