"""Injectable monotonic clocks for the streaming scheduler (DESIGN.md §8).

Every deadline behavior in ``serving.stream`` — wall-clock admission
bounds, the idle-backlog flush timer, wait accounting — reads time and
arms timers through this interface instead of calling ``time`` directly,
so the whole scheduling policy surface is testable (and benchmarkable)
in *simulated* time with zero wall-clock sleeps:

* ``SystemClock`` — production: ``time.monotonic`` plus daemon
  ``threading.Timer`` callbacks.  This is what lets a query sitting alone
  in the backlog get admitted with no further driver traffic.
* ``ManualClock`` — tests/benchmarks: time only moves when the driver
  calls ``advance``/``advance_to``, which fires due callbacks *in
  deadline order, at their scheduled instants* (``now()`` reads the
  firing callback's own due time while it runs).  Deterministic by
  construction — scheduler decisions depend only on the trace, never on
  host speed.

The contract is two methods: ``now() -> float`` (monotonic seconds) and
``call_at(t, fn) -> handle`` where ``handle.cancel()`` best-effort
revokes a not-yet-fired callback.  Callbacks may re-arm new timers.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Callable


class SystemClock:
    """Real time: ``time.monotonic`` + daemon ``threading.Timer``s.

    Callbacks fire on a timer thread — ``StreamingService`` serializes
    them against ``submit``/``drain`` with its own lock."""

    def now(self) -> float:
        return time.monotonic()

    def call_at(self, t: float, fn: Callable[[], None]):
        timer = threading.Timer(max(0.0, t - self.now()), fn)
        timer.daemon = True
        timer.start()
        return timer                      # threading.Timer has .cancel()


class _ManualTimer:
    __slots__ = ("fn", "cancelled")

    def __init__(self, fn: Callable[[], None]):
        self.fn = fn
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True


class ManualClock:
    """Deterministic test/bench clock: time moves only via ``advance``.

    ``advance`` fires every due callback at its exact scheduled time (in
    order, ``now()`` returning that time during the callback), so a
    deadline of ``t`` produces an admission stamped *at* ``t`` no matter
    how far past it the driver jumps — waits never exceed the bound by
    simulation artifacts."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._heap: list[tuple[float, int, _ManualTimer]] = []
        self._seq = itertools.count()

    def now(self) -> float:
        return self._now

    def call_at(self, t: float, fn: Callable[[], None]) -> _ManualTimer:
        h = _ManualTimer(fn)
        # never schedule into the past: a due-now callback fires on the
        # next advance (even advance(0)), like a 0-delay system timer
        heapq.heappush(self._heap, (max(float(t), self._now), next(self._seq), h))
        return h

    def advance(self, dt: float) -> None:
        self.advance_to(self._now + float(dt))

    def advance_to(self, t: float) -> None:
        t = float(t)
        while self._heap and self._heap[0][0] <= t:
            due, _, h = heapq.heappop(self._heap)
            if h.cancelled:
                continue
            self._now = max(self._now, due)
            h.fn()                        # may re-arm timers <= t: loop sees them
        self._now = max(self._now, t)
