"""Async SPG serving service: lane execution over a ``QueryPlan``
(DESIGN.md §4).

The service owns *how* a planned batch runs; the planner owns *what* runs
(``serving.planner``).  Execution policy:

* **Double-buffered async dispatch.**  Every lane chunk is a jitted device
  program returning un-synced device arrays; the service keeps up to
  ``async_depth`` chunks in flight and only blocks on the oldest when the
  window is full.  Host post-processing of chunk k (``device_get``,
  per-row ``flatnonzero``, ``SPGResult`` assembly) therefore overlaps the
  device computing chunk k+1.  ``async_depth=1`` degenerates to the
  seed's strictly synchronous dispatch-then-sync loop and exists as the
  benchmark baseline (``benchmarks.serving_throughput``).
* **Result cache.**  An optional cache keyed on the canonical pair
  ``(min(u, v), max(u, v))`` — the same key the planner dedups on — plus
  the serving epoch (DESIGN.md §13: a cached SPG from an earlier graph
  version must never answer a later query), mapping to
  ``(dist, edge_ids)``.  SPGs are orientation-invariant on an
  undirected graph, so one entry serves both directions.  Cache lookups
  happen at plan time (hit rows leave their lanes before any chunking);
  inserts happen as chunks drain.  ``cache_policy="lru"`` is plain LRU;
  ``"hub"`` reserves *protected slots* for entries whose endpoints are
  landmarks or high-degree hubs (``Graph.hub_mask``) — the hub-skew
  eviction policy of DESIGN.md §5: hot hub pairs ride out floods of
  one-shot cold traffic that would evict them from a pure LRU.
  ``cache_admission="reuse"`` additionally refuses *insertion* of
  predicted one-shot cold pairs (non-hub keys are only admitted on their
  second sighting) — DESIGN.md §8.
* **Multi-device.**  With ``mesh=`` (or ``devices=``), general-lane chunks
  run batch-sharded across local devices through
  ``core.distributed.make_serve_step`` (replicated graph/labels, queries
  split over the mesh via ``repro.compat.shard_map``), then re-enter the
  shared symmetrization program.  Landmark lanes stay single-device: they
  are label lookups plus one bounded BFS, never the serving bottleneck.

``QbSIndex.query_batch`` / ``query_batch_arrays`` and
``serving.serve_spg_batch`` are thin delegates over a default service
(``async_depth=2``, no cache, single device), so all scale policy lives
here.
"""
from __future__ import annotations

import warnings
from collections import OrderedDict, deque
from functools import partial
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import INF
from .planner import (
    LANE_GENERAL,
    LANE_LANDMARK_PAIR,
    LANE_ONE_SIDED,
    LANE_TRIVIAL,
    N_LANES,
    QueryPlan,
    chunk_padded,
    d_top_of,
    onesided_roots,
    plan_queries,
)

_NO_EDGES = np.zeros((0,), np.int32)   # edge counts fit int32 (E << 2^31)
_NO_EDGES.flags.writeable = False   # shared by every trivial-lane result


def _pack_result(value: tuple[int, np.ndarray]) -> tuple:
    """Pack a ``(dist, edge_ids)`` result for cache residency (DESIGN.md
    §10): int32 edge ids, delta-encoded as uint16 gaps when the sorted
    (``flatnonzero``-built) id list allows it — the anchor id stays int32.
    Returns ``(nbytes, dist, enc)``; ``nbytes`` feeds the byte-based
    capacity accounting."""
    dist, eids = value
    eids = np.asarray(eids)
    if eids.dtype != np.int32:
        eids = eids.astype(np.int32)
        eids.flags.writeable = False
    if eids.size > 1:
        deltas = np.diff(eids)
        if deltas.min() >= 0 and deltas.max() < (1 << 16):
            d16 = deltas.astype(np.uint16)
            d16.flags.writeable = False
            # 2 bytes per gap + 4-byte anchor + uint16 dist
            return d16.nbytes + 6, int(dist), ("delta", int(eids[0]), d16)
    return eids.nbytes + 2, int(dist), ("raw", eids)


def _unpack_result(entry: tuple) -> tuple[int, np.ndarray]:
    """Decode a packed cache entry back to ``(dist, edge_ids int32)``.
    Decoded arrays are frozen like every shared result array."""
    _, dist, enc = entry
    if enc[0] == "raw":
        return dist, enc[1]
    _, first, d16 = enc
    eids = np.empty((d16.size + 1,), np.int32)
    eids[0] = first
    eids[1:] = d16
    np.cumsum(eids, out=eids)
    eids.flags.writeable = False
    return dist, eids


class ResultCache:
    """``(dist, edge_ids)`` cache keyed on the canonical query pair plus
    the serving epoch (``(u, v, epoch)`` — DESIGN.md §13: an entry
    computed under one epoch must never answer a query admitted under a
    later one, so the epoch rides in the key and stale entries simply
    stop being reachable).  The cache itself is key-shape-agnostic; the
    ``protect`` predicate only ever reads ``key[0]``/``key[1]``.

    Without ``protect`` this is a plain LRU.  With ``protect`` (a predicate
    on the canonical key), ``protected_frac`` of the capacity becomes
    *protected slots*: accepted keys live in their own LRU tier that cold
    traffic cannot evict — eviction always drains the unprotected tier
    first, and protected entries only leave when their own tier overflows
    (the LRU protected entry then *demotes* into the unprotected tier
    rather than dropping).  This is the hub-skew eviction policy: landmark-
    and hub-endpoint pairs dominate repeat traffic, so they keep their
    slots under floods of one-shot pairs.

    Values live *packed* (``_pack_result``: int32/delta-uint16 edge ids)
    and decode on ``get``; ``self.bytes`` tracks the packed payload bytes
    and ``capacity_bytes`` optionally bounds them alongside the entry
    count, so capacity can be provisioned in memory rather than entries.

    ``capacity=0`` is a valid no-op cache: every ``get`` misses and ``put``
    stores nothing (callers can keep the cache object unconditionally).
    """

    def __init__(self, capacity: int, *,
                 protect: Callable[[tuple[int, int]], bool] | None = None,
                 protected_frac: float = 0.5,
                 capacity_bytes: int | None = None):
        if capacity < 0:
            raise ValueError("cache capacity must be non-negative")
        if capacity_bytes is not None and capacity_bytes < 0:
            raise ValueError("cache capacity_bytes must be non-negative")
        self.capacity = int(capacity)
        self.capacity_bytes = (
            None if capacity_bytes is None else int(capacity_bytes))
        self.protect = protect
        self.protected_cap = (
            max(1, int(capacity * protected_frac))
            if protect is not None and capacity else 0)
        # both tiers map key -> (nbytes, dist, enc) packed entries
        self._store: OrderedDict[tuple[int, int], tuple] = (
            OrderedDict())   # unprotected LRU tier
        self._protected: OrderedDict[tuple[int, int], tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0   # entries dropped by capacity pressure
        self.bytes = 0       # packed payload bytes currently resident

    def __len__(self) -> int:
        return len(self._store) + len(self._protected)

    def __contains__(self, key: tuple[int, int]) -> bool:
        return key in self._store or key in self._protected

    def get(self, key: tuple[int, int]):
        for tier in (self._protected, self._store):
            got = tier.get(key)
            if got is not None:
                tier.move_to_end(key)
                self.hits += 1
                return _unpack_result(got)
        self.misses += 1
        return None

    def _evict_one(self) -> None:
        _, entry = (self._store or self._protected).popitem(last=False)
        self.bytes -= entry[0]
        self.evictions += 1

    def bytes_for(self, keys) -> int:
        """Packed resident bytes attributable to ``keys`` (canonical
        pairs; absent keys contribute 0) — the per-replica memory
        attribution the partitioned-cache acceptance checks read."""
        total = 0
        for key in keys:
            entry = self._store.get(key)
            if entry is None:
                entry = self._protected.get(key)
            if entry is not None:
                total += entry[0]
        return total

    def put(self, key: tuple[int, int], value: tuple[int, np.ndarray]) -> None:
        self._insert_packed(key, _pack_result(value))

    def _insert_packed(self, key: tuple, entry: tuple) -> None:
        """Insert one already-packed ``(nbytes, dist, enc)`` entry — the
        shared tail of ``put`` and ``import_packed`` (tier choice,
        demotion, capacity pressure)."""
        if self.capacity == 0:
            return
        # a key lives in exactly one tier; re-put refreshes tier + recency
        old = self._store.pop(key, None)
        if old is None:
            old = self._protected.pop(key, None)
        if old is not None:
            self.bytes -= old[0]
        self.bytes += entry[0]
        if self.protected_cap and self.protect(key):
            self._protected[key] = entry
            while len(self._protected) > self.protected_cap:
                k, v = self._protected.popitem(last=False)
                self._store[k] = v   # demote, don't drop
        else:
            self._store[key] = entry
        while len(self) > self.capacity:
            self._evict_one()
        if self.capacity_bytes is not None:
            while self.bytes > self.capacity_bytes and len(self):
                self._evict_one()

    def export_packed(self, pred=None, *, remove: bool = False) -> list:
        """Export resident entries *packed* — ``[(key, (nbytes, dist,
        enc)), ...]`` in LRU-to-MRU order, so importing in list order
        reproduces the recency order here.  ``pred`` filters on the key;
        ``remove=True`` also evicts the exported entries (a *move*, the
        replica warm-handoff path: the pair's bytes must live on exactly
        one replica, matching the routing invariant)."""
        out = []
        for tier in (self._store, self._protected):
            keys = [k for k in tier if pred is None or pred(k)]
            for k in keys:
                out.append((k, tier[k]))
                if remove:
                    entry = tier.pop(k)
                    self.bytes -= entry[0]
        return out

    def import_packed(self, entries) -> None:
        """Absorb entries exported by a peer's ``export_packed``.  The
        receiving cache re-applies its *own* tier policy per key (replica
        tiers are homogeneous, so a hub-protected entry lands protected
        again) and its own capacity pressure."""
        for key, entry in entries:
            self._insert_packed(key, entry)


def round_chunk_to_shards(chunk: int, n_shards: int) -> int:
    """Round ``chunk`` up to a multiple of ``n_shards`` (the sharded
    general lane splits every chunk evenly across the mesh devices)."""
    if chunk <= 0:
        raise ValueError("chunk must be positive")
    if n_shards <= 1 or chunk % n_shards == 0:
        return chunk
    return ((chunk + n_shards - 1) // n_shards) * n_shards


class ServingService:
    """Planner-routed, lane-overlapped executor over a built ``QbSIndex``."""

    def __init__(self, index, *, async_depth: int = 2, cache_size: int = 0,
                 cache_policy: str = "lru", protected_frac: float = 0.5,
                 hub_top_frac: float = 0.01, cache_admission: str = "all",
                 cache_size_bytes: int | None = None,
                 chunk: int | None = None, mesh=None, devices=None):
        self.index = index
        self.chunk = int(index.chunk if chunk is None else chunk)
        self.async_depth = max(1, int(async_depth))
        self.cache = None
        if cache_size or cache_size_bytes:
            if cache_policy == "lru":
                protect = None
            elif cache_policy == "hub":
                protect = self._hub_protect(hub_top_frac)
            else:
                raise ValueError(f"unknown cache_policy={cache_policy!r}")
            # byte-only provisioning: entry count is unbounded, the packed
            # payload bytes are the capacity (ResultCache accounting)
            cap = cache_size if cache_size else (1 << 62)
            self.cache = ResultCache(cap, protect=protect,
                                     protected_frac=protected_frac,
                                     capacity_bytes=cache_size_bytes)
        # Cache *admission* (insertion) is a separate axis from eviction
        # (cache_policy): "all" inserts every computed result (the seed
        # behavior); "reuse" refuses predicted one-shot cold pairs — a key
        # is inserted only when an endpoint is a landmark/top-degree hub
        # (the traffic skew that predicts repetition, ``Graph.hub_mask``)
        # or when it is seen a second time (a bounded shadow set records
        # first sightings), so a flood of never-repeated cold pairs cannot
        # churn the cache at all, whatever the eviction policy.
        if cache_admission not in ("all", "reuse"):
            raise ValueError(f"unknown cache_admission={cache_admission!r}")
        self.cache_admission = cache_admission
        self._seen_once: OrderedDict | None = None
        if self.cache is not None and cache_admission == "reuse":
            # share the eviction policy's predicate when it exists so the
            # two hub policies can never diverge on hub_top_frac (and the
            # degree sort in Graph.hub_mask runs once)
            self._admit_hot = (self.cache.protect
                               if self.cache.protect is not None
                               else self._hub_protect(hub_top_frac))
            self._seen_once = OrderedDict()
            self._seen_cap = max(64, 4 * min(self.cache.capacity, 1 << 16))
        self.lane_served = [0] * N_LANES   # unique pairs answered per lane
        # service-level counters (the scheduler's stats live on the
        # streaming layer); chunk_roundings counts admission-time widths
        # rounded up to the shard multiple (warned once, counted always)
        self.stats = {"chunk_roundings": 0, "installs": 0}
        self._warned_rounding = False

        if (mesh is not None or devices is not None) and getattr(
                index, "is_sharded", False):
            # a ShardedIndex is already mesh-resident: its lane steps run
            # vertex-sharded over their own mesh (core.sharded), so batch-
            # sharding the general lane on top would need the replicated
            # ctx/scheme tables the sharded index exists to not hold
            raise ValueError(
                "mesh=/devices= batch sharding cannot wrap a sharded index; "
                "ShardedIndex serves from its own mesh already")
        if mesh is None and devices is not None:
            from jax.sharding import Mesh
            if isinstance(devices, int):
                avail = jax.devices()
                if len(avail) < devices:
                    raise ValueError(
                        f"devices={devices} requested but only "
                        f"{len(avail)} visible")
                devs = avail[:devices]
            else:
                devs = list(devices)
            mesh = Mesh(np.array(devs), ("q",))
        self._sharded_general = None
        self._n_shards = 1
        self._mesh = mesh
        if mesh is not None:
            self._n_shards = int(np.prod(list(mesh.shape.values())))
            rounded = round_chunk_to_shards(self.chunk, self._n_shards)
            if rounded != self.chunk:
                self._warned_rounding = True
                warnings.warn(
                    f"chunk={self.chunk} does not divide over "
                    f"{self._n_shards} shards; rounding up to {rounded}",
                    stacklevel=2)
                self.chunk = rounded
            self._sharded_general = self._make_sharded_general()

    def _make_sharded_general(self):
        from ..core.distributed import make_serve_step
        index = self.index
        return make_serve_step(
            index.ctx, index.scheme, self._mesh,
            n_vertices=index.graph.n_vertices,
            max_levels=index.max_levels, max_chain=index.max_chain,
            use_pallas=index.use_pallas, packed=index.packed)

    def install_index(self, index) -> None:
        """Swap in the next epoch's index (an ``apply_update`` product —
        DESIGN.md §13).  Chunks dispatched before the swap already hold
        device handles to the old epoch's tables, so their results stay
        bit-consistent with their admission epoch; the result cache
        survives the swap — its keys carry the epoch, so entries written
        under earlier epochs simply stop being reachable and age out
        under normal eviction pressure.  The hub-protect predicate stays
        pinned at construction (landmarks are pinned across epochs; the
        hub set is an eviction heuristic, not a correctness surface).

        Callers must serialize this against the query entry points —
        ``StreamingService.install_index`` does, under its scheduler
        lock; bare services are single-caller by contract."""
        if getattr(index, "is_sharded", False):
            raise ValueError("cannot install a sharded index")
        if index.epoch <= self.index.epoch:
            raise ValueError(
                f"install_index: epoch {index.epoch} is not ahead of "
                f"serving epoch {self.index.epoch}")
        self.index = index
        self.stats["installs"] += 1
        if self._mesh is not None:
            self._sharded_general = self._make_sharded_general()

    def _hub_protect(self, hub_top_frac: float):
        """Protect predicate for the hub-skew cache policy: a canonical
        pair is protected when either endpoint is a landmark or a
        top-degree hub (``Graph.hub_mask``)."""
        prot = self.index._is_landmark_np | self.index.graph.hub_mask(
            top_frac=hub_top_frac)
        return lambda key: bool(prot[key[0]] or prot[key[1]])

    # -- lane dispatch -------------------------------------------------------

    def _general_step(self, cu, cv):
        if self._sharded_general is None:
            return self.index.serve_step(cu, cv)
        mask, dist = self._sharded_general(cu, cv)
        from ..core.qbs import _symmetrize
        return _symmetrize(dist, mask, self.index._rev_edge_j)

    def _chunks(self, plan: QueryPlan, chunk: int | None = None):
        """Yield ``(unique_rows (chunk,), live, dispatch)`` per lane chunk.
        ``dispatch()`` enqueues the device program and returns un-synced
        device arrays ``(dist (chunk,), edge_mask (chunk, E))``.

        ``chunk`` overrides the service's width for this plan (the
        streaming admission layer picks it adaptively); every jitted lane
        step caches one compile per width, so callers should draw widths
        from a small fixed set.  Sharded services round the override up
        to the shard multiple — warned once per service instance and
        counted in ``stats['chunk_roundings']`` every time, so streaming
        traffic with a misaligned adaptive ladder shows up in metrics
        instead of spamming one warning per admission."""
        if chunk is None:
            chunk = self.chunk
        else:
            rounded = round_chunk_to_shards(int(chunk), self._n_shards)
            if rounded != chunk:
                self.stats["chunk_roundings"] += 1
                if not self._warned_rounding:
                    self._warned_rounding = True
                    warnings.warn(
                        f"admitted chunk={chunk} does not divide over "
                        f"{self._n_shards} shards; rounding up to "
                        f"{rounded} (warned once; see "
                        f"stats['chunk_roundings'])", stacklevel=2)
            chunk = rounded
        idx = self.index
        lid = idx._lid_np

        for sel, live in chunk_padded(plan.lanes[LANE_GENERAL], chunk):
            yield sel, live, partial(self._general_step,
                                     jnp.asarray(plan.cu[sel]),
                                     jnp.asarray(plan.cv[sel]))

        for sel, live in chunk_padded(plan.lanes[LANE_LANDMARK_PAIR],
                                      chunk):
            yield sel, live, partial(idx.landmark_pair_step,
                                     jnp.asarray(lid[plan.cu[sel]]),
                                     jnp.asarray(lid[plan.cv[sel]]))

        one = plan.lanes[LANE_ONE_SIDED]
        if one.size:
            roots, r_idx = onesided_roots(plan.cu[one], plan.cv[one],
                                          idx._is_landmark_np, lid)
            for pos, live in chunk_padded(np.arange(one.size), chunk):
                yield one[pos], live, partial(idx.landmark_onesided_step,
                                              jnp.asarray(roots[pos]),
                                              jnp.asarray(r_idx[pos]))

    def _execute(self, plan: QueryPlan) -> Iterator[tuple]:
        """Drain all device lanes: yields host tuples ``(unique_rows,
        dist (L,), edge_mask (L, E))`` with up to ``async_depth`` chunks in
        flight (the double buffer: chunk k+1 is enqueued before chunk k is
        *synced*, so host post-processing overlaps device compute).

        The overlap pays where host and device are separate silicon (the
        accelerator serving regime this targets); on a small CPU host the
        "device" programs share cores with this thread, so sync and async
        converge to parity there (pinned by
        ``benchmarks/serving_throughput.py``)."""
        inflight: deque = deque()

        def drain(limit: int):
            while len(inflight) > limit:
                sel, live, out = inflight.popleft()
                d, m = jax.device_get(out)
                yield sel[:live], d[:live], m[:live]

        for sel, live, dispatch in self._chunks(plan):
            inflight.append((sel, live, dispatch()))
            yield from drain(self.async_depth - 1)
        yield from drain(0)

    # -- cache ---------------------------------------------------------------

    def _cache_partition(self, plan: QueryPlan):
        """Pull cache hits out of the device lanes.  Returns the reduced
        plan plus ``[(unique_row, dist, edge_ids), ...]`` hits."""
        if self.cache is None:
            return plan, []
        hits = []
        epoch = self.index.epoch
        lanes = list(plan.lanes)
        for k in (LANE_LANDMARK_PAIR, LANE_ONE_SIDED, LANE_GENERAL):
            miss = []
            for row in lanes[k]:
                got = self.cache.get(
                    (int(plan.cu[row]), int(plan.cv[row]), epoch))
                if got is None:
                    miss.append(row)
                else:
                    hits.append((int(row), got[0], got[1]))
            lanes[k] = np.asarray(miss, dtype=np.intp)
        return plan._replace(lanes=tuple(lanes)), hits

    def cache_put(self, key: tuple[int, int, int],
                  value: tuple[int, np.ndarray]) -> None:
        """Insert a computed result through the cache *admission* policy
        (the one insertion path — the streaming scheduler routes through
        it too, so admission policy cannot drift between entry points).
        ``key`` is the epoched cache key ``(u, v, epoch)``."""
        if self.cache is None:
            return
        if self._seen_once is not None and key not in self.cache \
                and not self._admit_hot(key):
            if key not in self._seen_once:       # predicted one-shot: skip
                self._seen_once[key] = None
                while len(self._seen_once) > self._seen_cap:
                    self._seen_once.popitem(last=False)
                return
            del self._seen_once[key]             # second sighting: admit
        self.cache.put(key, value)

    def _cache_put(self, plan: QueryPlan, row: int, dist: int,
                   eids: np.ndarray) -> None:
        self.cache_put(
            (int(plan.cu[row]), int(plan.cv[row]), self.index.epoch),
            (int(dist), eids))

    # -- answers -------------------------------------------------------------

    def _answer_unique(self, plan: QueryPlan):
        """Answer every unique pair: ``(dist (U,) int32, edge_ids list)``."""
        u_dist = np.full((plan.n_unique,), INF, np.int32)
        u_eids: list = [None] * plan.n_unique
        for row in plan.lanes[LANE_TRIVIAL]:
            u_dist[row] = 0
            u_eids[row] = _NO_EDGES
        for k in range(N_LANES):
            self.lane_served[k] += int(plan.lanes[k].size)
        plan, hits = self._cache_partition(plan)
        for row, d, eids in hits:
            u_dist[row] = d
            u_eids[row] = eids
        for rows, d, m in self._execute(plan):
            for k, row in enumerate(rows):
                eids = np.flatnonzero(m[k]).astype(np.int32)
                # Frozen because the array is shared: duplicate queries fan
                # it out to several results and the cache hands it back on
                # later hits — an in-place mutation by a caller must not
                # corrupt either.
                eids.flags.writeable = False
                u_dist[row] = d[k]
                u_eids[row] = eids
                if self.cache is not None:
                    self._cache_put(plan, row, int(d[k]), eids)
        return u_dist, u_eids

    def query_batch(self, us, vs) -> list:
        """Arbitrary batch -> per-query ``SPGResult`` list (original
        orientation preserved; dedup/canonicalization are internal).

        ``edge_ids`` arrays are read-only and may be shared between
        duplicate queries and with the result cache."""
        from ..core.qbs import SPGResult
        us = np.asarray(us, np.int32).reshape(-1)
        vs = np.asarray(vs, np.int32).reshape(-1)
        plan = plan_queries(us, vs, self.index._is_landmark_np)
        u_dist, u_eids = self._answer_unique(plan)
        out = []
        for i in range(plan.n):
            row = plan.inv[i]
            d = int(u_dist[row])
            out.append(SPGResult(u=int(us[i]), v=int(vs[i]), dist=d,
                                 edge_ids=u_eids[row],
                                 d_top=d_top_of(int(plan.lane[row]), d, INF)))
        return out

    def query_arrays(self, us, vs) -> tuple[np.ndarray, np.ndarray]:
        """Arbitrary batch -> raw ``(dist (N,) int32, edge_mask (N, E)
        bool)`` arrays with no per-query result objects.  Same
        routing/cache/execution as ``query_batch`` (one shared
        ``_answer_unique``); only the result assembly differs."""
        us = np.asarray(us, np.int32).reshape(-1)
        vs = np.asarray(vs, np.int32).reshape(-1)
        plan = plan_queries(us, vs, self.index._is_landmark_np)
        u_dist, u_eids = self._answer_unique(plan)
        # one dense mask, filled per query from the (sparse) unique-row
        # edge ids — peak host memory stays a single (N, E) array however
        # many duplicates the batch carries
        mask = np.zeros((plan.n, self.index.graph.n_edges), bool)
        for i, row in enumerate(plan.inv):
            mask[i, u_eids[row]] = True
        return u_dist[plan.inv], mask
