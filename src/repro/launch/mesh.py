"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets the forced host device count
before any jax initialization)."""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            "sets this automatically)"
        )
    return Mesh(np.array(devs[:n]).reshape(shape), axes)


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n != "model")
