"""QbS query-serving driver: build (or load) a labelling scheme for a graph
and answer batched shortest-path-graph queries.

  PYTHONPATH=src python -m repro.launch.serve --graph ba --n 20000 \
      --landmarks 20 --queries 200

``--shards N`` builds the vertex-sharded index instead (labels born
sharded over an N-device mesh, every lane served from the shards —
DESIGN.md §11); emulate devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--replicas N`` serves through the consistent-hash replica tier
(``serving.ReplicaRouter`` — DESIGN.md §12) instead of the bare index,
and ``--metrics-port P`` (0 = ephemeral) exports every replica's
counters and per-QoS latency histograms as a Prometheus-style text
endpoint at ``http://127.0.0.1:P/metrics`` while queries run.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import (
    QbSIndex,
    barabasi_albert_graph,
    gnp_random_graph,
    labelling_size_bytes,
    packed_size_bytes,
    ring_of_cliques,
)


def build_graph(kind: str, n: int, seed: int):
    if kind == "ba":
        return barabasi_albert_graph(n, 3, seed=seed)
    if kind == "gnp":
        return gnp_random_graph(n, 6.0, seed=seed)
    if kind == "cliques":
        return ring_of_cliques(max(n // 8, 2), 8, seed=seed)
    raise ValueError(kind)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="ba", choices=["ba", "gnp", "cliques"])
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--landmarks", type=int, default=20)
    ap.add_argument("--queries", type=int, default=100)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=0,
                    help="build the vertex-sharded index over this many "
                         "devices (0 = replicated single-device index)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve through a consistent-hash ReplicaRouter "
                         "over this many streaming replicas (0 = direct "
                         "index serving)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="export the metrics scrape endpoint on this port "
                         "(0 = pick an ephemeral port); implies at least "
                         "one streaming replica")
    args = ap.parse_args()

    g = build_graph(args.graph, args.n, args.seed)
    print(f"[serve] graph {args.graph}: V={g.n_vertices} E={g.n_edges // 2}")

    t0 = time.perf_counter()
    if args.shards:
        idx = QbSIndex.build(g, n_landmarks=args.landmarks,
                             chunk=args.chunk, sharded=args.shards)
        t1 = time.perf_counter()
        info = idx.sharded_size_bytes()
        print(f"[serve] sharded labelling built in {t1 - t0:.2f}s over "
              f"{info['n_shards']} devices ({idx.labels.pack_dtype})")
        print(f"[serve] per-device bytes: "
              f"{info['per_device_bytes'] / 1e6:.2f}MB "
              f"(labels {info['per_device_label_bytes'] / 1e6:.2f}MB + CSR "
              f"{info['per_device_csr_bytes'] / 1e6:.2f}MB) = "
              f"{info['per_device_frac']:.2f}x of the replicated "
              f"{info['replicated_bytes'] / 1e6:.2f}MB")
    else:
        idx = QbSIndex.build(g, n_landmarks=args.landmarks, chunk=args.chunk)
        t1 = time.perf_counter()
        sz = labelling_size_bytes(idx.scheme)
        psz = packed_size_bytes(idx.packed)
        print(f"[serve] labelling built in {t1 - t0:.2f}s; "
              f"size(L)={sz['label_bytes'] / 1e6:.2f}MB "
              f"meta_edges={sz['n_meta_edges']}")
        print(f"[serve] packed tables: {psz['packed_bytes'] / 1e6:.2f}MB "
              f"({psz['dtype']}, {psz['ratio']:.1f}x smaller than int32)")

    rng = np.random.default_rng(args.seed)
    us = rng.integers(0, g.n_vertices, size=args.queries)
    vs = rng.integers(0, g.n_vertices, size=args.queries)

    n_replicas = args.replicas
    if args.metrics_port is not None and n_replicas == 0:
        n_replicas = 1
    router = server = None
    if n_replicas:
        from ..serving import MetricsRegistry, ReplicaRouter, serve_metrics
        router = ReplicaRouter(idx, n_replicas=n_replicas, cache_size=4096,
                               cache_policy="hub")
        print(f"[serve] replica tier: {n_replicas} replicas behind "
              f"consistent hashing")
        if args.metrics_port is not None:
            registry = MetricsRegistry()
            for i, rep in enumerate(router.replicas):
                registry.register(f"replica{i}", rep)
            server = serve_metrics(registry, port=args.metrics_port)
            print(f"[serve] metrics: http://127.0.0.1:"
                  f"{server.server_address[1]}/metrics")

    t2 = time.perf_counter()
    results = (router.query_batch(us, vs) if router is not None
               else idx.query_batch(us, vs))
    t3 = time.perf_counter()
    dists = np.array([r.dist for r in results], dtype=np.int64)
    sizes = np.array([r.edge_ids.size for r in results])
    print(f"[serve] {args.queries} queries in {t3 - t2:.2f}s "
          f"({(t3 - t2) / args.queries * 1e3:.2f} ms/query incl. host assembly)")
    finite = dists < (1 << 20)
    if finite.any():
        print(f"[serve] dist: mean={dists[finite].mean():.2f} "
              f"max={dists[finite].max()}; SPG edges: mean={sizes.mean():.1f} "
              f"max={sizes.max()}")

    if router is not None:
        routed = router.stats["routed"]
        per_rep = {i: rep.stats["submitted"]
                   for i, rep in enumerate(router.replicas)}
        print(f"[serve] router: {routed} routed, per-replica {per_rep}")
        if server is not None:
            server.shutdown()
        router.close()


if __name__ == "__main__":
    main()
