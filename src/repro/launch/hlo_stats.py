"""Extract roofline inputs from a lowered/compiled jit artifact.

``cost_analysis()`` provides HLO FLOPs and bytes accessed; collective bytes
are NOT in cost_analysis, so we parse the (optimized) HLO text and sum the
output-shape bytes of every collective op, bucketed by kind.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output bytes of every collective instruction, by kind.

    Matches lines like
      ``%x = bf16[8,128]{1,0} all-reduce(%y), replica_groups=...``
      ``%t = (f32[4], f32[4]) all-to-all(...)``
    Excludes `-start/-done` duplicates (counts the -start only).
    """
    out: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        lhs, rhs = s.split(" = ", 1)
        for kind in _COLLECTIVES:
            # opcode appears immediately after the result type
            m = re.match(r"^((?:\([^)]*\))|(?:[\w\[\],{}: ]+?))\s+" + kind + r"(-start)?\(", rhs)
            if m:
                if f"{kind}-done" in rhs:
                    break
                out[kind] += _shape_bytes(m.group(1))
                counts[kind] += 1
                break
    out_total = dict(out)
    out_total["_counts"] = dict(counts)  # type: ignore[assignment]
    return out_total


def summarize_compiled(lowered, compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cost = dict(cost or {})
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_d = {"error": repr(e)}
    text = compiled.as_text()
    coll = collective_bytes(text)
    return {
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "transcendentals": float(cost.get("transcendentals", -1.0)),
        "memory": mem_d,
        "collectives": coll,
        "n_hlo_lines": text.count("\n"),
    }
