"""End-to-end training driver.

Runs on whatever devices exist (1-CPU smoke runs and the examples use tiny
reduced configs; the production mesh path is exercised by dryrun.py).
Features: deterministic sharded data, checkpoint/resume (preemption-safe),
async checkpoint writes, grad accumulation, bf16 grad compression.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --reduced \
      --steps 200 --ckpt-dir /tmp/run1 [--resume]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt
from ..configs import get_config
from ..data import Prefetcher, SyntheticLM, SyntheticLMConfig
from ..models import build_model
from ..training import adamw, compress_bf16, make_train_step, warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    opt = adamw(warmup_cosine(args.lr, max(args.steps // 20, 5), args.steps))

    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    data_cfg = SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed,
        frontend=cfg.frontend, frontend_dim=cfg.frontend_dim,
    )
    start_step = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        start_step, tree, extra = ckpt.restore(
            args.ckpt_dir, {"params": params, "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        print(f"[train] resumed from step {start_step}")

    source = SyntheticLM(data_cfg)
    pf = Prefetcher(source, start_step=start_step, depth=2)
    step_fn = jax.jit(make_train_step(
        model, opt, microbatches=args.microbatches, remat=args.remat,
        compress=compress_bf16 if args.compress_grads else None))

    pending = None
    t0 = time.perf_counter()
    try:
        for step in range(start_step, args.steps):
            got_step, batch = pf.next()
            assert got_step == step
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (step + 1) % args.log_every == 0 or step == start_step:
                dt = (time.perf_counter() - t0) / max(step - start_step + 1, 1)
                print(f"[train] step {step + 1} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} {dt * 1e3:.0f} ms/step",
                      flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                if pending is not None:
                    pending.join()
                pending = ckpt.save_async(
                    args.ckpt_dir, step + 1,
                    {"params": params, "opt": opt_state},
                    extra={"data_step": step + 1})
    finally:
        pf.close()
        if pending is not None:
            pending.join()
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt_state},
                  extra={"data_step": args.steps})
    print("[train] done")


if __name__ == "__main__":
    main()
