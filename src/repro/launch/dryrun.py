import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax import: jax locks the device count at first init.
# Smoke tests / benches never import this module, so they see 1 device.

import argparse
import json
import math
import time
import traceback
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, get_config
from ..configs.qbs_graphs import GRAPHS
from ..models import (
    SHAPES,
    batch_pspecs,
    build_model,
    cache_pspecs,
    cell_applicable,
    input_specs,
    param_pspecs,
)
from ..training import adamw, make_train_step, warmup_cosine
from ..serving import make_decode_step, make_prefill_step
from .hlo_stats import summarize_compiled
from .mesh import dp_axes, make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _shard_tree(mesh, spec_tree):
    return jax.tree_util.tree_map(lambda s: _ns(mesh, s), spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def _sds_tree(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def lower_lm_cell(arch: str, shape_name: str, mesh, *, remat: bool = False,
                  kv_quant: bool = False, zero1: bool = False,  # noqa: doc
                  moe_sort: bool = False, moe_group: bool = False,
                  flash: bool = False, seq_shard: str = "",
                  microbatches: int = 1, kv_layout: str = "hd",
                  depth_probe: bool = True) -> dict:
    from dataclasses import replace as _replace

    cfg = get_config(arch)
    if moe_sort:
        cfg = _replace(cfg, moe_dispatch="sort", moe_ep_anchor=True)
    if moe_group:
        cfg = _replace(cfg, moe_group_size=1024)
    if flash:
        cfg = _replace(cfg, attn_impl="chunked")
    if remat:
        cfg = _replace(cfg, remat_policy="layer")
        remat = False  # cfg-level per-layer remat, not whole-loss remat
    if microbatches > 1:
        pass  # threaded below
    if seq_shard == "dp":      # anchor activations to DP-only sharding
        cfg = _replace(cfg, act_spec=(tuple(dp_axes(mesh)), None, None))
    elif seq_shard == "sp":    # Megatron-SP: sequence sharded over model
        cfg = _replace(cfg, act_spec=(tuple(dp_axes(mesh)), "model", None))
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"skipped": why}

    stats = _lower_lm_once(cfg, shape, mesh, remat=remat, kv_quant=kv_quant,
                           microbatches=microbatches, zero1=zero1,
                           kv_layout=kv_layout)

    if depth_probe:
        # XLA's HloCostAnalysis visits a scan body ONCE, so flops/collective
        # bytes are undercounted by ~n_layers.  Lower two shallow variants
        # and extrapolate linearly in depth (exact for scan-linear programs).
        # Memory/compile proof above still comes from the real-depth program.
        from dataclasses import replace
        per = cfg.hybrid_period or 1
        l1, l2 = per, 2 * per
        if cfg.n_layers > l2:
            s1 = _lower_lm_once(replace(cfg, n_layers=l1, scan_unroll=True),
                                shape, mesh, remat=remat, kv_quant=kv_quant,
                                microbatches=microbatches, zero1=zero1,
                                kv_layout=kv_layout)
            s2 = _lower_lm_once(replace(cfg, n_layers=l2, scan_unroll=True),
                                shape, mesh, remat=remat, kv_quant=kv_quant,
                                microbatches=microbatches, zero1=zero1,
                                kv_layout=kv_layout)
            stats["depth_extrapolated"] = _extrapolate_depth(
                s1, s2, l1, l2, cfg.n_layers)
    return stats


def _extrapolate_depth(s1: dict, s2: dict, l1: int, l2: int, l: int) -> dict:
    def lin(a, b):
        slope = (b - a) / (l2 - l1)
        return a + slope * (l - l1)

    out = {
        "flops": lin(s1["flops"], s2["flops"]),
        "bytes_accessed": lin(s1["bytes_accessed"], s2["bytes_accessed"]),
        "transcendentals": lin(s1["transcendentals"], s2["transcendentals"]),
        "collectives": {},
        "probe_layers": [l1, l2],
    }
    kinds = (set(s1["collectives"]) | set(s2["collectives"])) - {"_counts"}
    for k in kinds:
        out["collectives"][k] = lin(s1["collectives"].get(k, 0),
                                    s2["collectives"].get(k, 0))
    return out


def _lower_lm_once(cfg, shape, mesh, *, remat: bool = False,
                   kv_quant: bool = False, microbatches: int = 1,
                   zero1: bool = False, kv_layout: str = "hd") -> dict:
    model = build_model(cfg)
    dpx = dp_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dpx]))
    axis_sizes = dict(mesh.shape)

    from ..models import sanitize_pspecs

    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspec = sanitize_pspecs(param_pspecs(cfg, params_shapes), params_shapes,
                            axis_sizes)
    p_sh = _shard_tree(mesh, pspec)

    specs = input_specs(cfg, shape, kv_quant=kv_quant)
    t0 = time.perf_counter()

    if shape.kind == "train":
        opt = adamw(warmup_cosine(3e-4, 2000, 100_000))
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        mom_spec = pspec
        if zero1:
            # ZeRO-1: shard optimizer moments over the DP axes on the first
            # dimension they divide (params stay TP-sharded + DP-replicated)
            def z1(spec, leaf):
                dims = list(spec)
                for i, d in enumerate(dims):
                    if d is None and leaf.shape[i] % dp_total == 0:
                        dims[i] = dpx
                        return P(*dims)
                return spec
            mom_spec = jax.tree_util.tree_map(
                z1, pspec, params_shapes, is_leaf=lambda x: isinstance(x, P))
        opt_spec = {"mu": mom_spec, "nu": mom_spec, "step": P()}
        o_sh = _shard_tree(mesh, opt_spec)
        b_spec = sanitize_pspecs(
            batch_pspecs(cfg, specs["batch"], dpx), specs["batch"], axis_sizes)
        b_sh = _shard_tree(mesh, b_spec)
        step = make_train_step(model, opt, remat=remat,
                               microbatches=microbatches)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
        lowered = fn.lower(params_shapes, opt_shapes, specs["batch"])
    elif shape.kind == "prefill":
        b_spec = sanitize_pspecs(
            batch_pspecs(cfg, specs["batch"], dpx), specs["batch"], axis_sizes)
        b_sh = _shard_tree(mesh, b_spec)
        fn = jax.jit(make_prefill_step(model), in_shardings=(p_sh, b_sh))
        lowered = fn.lower(params_shapes, specs["batch"])
    else:  # decode
        b = shape.global_batch
        if b % dp_total == 0:
            c_spec = cache_pspecs(cfg, specs["cache"], dpx)
            if kv_layout != "hd":
                # KV layout study (§Perf decode): "seq" shards cache S over
                # the otherwise-idle model axis; "rep" replicates over model
                def relayout(path, spec, leaf):
                    dims = list(spec)
                    if leaf.ndim >= 4 and "model" in [d for d in dims if isinstance(d, str)]:
                        nd = leaf.ndim
                        if kv_layout == "seq":
                            return P(*([None] * (nd - 4) + [dpx, "model", None, None]))
                        return P(*([None] * (nd - 4) + [dpx, None, None, None]))
                    return spec
                c_spec = jax.tree_util.tree_map_with_path(
                    relayout, c_spec, specs["cache"],
                    is_leaf=lambda x: isinstance(x, P))
            t_spec = P(dpx, None)
        else:
            # SP fallback (long_500k, B=1): replicate batch, shard the cache
            # sequence dim over the DP axes
            c_spec = _sp_cache_pspecs(cfg, specs["cache"], dpx)
            t_spec = P(None, None)
        c_spec = sanitize_pspecs(c_spec, specs["cache"], axis_sizes)
        c_sh = _shard_tree(mesh, c_spec)
        fn = jax.jit(
            make_decode_step(model),
            in_shardings=(p_sh, c_sh, _ns(mesh, P()), _ns(mesh, t_spec)),
            donate_argnums=(1,),
        )
        lowered = fn.lower(params_shapes, specs["cache"],
                           jax.ShapeDtypeStruct((), jnp.int32),
                           specs["tokens"])

    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    stats = summarize_compiled(lowered, compiled)
    stats.update({
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "variant": {"remat": remat, "kv_quant": kv_quant},
    })
    return stats


def _sp_cache_pspecs(cfg, cache, dpx):
    """Sequence-parallel cache specs for batch-1 long-context decode."""

    def rule(path, leaf):
        keys = [str(e.key) for e in path if hasattr(e, "key")]
        nd = leaf.ndim
        name = keys[-1] if keys else ""
        if name in {"shift", "cm", "conv"}:
            return P(*([None] * (nd - 3) + [None, None, "model"]))
        if nd >= 4 and name in {"wkv", "ssm"}:
            return P(*([None] * (nd - 4) + [None, "model", None, None]))
        if nd >= 4 and name == "scale":
            return P(*([None] * (nd - 4) + [None, dpx, None, None]))
        if nd >= 4:  # KV (B, S, Hkv, hd): shard S over DP axes
            return P(*([None] * (nd - 4) + [None, dpx, None, "model"]))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, cache)


# ---------------------------------------------------------------------------
# QbS engine cells (paper-scale labelling + serving)
# ---------------------------------------------------------------------------

def lower_qbs_labelling_cell(graph_name: str, mesh, *, frontier_mode="bitmap") -> dict:
    from ..core.distributed import make_labelling_step, make_labelling_step_pull

    g = GRAPHS[graph_name]
    axis_names = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    vloc = math.ceil(g.n_vertices / n_shards)
    emax = math.ceil(g.n_edge_slots / n_shards)
    i32 = jnp.int32
    t0 = time.perf_counter()
    if frontier_mode == "pull":
        # plan sizes from the uniform-spread estimate: each shard's edge
        # sources distribute ~evenly over owners
        p_pad = (math.ceil(emax / n_shards) + 31) // 32 * 32
        step = make_labelling_step_pull(
            mesh, n_vertices=g.n_vertices, v_loc=vloc, e_max=emax,
            p_pad=p_pad, n_landmarks=g.n_landmarks, max_levels=64,
        )
        lowered = step.lower(
            jax.ShapeDtypeStruct((n_shards, emax), i32),
            jax.ShapeDtypeStruct((n_shards, emax), i32),
            jax.ShapeDtypeStruct((n_shards,), i32),
            jax.ShapeDtypeStruct((g.n_landmarks,), i32),
            jax.ShapeDtypeStruct((n_shards, n_shards, p_pad), i32),
            jax.ShapeDtypeStruct((n_shards, emax), i32),
            jax.ShapeDtypeStruct((n_shards, emax), i32),
        )
    else:
        step = make_labelling_step(
            mesh, n_vertices=g.n_vertices, v_loc=vloc, e_max=emax,
            n_landmarks=g.n_landmarks, frontier_mode=frontier_mode,
            max_levels=64,
        )
        lowered = step.lower(
            jax.ShapeDtypeStruct((n_shards, emax), i32),
            jax.ShapeDtypeStruct((n_shards, emax), i32),
            jax.ShapeDtypeStruct((n_shards,), i32),
            jax.ShapeDtypeStruct((g.n_landmarks,), i32),
        )
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    stats = summarize_compiled(lowered, compiled)
    stats.update({
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "n_devices": n_shards,
        "variant": {"frontier_mode": frontier_mode},
        "graph": {"V": g.n_vertices, "E_directed": g.n_edge_slots,
                  "R": g.n_landmarks},
    })
    return stats


def lower_qbs_serve_cell(graph_name: str, mesh, *, batch: int | None = None,
                         avg_degree_slots: int | None = None) -> dict:
    """Replicated-label batched serving (graphs that fit per-device); the
    vertex-sharded variant for billion-scale graphs lives in
    core.scale_serve and is lowered by lower_qbs_scale_serve_cell."""
    from ..core.frontier import abstract_engine
    from ..core.search import SearchContext

    g = GRAPHS[graph_name]
    v, e, r = g.n_vertices, g.n_edge_slots, g.n_landmarks
    if batch is None:  # one query per device, times query-parallel width
        batch = int(np.prod(list(mesh.shape.values())))
    i32, b_ = jnp.int32, jnp.bool_
    ctx = SearchContext(
        src=jax.ShapeDtypeStruct((e,), i32),
        dst=jax.ShapeDtypeStruct((e,), i32),
        gminus_e=jax.ShapeDtypeStruct((e,), b_),
        is_landmark=jax.ShapeDtypeStruct((v,), b_),
        lid=jax.ShapeDtypeStruct((v,), i32),
        label_dist=jax.ShapeDtypeStruct((v, r), i32),
        meta_w=jax.ShapeDtypeStruct((r, r), i32),
        engine=abstract_engine(v, e, masked=True),
    )
    scheme_label = jax.ShapeDtypeStruct((v, r), i32)
    meta = jax.ShapeDtypeStruct((r, r), i32)

    axis_names = tuple(mesh.axis_names)

    from functools import partial
    from ..core.search import Query, guided_search
    from ..core.sketch import compute_sketch_batch

    searcher = partial(guided_search, n_vertices=v, max_levels=32, max_chain=32)

    def step(ctx, label_dist, meta_w, meta_dist, us, vs):
        lu = label_dist[us]
        lv = label_dist[vs]
        sk = compute_sketch_batch(lu, lv, meta_w, meta_dist)
        queries = Query(u=us, v=vs, d_top=sk.d_top, du_land=sk.du_land,
                        dv_land=sk.dv_land, meta_edge=sk.meta_edge,
                        d_star_u=sk.d_star_u, d_star_v=sk.d_star_v)
        res = jax.vmap(searcher, in_axes=(None, 0))(ctx, queries)
        return res.edge_mask, res.dist

    rep = _ns(mesh, P())
    bsp = _ns(mesh, P(axis_names))
    ctx_sh = jax.tree_util.tree_map(lambda _: rep, ctx)
    fn = jax.jit(step, in_shardings=(ctx_sh, rep, rep, rep, bsp, bsp),
                 out_shardings=(bsp, bsp))
    t0 = time.perf_counter()
    lowered = fn.lower(ctx, scheme_label, meta, meta,
                       jax.ShapeDtypeStruct((batch,), i32),
                       jax.ShapeDtypeStruct((batch,), i32))
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    stats = summarize_compiled(lowered, compiled)
    stats.update({
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "variant": {"mode": "replicated-labels", "batch": batch},
        "graph": {"V": v, "E_directed": e, "R": r},
    })
    return stats



def lower_qbs_scale_serve_cell(graph_name: str, mesh, *, batch: int = 32) -> dict:
    """Vertex-sharded serving (labels + state sharded): the layout that
    actually scales to ClueWeb09 (labels alone are 68GB — unreplicable)."""
    from ..core.scale_serve import make_scale_serve_step

    g = GRAPHS[graph_name]
    axis_names = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    vloc = math.ceil(g.n_vertices / n_shards)
    emax = math.ceil(g.n_edge_slots / n_shards)
    r = g.n_landmarks
    i32, i16 = jnp.int32, jnp.int16
    t0 = time.perf_counter()
    step = make_scale_serve_step(
        mesh, n_vertices=g.n_vertices, v_loc=vloc, e_max=emax,
        n_landmarks=r, batch=batch, max_levels=16, max_chain=4)
    lowered = step.lower(
        jax.ShapeDtypeStruct((n_shards, emax), i32),
        jax.ShapeDtypeStruct((n_shards, emax), i32),
        jax.ShapeDtypeStruct((n_shards,), i32),
        jax.ShapeDtypeStruct((n_shards, vloc, r), i16),
        jax.ShapeDtypeStruct((n_shards, emax, r), i16),
        jax.ShapeDtypeStruct((r,), i32),
        jax.ShapeDtypeStruct((r, r), i32),
        jax.ShapeDtypeStruct((r, r), i32),
        jax.ShapeDtypeStruct((batch,), i32),
        jax.ShapeDtypeStruct((batch,), i32),
    )
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    stats = summarize_compiled(lowered, compiled)
    stats.update({
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "n_devices": n_shards,
        "variant": {"mode": "vertex-sharded", "batch": batch},
        "graph": {"V": g.n_vertices, "E_directed": g.n_edge_slots, "R": r},
    })
    return stats


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

QBS_LABELLING_GRAPHS = ["youtube", "livejournal", "orkut", "twitter",
                        "friendster", "uk2007", "clueweb09"]
QBS_SERVE_GRAPHS = ["youtube", "livejournal", "orkut"]


def run_cell(kind: str, key: str, shape: str, mesh_name: str, *,
             force=False, **kw) -> tuple[str, dict]:
    variant = kw.pop("variant_tag", "")
    name = f"{kind}__{key}__{shape}__{mesh_name}" + (f"__{variant}" if variant else "")
    out = RESULTS / f"{name}.json"
    if out.exists() and not force:
        prior = json.loads(out.read_text())
        if "error" not in prior:  # re-attempt recorded failures
            return name, prior
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    try:
        with mesh:
            if kind == "lm":
                stats = lower_lm_cell(key, shape, mesh, **kw)
            elif kind == "qbs-label":
                stats = lower_qbs_labelling_cell(key, mesh, **kw)
            elif kind == "qbs-serve":
                stats = lower_qbs_serve_cell(key, mesh, **kw)
            elif kind == "qbs-scale-serve":
                stats = lower_qbs_scale_serve_cell(key, mesh, **kw)
            else:
                raise ValueError(kind)
    except Exception as e:  # noqa: BLE001 — record failures, they are bugs
        stats = {"error": repr(e), "traceback": traceback.format_exc()[-4000:]}
    RESULTS.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(stats, indent=1))
    status = "SKIP" if "skipped" in stats else ("FAIL" if "error" in stats else "ok")
    print(f"[dryrun] {name}: {status} "
          f"(compile {stats.get('compile_s', '-')}s)", flush=True)
    return name, stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="all", choices=["all", "lm", "qbs"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--moe-sort", action="store_true")
    ap.add_argument("--moe-group", action="store_true")
    ap.add_argument("--flash", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--qbs-frontier", default="", choices=["", "bool", "bitmap", "pull"])
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--kv-layout", default="hd", choices=["hd", "seq", "rep"])
    ap.add_argument("--seq-shard", default="", choices=["", "dp", "sp"])
    args = ap.parse_args()

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    variant_tag = ""
    kw: dict = {}
    if args.remat:
        kw["remat"] = True
        variant_tag += "remat"
    if args.kv_quant:
        kw["kv_quant"] = True
        variant_tag += "kvq"
    if args.moe_sort:
        kw["moe_sort"] = True
        variant_tag += "moesort"
    if args.moe_group:
        kw["moe_group"] = True
        variant_tag += "moegroup"
    if args.flash:
        kw["flash"] = True
        variant_tag += "flash"
    if args.microbatches > 1:
        kw["microbatches"] = args.microbatches
        variant_tag += f"mb{args.microbatches}"
    if args.seq_shard:
        kw["seq_shard"] = args.seq_shard
        variant_tag += f"act{args.seq_shard}"
    if args.zero1:
        kw["zero1"] = True
        variant_tag += "zero1"
    if args.kv_layout != "hd":
        kw["kv_layout"] = args.kv_layout
        variant_tag += f"kv{args.kv_layout}"

    failures = 0
    if args.cells in ("all", "lm"):
        archs = [args.arch] if args.arch else sorted(ARCHS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for mesh_name in meshes:
            for arch in archs:
                for shape in shapes:
                    _, stats = run_cell("lm", arch, shape, mesh_name,
                                        force=args.force,
                                        variant_tag=variant_tag, **kw)
                    failures += 1 if "error" in stats else 0
    if args.cells in ("all", "qbs"):
        qkw = {}
        qtag = ""
        if args.qbs_frontier:
            qkw["frontier_mode"] = args.qbs_frontier
            qtag = args.qbs_frontier
        for mesh_name in meshes:
            for gname in QBS_LABELLING_GRAPHS:
                _, stats = run_cell("qbs-label", gname, "label", mesh_name,
                                    force=args.force, variant_tag=qtag, **qkw)
                failures += 1 if "error" in stats else 0
            for gname in QBS_SERVE_GRAPHS:
                _, stats = run_cell("qbs-serve", gname, "serve", mesh_name,
                                    force=args.force)
                failures += 1 if "error" in stats else 0
            for gname in ("twitter", "clueweb09"):
                _, stats = run_cell("qbs-scale-serve", gname, "serve", mesh_name,
                                    force=args.force)
                failures += 1 if "error" in stats else 0
    print(f"[dryrun] done; failures={failures}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
