"""Checkpoint/restart with atomic rotation, async writes and elastic
restore.

Layout:  <root>/step_<N>/   leaf files  arr_<k>.npy  +  manifest.json
         <root>/LATEST      (atomic pointer, written last)

Crash safety: a checkpoint directory is staged under a tmp name and
``os.rename``d into place (POSIX-atomic), then LATEST is rewritten; a
killed writer can never leave a half checkpoint that restore would pick
up — the preemption-simulation test exercises exactly this.

Elastic restore: leaves are stored as full logical arrays with their
treedef; the loader re-shards onto whatever mesh the restarted job has
(checkpoints are mesh-shape-agnostic).  At 1000+-node scale the same
manifest format fans out to per-shard files — single-file-per-leaf keeps
this repo's footprint honest while preserving the protocol.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(root: str | Path, step: int, tree: Any, *, extra: dict | None = None,
         keep: int = 3) -> Path:
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:010d}"
    tmp = root / f".tmp_step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtypes.append(str(arr.dtype))
        if str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)  # exact; restored via astype(bf16)
        np.save(tmp / f"arr_{i:05d}.npy", arr)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": dtypes,
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    (root / ".LATEST_tmp").write_text(final.name)
    os.rename(root / ".LATEST_tmp", root / "LATEST")

    # rotation
    ckpts = sorted(p for p in root.iterdir() if p.name.startswith("step_"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old)
    return final


def save_async(root: str | Path, step: int, tree: Any, **kw) -> threading.Thread:
    """Device->host transfer happens synchronously (cheap), disk write in a
    background thread so the train loop isn't blocked."""
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
    t = threading.Thread(target=save, args=(root, step, host_tree), kwargs=kw)
    t.start()
    return t


def latest_step(root: str | Path) -> int | None:
    root = Path(root)
    ptr = root / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (root / name / "manifest.json").exists():
        return None
    return int(name.split("_")[1])


def restore(root: str | Path, example_tree: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[int, Any, dict]:
    """Restore into the structure of ``example_tree``; optionally re-shard
    with a matching ``shardings`` pytree (elastic restore onto a new mesh)."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:010d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(example_tree)
    assert manifest["n_leaves"] == len(leaves), "tree structure changed"
    out = []
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves)
    )
    for i, (leaf, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(d / f"arr_{i:05d}.npy")
        want_dtype = manifest["dtypes"][i]
        a = jax.numpy.asarray(arr).astype(want_dtype)
        if shd is not None:
            a = jax.device_put(a, shd)
        out.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return step, tree, manifest["extra"]
