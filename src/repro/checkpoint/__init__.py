from .checkpoint import latest_step, restore, save, save_async

__all__ = ["latest_step", "restore", "save", "save_async"]
