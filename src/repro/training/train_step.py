"""Training step factory: grad accumulation, mixed precision, activation
remat, optional gradient compression hook.

Distribution model: params carry TP PartitionSpecs, the batch is DP-sharded;
under ``jit`` GSPMD inserts the DP gradient all-reduce.  Microbatched
accumulation runs as a ``lax.scan`` whose per-microbatch backward overlaps
with the deferred reduction (the reduce happens once on the accumulated
grads — 1/k the collective bytes of naive per-microbatch reduction).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models.registry import Model
from .optimizer import Optimizer


def _split_microbatches(batch: dict, k: int) -> dict:
    def sp(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape(k, b // k, *x.shape[1:])
    return jax.tree_util.tree_map(sp, batch)


def compress_bf16(grads):
    """Gradient compression: bf16 round-trip (2x collective bytes saving
    when the DP reduce is done in the compressed domain)."""
    return jax.tree_util.tree_map(
        lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
    remat: bool = False,
    compress: Callable | None = None,
) -> Callable:
    loss_fn = model.loss
    if remat:
        loss_fn = jax.checkpoint(loss_fn, static_argnums=())

    def train_step(params, opt_state, batch):
        def grad_of(p, mb):
            (loss, metrics), grads = jax.value_and_grad(
                lambda q: loss_fn(q, batch=mb), has_aux=True)(p)
            return loss, metrics, grads

        if microbatches == 1:
            loss, metrics, grads = grad_of(params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)

            def body(acc, mb):
                loss_a, grads_a, n = acc
                loss, metrics, grads = grad_of(params, mb)
                grads_a = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grads_a, grads)
                return (loss_a + loss, grads_a, n + 1), metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads, _), metrics = jax.lax.scan(
                body, (jnp.float32(0), zeros, jnp.int32(0)), mbs)
            loss = loss_sum / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)

        if compress is not None:
            grads = compress(grads)
        params, opt_state, opt_metrics = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics) if isinstance(metrics, dict) else {"nll": loss}
        metrics["loss"] = loss
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step
