from .optimizer import Optimizer, adamw, clip_by_global_norm, global_norm, warmup_cosine
from .train_step import compress_bf16, make_train_step

__all__ = [
    "Optimizer", "adamw", "clip_by_global_norm", "global_norm",
    "warmup_cosine", "compress_bf16", "make_train_step",
]
