"""Self-contained optimizer stack (no optax dependency): AdamW with
decoupled weight decay, global-norm clipping, warmup+cosine schedule.
Optimizer state dtype is f32 regardless of param dtype (mixed precision:
bf16 params, f32 master moments)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any, dict]]


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


def adamw(
    lr: Callable | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        return {"mu": zeros, "nu": jax.tree_util.tree_map(jnp.copy, zeros),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                    state["mu"], grads)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                    state["nu"], grads)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "step": step}, {
            "grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init=init, update=update)
