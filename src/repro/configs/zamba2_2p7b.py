"""zamba2-2.7b [hybrid] — Mamba2 blocks + shared attention block
[arXiv:2411.15242; hf].  54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64; shared full block every 6 Mamba2 layers
(9 applications of one weight set), conditioned on concat(h, x_emb)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    hybrid_period=6,
)
