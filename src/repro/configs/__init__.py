"""Assigned architecture pool: exact public configs, selectable via
``--arch <id>`` in the launchers.  Sources/verification tiers per the brief
are recorded in each module's docstring."""
from __future__ import annotations

from ..models.config import ModelConfig
from . import (
    dbrx_132b,
    deepseek_7b,
    hubert_xlarge,
    internvl2_76b,
    phi3_medium_14b,
    phi3p5_moe_42b,
    qwen1p5_32b,
    qwen1p5_4b,
    rwkv6_1p6b,
    zamba2_2p7b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        zamba2_2p7b, qwen1p5_4b, deepseek_7b, qwen1p5_32b, phi3_medium_14b,
        phi3p5_moe_42b, dbrx_132b, rwkv6_1p6b, hubert_xlarge, internvl2_76b,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]
