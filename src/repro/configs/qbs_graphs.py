"""Paper-dataset graph scales (Table 1) as dry-run stand-ins for the QbS
engine.  V/E are the undirected counts; the engine stores 2|E| directed
slots.  These drive ShapeDtypeStruct-only lowering of the distributed
labelling and serving steps at true paper scale."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class GraphScale:
    name: str
    n_vertices: int
    n_edges_undirected: int   # |E^un| from Table 1
    n_landmarks: int = 20

    @property
    def n_edge_slots(self) -> int:
        return 2 * self.n_edges_undirected


GRAPHS = {
    g.name: g
    for g in [
        GraphScale("douban", 200_000, 300_000),
        GraphScale("youtube", 1_100_000, 3_000_000),
        GraphScale("skitter", 1_700_000, 11_100_000),
        GraphScale("livejournal", 4_800_000, 43_100_000),
        GraphScale("orkut", 3_100_000, 117_000_000),
        GraphScale("twitter", 41_700_000, 1_200_000_000),
        GraphScale("friendster", 65_600_000, 1_800_000_000),
        GraphScale("uk2007", 106_000_000, 3_300_000_000),
        GraphScale("clueweb09", 1_700_000_000, 7_800_000_000),
    ]
}
