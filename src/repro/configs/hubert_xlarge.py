"""hubert-xlarge [audio] — encoder-only, w2v2 arch [arXiv:2106.07447;
unverified].  48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (cluster
targets).  Modality frontend is a stub: ``input_specs()`` provides
precomputed frame embeddings (frontend_dim=512)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    encoder_only=True,
    frontend="audio_frames",
    frontend_dim=512,
)
