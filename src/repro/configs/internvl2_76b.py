"""internvl2-76b [vlm] — InternViT + Llama3-70B-style LM backbone
[arXiv:2404.16821; unverified].  80L d_model=8192 64H (kv=8) d_ff=28672
vocab=128256.  Vision frontend is a stub: ``input_specs()`` provides
precomputed patch embeddings (256 patches, frontend_dim=1024)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision_patches",
    frontend_dim=1024,
)
