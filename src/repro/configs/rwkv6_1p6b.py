"""rwkv6-1.6b "Finch" [ssm] — attention-free, data-dependent decay
[arXiv:2404.05892; unverified].  24L d_model=2048 d_ff=7168 vocab=65536."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,        # unused (attention-free); kept for config uniformity
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
)
