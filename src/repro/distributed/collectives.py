"""Explicit-collective helpers for shard_map regions.

* ``psum_compressed`` — DP gradient all-reduce in a compressed domain
  (bf16: 2x bytes; int8 + per-tensor scale: 4x bytes) with error feedback
  so compression error accumulates into the next step instead of the model.
* ``reduce_scatter_gather`` — ZeRO-1-style decomposition of an all-reduce:
  reduce-scatter -> (owner-shard update) -> all-gather.  Same total bytes
  as all-reduce but the optimizer state/update runs 1/N-sharded.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def psum_bf16(tree: Any, axis_names) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_names).astype(jnp.float32),
        tree,
    )


def _quant_int8(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    return jnp.round(g / scale).astype(jnp.int8), scale


def psum_int8_ef(tree: Any, errors: Any, axis_names) -> tuple[Any, Any]:
    """int8 all-reduce with error feedback. Returns (reduced, new_errors)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quant_int8(g32)
        new_e = g32 - q.astype(jnp.float32) * scale
        # reduce int32 accumulators (int8 would overflow at N>127 summands)
        red = jax.lax.psum(q.astype(jnp.int32), axis_names).astype(jnp.float32)
        red_scale = jax.lax.psum(scale, axis_names) / jax.lax.psum(
            jnp.ones(()), axis_names)
        return red * red_scale, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(tree)
    flat_e = jax.tree_util.tree_leaves(errors)
    out, errs = zip(*(one(g, e) for g, e in zip(flat_g, flat_e)))
    return (jax.tree_util.tree_unflatten(treedef, list(out)),
            jax.tree_util.tree_unflatten(treedef, list(errs)))


def zero1_update(grads_flat: jax.Array, axis_name: str):
    """reduce_scatter over the flattened grad vector: each device owns a
    1/N slice for its optimizer shard; caller all-gathers updated params."""
    red = jax.lax.psum_scatter(grads_flat, axis_name, tiled=True)
    return red


def all_gather_params(shard: jax.Array, axis_name: str) -> jax.Array:
    return jax.lax.all_gather(shard, axis_name, tiled=True)
