"""Mesh-axis conventions shared by launchers and tests.

single-pod:  (data=16, model=16)                 256 chips (v5e pod)
multi-pod:   (pod=2, data=16, model=16)          512 chips

DP = pod x data; TP/EP/state-sharding = model; SP variants shard sequence
over data for long-context serving.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes_of(mesh: Mesh):
    names = tuple(mesh.axis_names)
    return tuple(n for n in names if n != "model") or (names[0],)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def shard_tree(mesh: Mesh, tree, spec_tree):
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, spec_tree
    )


def local_mesh(n: int = 1, names=("data", "model")) -> Mesh:
    devs = np.array(jax.devices()[:n]).reshape((n,) + (1,) * (len(names) - 1))
    return Mesh(devs, names)
