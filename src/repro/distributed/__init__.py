from .collectives import all_gather_params, psum_bf16, psum_int8_ef, zero1_update
from .sharding import dp_axes_of, local_mesh, named, shard_tree

__all__ = [
    "all_gather_params", "psum_bf16", "psum_int8_ef", "zero1_update",
    "dp_axes_of", "local_mesh", "named", "shard_tree",
]
