"""End-to-end training driver example: a ~100M-param dense LM for a few
hundred steps on the deterministic synthetic stream, with checkpointing and
resume.  (CPU-sized by default; pass --full-ish for the bigger variant.)

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full-ish]
"""
import argparse
import tempfile
import time
from dataclasses import replace

import numpy as np
import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.models import build_model
from repro.training import adamw, make_train_step, warmup_cosine

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full-ish", action="store_true",
                help="~100M params (slow on CPU; default is a tiny config)")
args = ap.parse_args()

cfg = get_config("deepseek-7b")
if args.full_ish:
    cfg = replace(cfg, n_layers=10, d_model=768, n_heads=12, n_kv_heads=12,
                  d_ff=2048, vocab_size=32_000)   # ~0.1B params
    seq, batch = 256, 8
else:
    cfg = cfg.reduced()
    seq, batch = 64, 8

model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
print(f"model: {cfg.name}-derived, {n / 1e6:.1f}M params")

opt = adamw(warmup_cosine(3e-3, 20, args.steps))
opt_state = opt.init(params)
data = SyntheticLM(SyntheticLMConfig(cfg.vocab_size, seq, batch, seed=0))
step_fn = jax.jit(make_train_step(model, opt))

ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
t0 = time.time()
for step in range(args.steps):
    batch_np = data.batch_at(step)
    p_batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    params, opt_state, metrics = step_fn(params, opt_state, p_batch)
    if (step + 1) % 20 == 0:
        print(f"step {step + 1:4d} loss={float(metrics['loss']):.4f} "
              f"({(time.time() - t0) / (step + 1) * 1e3:.0f} ms/step)")
    if (step + 1) % 100 == 0:
        ckpt.save(ckpt_dir, step + 1, {"params": params, "opt": opt_state},
                  extra={"data_step": step + 1})

print(f"final loss {float(metrics['loss']):.4f}; checkpoints in {ckpt_dir}")
s, tree, extra = ckpt.restore(ckpt_dir, {"params": params, "opt": opt_state})
print(f"restore check: step {s} OK")
