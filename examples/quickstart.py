"""Quickstart: build a QbS index and answer shortest-path-graph queries.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import QbSIndex, from_edges
from repro.core.baselines import bfs_spg

# The paper's Figure 3 graph (1-indexed in the paper).
edges = np.array([(1, 2), (1, 3), (2, 4), (2, 5), (2, 6), (3, 4), (5, 6), (5, 7)]) - 1
graph = from_edges(edges, 7)

# Offline: labelling scheme (Algorithm 2) with 2 landmarks.
index = QbSIndex.build(graph, n_landmarks=2)
print("landmarks:", np.asarray(index.scheme.landmarks).tolist())
print("meta-graph d_M:\n", np.asarray(index.scheme.meta_dist))

# Online: SPG(3, 7) -> the sketch bounds the guided search (Algorithms 3+4).
res = index.query(2, 6)  # paper's SPG(3,7), 0-indexed
print(f"\nSPG(3,7): distance={res.dist}")
print("edges:", sorted((a + 1, b + 1) for a, b in res.edge_pairs(graph)))

oracle = bfs_spg(graph, 2, 6)
assert res.edge_pairs(graph) == oracle.edge_pairs(graph)
print("matches the two-BFS oracle: OK")

# Batched serving: many queries per call.
us, vs = np.array([0, 1, 3]), np.array([6, 6, 5])
for r in index.query_batch(us, vs):
    print(f"SPG({r.u + 1},{r.v + 1}): d={r.dist}, |E|={r.edge_ids.size // 2}")
