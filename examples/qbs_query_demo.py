"""End-to-end QbS serving on a 20k-vertex hub-heavy graph: build the
labelling, inspect sketches, answer a query batch through the
planner/service stack, and cross-check a sample against the exact oracle.

  PYTHONPATH=src python examples/qbs_query_demo.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (
    INF,
    QbSIndex,
    barabasi_albert_graph,
    compute_sketch_batch,
    labelling_size_bytes,
)
from repro.core.baselines import bfs_spg

graph = barabasi_albert_graph(20_000, 3, seed=0)
print(f"graph: V={graph.n_vertices} E={graph.n_edges // 2}")

t0 = time.time()
index = QbSIndex.build(graph, n_landmarks=20)
print(f"labelling built in {time.time() - t0:.2f}s; "
      f"size={labelling_size_bytes(index.scheme)['label_bytes'] / 1e3:.0f}KB "
      f"(graph: {graph.n_edges * 4 / 1e3:.0f}KB)")

# peek at one sketch
u, v = 1234, 8876
sk = compute_sketch_batch(
    index.scheme.label_dist[jnp.asarray([u])],
    index.scheme.label_dist[jnp.asarray([v])],
    index.scheme.meta_w, index.scheme.meta_dist)
print(f"sketch for ({u},{v}): d_top={int(sk.d_top[0])} "
      f"d*_u={int(sk.d_star_u[0])} d*_v={int(sk.d_star_v[0])} "
      f"sketch_edges_u={int((np.asarray(sk.du_land[0]) < INF).sum())}")

rng = np.random.default_rng(1)
us = rng.integers(0, graph.n_vertices, size=64)
vs = rng.integers(0, graph.n_vertices, size=64)
t0 = time.time()
results = index.query_batch(us, vs)   # default service: async_depth=2
dt = time.time() - t0
print(f"64 queries in {dt:.2f}s ({dt / 64 * 1e3:.1f} ms/query)")

# explicit service: planner lane stats + canonical-pair result cache
service = index.make_service(async_depth=2, cache_size=1024)
service.query_batch(us, vs)
lanes = dict(zip(("trivial", "landmark_pair", "one_sided", "general"),
                 service.lane_served))
t0 = time.time()
service.query_batch(us, vs)           # repeat stream: all cache hits
dt_hot = time.time() - t0
print(f"planner lanes {lanes}; hot re-query {dt_hot / 64 * 1e6:.0f} us/query "
      f"(cache hits={service.cache.hits})")

for k in (0, 7, 13):
    r = results[k]
    o = bfs_spg(graph, r.u, r.v)
    status = "OK" if o.edge_pairs(graph) == r.edge_pairs(graph) else "MISMATCH"
    print(f"  SPG({r.u},{r.v}): d={r.dist} |edges|={len(r.edge_pairs(graph))} "
          f"oracle:{status}")
