"""Batched LM serving example: prefill a prompt batch, then greedy-decode
continuation tokens with bf16 and int8-quantized KV caches.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import numpy as np
import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serving import greedy_generate

cfg = get_config("phi3-medium-14b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
prompts = rng.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32)

t0 = time.time()
out_bf16 = greedy_generate(model, params, prompts, n_new=16)
t1 = time.time()
out_int8 = greedy_generate(model, params, prompts, n_new=16, kv_quant=True)
t2 = time.time()

print(f"bf16 KV: {out_bf16.shape} in {t1 - t0:.2f}s")
print(f"int8 KV: {out_int8.shape} in {t2 - t1:.2f}s")
agree = float((np.asarray(out_bf16) == np.asarray(out_int8)).mean())
print(f"greedy-token agreement bf16 vs int8 KV: {agree * 100:.0f}% "
      "(random-weight model; production models agree far more)")
print("sample continuation (bf16):", np.asarray(out_bf16[0]).tolist())
