"""Cross-backend equivalence for the pluggable frontier engine.

The ``segment`` backend is the bit-identical reference (the seed's
``segment_max`` relay).  ``csr`` (pull over the src-sorted layout) and
``hybrid`` (dense hub block + compacted tail) must produce *identical*
booleans on every generator regime — OR-reductions are order-invariant, so
there is no tolerance anywhere in this file.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    QbSIndex,
    barabasi_albert_graph,
    build_labelling,
    gnp_random_graph,
    grid_graph,
    make_relay,
    random_regular_graph,
    ring_of_cliques,
    select_landmarks,
)
from repro.core.baselines import bfs_spg, bibfs_spg
from repro.core.frontier import segment_or

BACKENDS = ("segment", "csr", "hybrid")

GRAPHS = {
    "gnp": lambda: gnp_random_graph(60, 3.0, seed=7),
    "barabasi_albert": lambda: barabasi_albert_graph(70, 2, seed=3),
    "random_regular": lambda: random_regular_graph(48, 4, seed=5),
    "ring_of_cliques": lambda: ring_of_cliques(6, 5),
    "grid": lambda: grid_graph(6, 6),
}


def _engines(g, **kw):
    return {
        "segment": make_relay(g, backend="segment", **kw),
        "csr": make_relay(g, backend="csr", block_size=64, **kw),
        "hybrid": make_relay(g, backend="hybrid", n_hubs=16, **kw),
    }


@pytest.mark.parametrize("gen", sorted(GRAPHS))
def test_relay_identical_across_backends(gen):
    g = GRAPHS[gen]()
    rng = np.random.default_rng(11)
    vals = jnp.asarray(rng.random((5, g.n_vertices)) < 0.25)
    engines = _engines(g)
    want = np.asarray(engines["segment"].relay(vals))
    for name in ("csr", "hybrid"):
        got = np.asarray(engines[name].relay(vals))
        assert (got == want).all(), name
    # 1-D convenience form round-trips
    got1 = np.asarray(engines["hybrid"].relay(vals[0]))
    assert (got1 == want[0]).all()


@pytest.mark.parametrize("gen", sorted(GRAPHS))
def test_masked_relay_identical_across_backends(gen):
    """Vertex-factored (hence symmetric) edge masks — the G- shape."""
    g = GRAPHS[gen]()
    rng = np.random.default_rng(13)
    vkeep = rng.random(g.n_vertices) < 0.7
    emask = vkeep[np.asarray(g.src)] & vkeep[np.asarray(g.dst)]
    vals = jnp.asarray(rng.random((3, g.n_vertices)) < 0.3)
    engines = _engines(g, edge_mask=emask)
    want = np.asarray(engines["segment"].relay(vals))
    for name in ("csr", "hybrid"):
        got = np.asarray(engines[name].relay(vals))
        assert (got == want).all(), name


def test_scatter_matches_segment_or():
    g = GRAPHS["gnp"]()
    rng = np.random.default_rng(3)
    msgs = jnp.asarray(rng.random((4, g.n_edges)) < 0.2)
    want = np.asarray(segment_or(msgs, g.dst, g.n_vertices))
    for name, eng in _engines(g).items():
        got = np.asarray(eng.scatter(msgs))
        assert (got == want).all(), name


def test_hybrid_pallas_kernel_path():
    """The hybrid backend's dense block through the real Pallas kernel
    (interpret mode) must agree with the jnp matmul path."""
    g = GRAPHS["barabasi_albert"]()
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.random((4, g.n_vertices)) < 0.3)
    ref = make_relay(g, backend="hybrid", n_hubs=16, use_pallas=False)
    pal = make_relay(g, backend="hybrid", n_hubs=16, use_pallas=True,
                     interpret=True)
    assert (np.asarray(pal.relay(vals)) == np.asarray(ref.relay(vals))).all()


def test_hub_split_structure():
    g = GRAPHS["barabasi_albert"]()
    split = g.hub_split(8)
    deg = np.asarray(g.degrees())
    assert split.hub_ids.shape == (8,)
    assert deg[split.hub_ids].min() >= np.sort(deg)[-8:].min() - 0  # top-degree
    assert split.adj_hh.shape == (8, 8)
    assert (split.adj_hh == split.adj_hh.T).all()  # symmetrized edge list
    assert not np.diag(split.adj_hh).any()         # no self loops
    # hub_edge marks exactly the edges inside the hub set
    src, dst = np.asarray(g.src), np.asarray(g.dst)
    want = split.is_hub[src] & split.is_hub[dst] & (src != dst)
    assert (split.hub_edge == want).all()


@pytest.mark.parametrize("gen", sorted(GRAPHS))
def test_labelling_scheme_bit_identical(gen):
    g = GRAPHS[gen]()
    lms = select_landmarks(g, 5)
    ref = build_labelling(g, lms, backend="segment")
    for name in ("csr", "hybrid"):
        kw = {"block_size": 64} if name == "csr" else {"n_hubs": 16}
        got = build_labelling(g, lms, backend=name, **kw)
        assert (np.asarray(got.label_dist) == np.asarray(ref.label_dist)).all(), name
        assert (np.asarray(got.meta_w) == np.asarray(ref.meta_w)).all(), name
        assert (np.asarray(got.meta_dist) == np.asarray(ref.meta_dist)).all(), name


@pytest.mark.parametrize("gen", sorted(GRAPHS))
def test_spg_results_identical_across_backends(gen):
    """End-to-end: every backend must return the seed path's exact SPG
    (dist + edge-id set) and match the two-BFS oracle."""
    g = GRAPHS[gen]()
    idxs = {
        "segment": QbSIndex.build(g, n_landmarks=5),
        "csr": QbSIndex.build(g, n_landmarks=5, backend="csr",
                              engine_opts={"block_size": 64}),
        "hybrid": QbSIndex.build(g, n_landmarks=5, backend="hybrid",
                                 engine_opts={"n_hubs": 16}),
    }
    rng = np.random.default_rng(17)
    lms = np.asarray(idxs["segment"].scheme.landmarks)
    pairs = [(int(rng.integers(0, g.n_vertices)),
              int(rng.integers(0, g.n_vertices))) for _ in range(6)]
    pairs += [(int(lms[0]), int(rng.integers(0, g.n_vertices))),
              (int(lms[0]), int(lms[1]))]  # landmark-endpoint path too
    for u, v in pairs:
        o = bfs_spg(g, u, v)
        ref = idxs["segment"].query(u, v)
        assert ref.dist == o.dist, (u, v)
        assert ref.edge_pairs(g) == o.edge_pairs(g), (u, v)
        for name in ("csr", "hybrid"):
            r = idxs[name].query(u, v)
            assert r.dist == ref.dist, (name, u, v)
            assert (r.edge_ids == ref.edge_ids).all(), (name, u, v)


def test_bibfs_baseline_across_backends():
    g = GRAPHS["random_regular"]()
    ref = bibfs_spg(g, 1, 17)
    for name in ("csr", "hybrid"):
        r = bibfs_spg(g, 1, 17, backend=name)
        assert r.dist == ref.dist
        assert (r.edge_ids == ref.edge_ids).all(), name


def test_unknown_backend_rejected():
    g = GRAPHS["grid"]()
    with pytest.raises(ValueError):
        make_relay(g, backend="nope")
