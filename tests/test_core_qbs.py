"""End-to-end correctness of the QbS engine against exact oracles.

The paper's central claim is exactness: the returned subgraph contains
*exactly* all shortest paths (Theorem 5.1).  We check edge-set equality with
a textbook two-BFS oracle, and independently with networkx on tiny graphs.
"""
import numpy as np
import pytest

from repro.core import (
    INF,
    QbSIndex,
    barabasi_albert_graph,
    from_edges,
    gnp_random_graph,
    grid_graph,
    ring_of_cliques,
    to_networkx,
)
from repro.core.baselines import bfs_spg, bibfs_spg


def assert_query_exact(g, idx, u, v):
    o = bfs_spg(g, u, v)
    r = idx.query(u, v)
    assert r.dist == o.dist, (u, v, r.dist, o.dist)
    assert r.edge_pairs(g) == o.edge_pairs(g), (
        u, v,
        sorted(r.edge_pairs(g) - o.edge_pairs(g)),
        sorted(o.edge_pairs(g) - r.edge_pairs(g)),
    )


def test_paper_figure3_example():
    """Fig. 3: SPG(3,7) must be the green subgraph (1-indexed)."""
    edges = np.array([(1, 2), (1, 3), (2, 4), (2, 5), (2, 6), (3, 4), (5, 6), (5, 7)]) - 1
    g = from_edges(edges, 7)
    idx = QbSIndex.build(g, n_landmarks=2)
    r = idx.query(2, 6)
    assert r.dist == 4
    assert r.edge_pairs(g) == {(0, 2), (0, 1), (2, 3), (1, 3), (1, 4), (4, 6)}


def test_networkx_cross_validation():
    g = gnp_random_graph(30, 3.0, seed=11)
    nxg = to_networkx(g)
    import networkx as nx

    idx = QbSIndex.build(g, n_landmarks=4)
    rng = np.random.default_rng(0)
    for _ in range(10):
        u, v = int(rng.integers(0, 30)), int(rng.integers(0, 30))
        r = idx.query(u, v)
        if u == v:
            assert r.dist == 0
            continue
        if not nx.has_path(nxg, u, v):
            assert r.dist >= INF
            assert r.edge_ids.size == 0
            continue
        paths = list(nx.all_shortest_paths(nxg, u, v))
        want = {
            (min(a, b), max(a, b))
            for p in paths
            for a, b in zip(p, p[1:])
        }
        assert r.dist == len(paths[0]) - 1
        assert r.edge_pairs(g) == want


@pytest.mark.parametrize("seed,nl", [(0, 1), (1, 3), (2, 5), (3, 8)])
def test_random_graphs_match_oracle(seed, nl):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 50))
    g = gnp_random_graph(n, 3.5, seed=seed + 50)
    idx = QbSIndex.build(g, n_landmarks=min(nl, n // 3))
    for _ in range(8):
        assert_query_exact(g, idx, int(rng.integers(0, n)), int(rng.integers(0, n)))


def test_hub_heavy_graph():
    g = barabasi_albert_graph(80, 2, seed=3)
    idx = QbSIndex.build(g, n_landmarks=6)
    rng = np.random.default_rng(4)
    for _ in range(8):
        assert_query_exact(g, idx, int(rng.integers(0, 80)), int(rng.integers(0, 80)))


def test_grid_many_tied_paths():
    """Grids maximize shortest-path multiplicity (binomial many paths)."""
    g = grid_graph(6, 6)
    idx = QbSIndex.build(g, n_landmarks=4)
    assert_query_exact(g, idx, 0, 35)  # corner to corner
    assert_query_exact(g, idx, 0, 5)
    assert_query_exact(g, idx, 7, 28)


def test_flat_degree_graph():
    g = ring_of_cliques(6, 5)
    idx = QbSIndex.build(g, n_landmarks=5)
    rng = np.random.default_rng(5)
    for _ in range(6):
        assert_query_exact(g, idx, int(rng.integers(0, 30)), int(rng.integers(0, 30)))


def test_landmark_endpoint_queries():
    g = gnp_random_graph(40, 3.0, seed=9)
    idx = QbSIndex.build(g, n_landmarks=5)
    lms = np.asarray(idx.scheme.landmarks)
    assert_query_exact(g, idx, int(lms[0]), 7)
    assert_query_exact(g, idx, 9, int(lms[1]))
    assert_query_exact(g, idx, int(lms[0]), int(lms[2]))


def test_trivial_and_adjacent_queries():
    g = gnp_random_graph(25, 3.0, seed=13)
    idx = QbSIndex.build(g, n_landmarks=3)
    r = idx.query(4, 4)
    assert r.dist == 0 and r.edge_ids.size == 0
    # adjacent pair: SPG must be exactly that one edge
    s = np.asarray(g.src)
    d = np.asarray(g.dst)
    real = s != d
    u, v = int(s[real][0]), int(d[real][0])
    if not bool(np.asarray(idx.scheme.is_landmark)[u] | np.asarray(idx.scheme.is_landmark)[v]):
        r = idx.query(u, v)
        assert r.dist == 1
        assert r.edge_pairs(g) == {(min(u, v), max(u, v))}


def test_disconnected_graph():
    edges = np.array([(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)])
    g = from_edges(edges, 7)  # vertex 6 isolated
    idx = QbSIndex.build(g, n_landmarks=2)
    r = idx.query(0, 4)
    assert r.dist >= INF and r.edge_ids.size == 0
    r = idx.query(6, 0)
    assert r.dist >= INF and r.edge_ids.size == 0
    assert_query_exact(g, idx, 0, 2)


def test_batched_equals_single():
    g = gnp_random_graph(35, 3.0, seed=21)
    idx = QbSIndex.build(g, n_landmarks=4)
    rng = np.random.default_rng(2)
    us = rng.integers(0, 35, size=11)
    vs = rng.integers(0, 35, size=11)
    batch = idx.query_batch(us, vs)
    for u, v, rb in zip(us, vs, batch):
        r1 = idx.query(int(u), int(v))
        assert r1.dist == rb.dist
        assert set(r1.edge_ids.tolist()) == set(rb.edge_ids.tolist())


def test_bibfs_baseline_matches_oracle():
    g = gnp_random_graph(40, 3.0, seed=31)
    rng = np.random.default_rng(3)
    for _ in range(6):
        u, v = int(rng.integers(0, 40)), int(rng.integers(0, 40))
        o = bfs_spg(g, u, v)
        b = bibfs_spg(g, u, v)
        assert b.dist == o.dist
        assert b.edge_pairs(g) == o.edge_pairs(g)
