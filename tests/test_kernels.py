"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py.

Kernels execute under ``interpret=True`` on CPU: the same BlockSpec tiling
and kernel body the TPU would run, minus the hardware.
"""
import numpy as np
import pytest
from numpy.testing import assert_array_equal

import jax.numpy as jnp

from repro.core.graph import INF
from repro.kernels import ref
from repro.kernels.frontier import bitmap_expand
from repro.kernels.minplus import minplus


def _rand_dist(rng, shape, dtype, inf_frac=0.2):
    x = rng.integers(0, 64, size=shape)
    mask = rng.random(shape) < inf_frac
    x = np.where(mask, INF, x)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1),
    (8, 20, 20),       # sketch shape: B queries x R landmarks
    (32, 20, 20),
    (128, 128, 128),   # exactly one tile
    (130, 20, 50),     # ragged every dim
    (256, 64, 129),
    (5, 200, 7),       # K > one lane-width
])
@pytest.mark.parametrize("dtype", [jnp.int32])
def test_minplus_matches_ref(m, k, n, dtype):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = _rand_dist(rng, (m, k), dtype)
    b = _rand_dist(rng, (k, n), dtype)
    got = minplus(a, b, interpret=True)
    want = ref.minplus_ref(a, b)
    assert got.dtype == want.dtype
    assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("tm,tn", [(8, 128), (16, 256), (128, 128)])
def test_minplus_tile_shapes(tm, tn):
    rng = np.random.default_rng(0)
    a = _rand_dist(rng, (100, 20), jnp.int32)
    b = _rand_dist(rng, (20, 20), jnp.int32)
    got = minplus(a, b, tm=tm, tn=tn, interpret=True)
    assert_array_equal(np.asarray(got), np.asarray(ref.minplus_ref(a, b)))


def test_minplus_inf_saturation():
    """All-INF rows stay INF-dominated (no wraparound)."""
    a = jnp.full((4, 4), INF, jnp.int32)
    b = jnp.full((4, 4), INF, jnp.int32)
    got = np.asarray(minplus(a, b, interpret=True))
    assert (got >= 2 * INF).all()


@pytest.mark.parametrize("r,v", [
    (1, 1),
    (8, 128),
    (20, 100),     # labelling shape: R landmarks x V block
    (20, 257),     # ragged
    (3, 300),
    (64, 512),
])
def test_bitmap_expand_matches_ref(r, v):
    rng = np.random.default_rng(r * 100 + v)
    frontier = jnp.asarray(rng.random((r, v)) < 0.1)
    adj = rng.random((v, v)) < 0.05
    adj = np.triu(adj, 1)
    adj = jnp.asarray(adj | adj.T)
    got = bitmap_expand(frontier, adj, interpret=True)
    want = ref.bitmap_expand_ref(frontier, adj)
    assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("tk", [128, 256])
def test_bitmap_expand_k_grid_accumulation(tk):
    """Multi-step K-grid must accumulate across adjacency column blocks."""
    rng = np.random.default_rng(5)
    frontier = jnp.asarray(rng.random((8, 300)) < 0.2)
    adj = rng.random((300, 300)) < 0.03
    adj = np.triu(adj, 1)
    adj = jnp.asarray(adj | adj.T)
    got = bitmap_expand(frontier, adj, tk=tk, interpret=True)
    assert_array_equal(np.asarray(got), np.asarray(ref.bitmap_expand_ref(frontier, adj)))


def test_bitmap_expand_is_bfs_step():
    """Kernel output == one level of BFS on a path graph."""
    v = 40
    adj = np.zeros((v, v), bool)
    for i in range(v - 1):
        adj[i, i + 1] = adj[i + 1, i] = True
    frontier = np.zeros((2, v), bool)
    frontier[0, 0] = True
    frontier[1, 20] = True
    got = np.asarray(bitmap_expand(jnp.asarray(frontier), jnp.asarray(adj), interpret=True))
    assert got[0].nonzero()[0].tolist() == [1]
    assert got[1].nonzero()[0].tolist() == [19, 21]


def test_sketch_d_top_pallas_path_matches_core():
    """Pallas sketching fast path == core sketch d_top on a real labelling."""
    from repro.core import build_labelling, compute_sketch_batch, gnp_random_graph, select_landmarks
    from repro.kernels import sketch_d_top

    g = gnp_random_graph(60, 3.0, seed=2)
    scheme = build_labelling(g, select_landmarks(g, 6))
    rng = np.random.default_rng(3)
    us = jnp.asarray(rng.integers(0, 60, size=16))
    vs = jnp.asarray(rng.integers(0, 60, size=16))
    lu = scheme.label_dist[us]
    lv = scheme.label_dist[vs]
    sk = compute_sketch_batch(lu, lv, scheme.meta_w, scheme.meta_dist)
    got = sketch_d_top(lu, lv, scheme.meta_dist)
    assert_array_equal(np.minimum(np.asarray(got), INF), np.asarray(sk.d_top))
