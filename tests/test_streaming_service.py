"""Streaming admission control (``serving.stream``): bit-identity to the
pure-numpy seed-semantics oracle under every admission/cache/async
policy, future semantics, cross-batch dedup, adaptive chunk tracking,
and the hub-skew cache eviction policy."""
import numpy as np
import pytest

from helpers.serving_oracle import assert_bit_identical

from repro.core import QbSIndex, gnp_random_graph
from repro.serving import (
    AdmissionPolicy,
    ServingService,
    StreamingService,
    merge_plans,
    plan_from_pairs,
    plan_queries,
)

BACKEND_OPTS = {
    "segment": {},
    "csr": {"engine_opts": {"block_size": 64}},
    "hybrid": {"engine_opts": {"n_hubs": 16}},
}

POLICIES = {
    "adaptive": AdmissionPolicy(adaptive=True, min_chunk=2, max_chunk=32),
    "fixed": AdmissionPolicy(adaptive=False, chunk=8),
}


@pytest.fixture(scope="module")
def graph():
    return gnp_random_graph(45, 3.2, seed=17)


@pytest.fixture(scope="module", params=sorted(BACKEND_OPTS))
def index(request, graph):
    return QbSIndex.build(graph, n_landmarks=5, chunk=8,
                          backend=request.param,
                          **BACKEND_OPTS[request.param])


@pytest.fixture(scope="module")
def seg_index(graph):
    return QbSIndex.build(graph, n_landmarks=5, chunk=8)


def _mixed_trace(idx, rng, n=26):
    """All four lanes + duplicates, same recipe as the planner tests."""
    g = idx.graph
    lms = np.asarray(idx.scheme.landmarks)
    non = np.flatnonzero(~idx._is_landmark_np)
    us = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    vs = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    us[0] = vs[0] = int(non[0])            # trivial
    us[1], vs[1] = lms[0], lms[1]          # landmark-landmark
    us[2], vs[2] = lms[2], non[1]          # one-sided
    us[3], vs[3] = non[2], non[3]          # general
    us[4], vs[4] = vs[3], us[3]            # swapped duplicate
    return us, vs


def test_stream_bit_identical_every_policy(index):
    """Incremental submission with interleaved drains is bit-identical to
    the oracle on every backend × admission policy × cache policy ×
    async depth."""
    idx = index
    rng = np.random.default_rng(3)
    combos = [
        dict(policy=POLICIES["adaptive"]),
        dict(policy=POLICIES["fixed"], async_depth=1),
        dict(policy=POLICIES["adaptive"], cache_size=32),
        dict(policy=POLICIES["adaptive"], cache_size=32, cache_policy="hub"),
    ]
    for kw in combos:
        us, vs = _mixed_trace(idx, rng)
        st = StreamingService(idx, **kw)
        futs = []
        for k in range(us.size):
            futs.append(st.submit(int(us[k]), int(vs[k])))
            if k in (7, 15):               # idle gaps mid-stream
                st.drain()
        st.drain()
        assert st.n_pending == 0 and st.n_inflight == 0
        assert_bit_identical(idx.graph, [f.result() for f in futs], us, vs)


def test_one_shot_wrapper_matches_service(seg_index):
    """StreamingService.query_batch == ServingService.query_batch on
    (u, v, dist, edge_ids, d_top) — including the cache-hit resolution
    path on a repeated batch."""
    idx = seg_index
    rng = np.random.default_rng(5)
    us, vs = _mixed_trace(idx, rng)
    ref = ServingService(idx).query_batch(us, vs)
    st = StreamingService(idx, cache_size=64)
    for _ in range(2):                     # second pass resolves from cache
        got = st.query_batch(us, vs)
        for a, b in zip(ref, got):
            assert (a.u, a.v, a.dist, a.d_top) == (b.u, b.v, b.dist, b.d_top)
            assert np.array_equal(a.edge_ids, b.edge_ids)
    assert st.stats["cache_hits"] > 0


def test_futures_resolve_on_drain(seg_index):
    idx = seg_index
    non = np.flatnonzero(~idx._is_landmark_np)
    st = StreamingService(idx, policy=AdmissionPolicy(adaptive=False,
                                                      chunk=64))
    triv = st.submit(int(non[0]), int(non[0]))
    assert triv.done()                     # trivial resolves at submit
    fut = st.submit(int(non[1]), int(non[2]))
    assert not fut.done() and st.n_pending == 1   # below admission width
    st.drain()
    assert fut.done()
    # result() on an unresolved future drains implicitly
    fut2 = st.submit(int(non[3]), int(non[4]))
    assert not fut2.done()
    assert fut2.result().dist == fut2.result().dist   # idempotent
    assert fut2.done()


def test_inflight_dedup_joins(seg_index):
    """Duplicate submissions of a pending/in-flight canonical pair join
    the existing computation — one device answer fans out to all of them
    (shared edge_ids array, no recompute)."""
    idx = seg_index
    non = np.flatnonzero(~idx._is_landmark_np)
    st = StreamingService(idx, policy=AdmissionPolicy(adaptive=False,
                                                      chunk=64))
    a = st.submit(int(non[1]), int(non[2]))
    b = st.submit(int(non[2]), int(non[1]))    # swapped orientation
    c = st.submit(int(non[1]), int(non[2]))
    assert st.stats["joined"] == 2
    assert st.n_pending == 1                   # one unique pair pending
    st.drain()
    ra, rb, rc = a.result(), b.result(), c.result()
    assert ra.dist == rb.dist == rc.dist
    assert ra.edge_ids is rb.edge_ids is rc.edge_ids
    assert (rb.u, rb.v) == (int(non[2]), int(non[1]))  # orientation kept
    assert st.stats["admitted_pairs"] == 1


def test_cache_hit_resolves_at_submit(seg_index):
    idx = seg_index
    non = np.flatnonzero(~idx._is_landmark_np)
    st = StreamingService(idx, cache_size=16)
    first = st.submit(int(non[1]), int(non[2]))
    st.drain()
    hit = st.submit(int(non[2]), int(non[1]))
    assert hit.done()                      # resolved without device work
    assert st.stats["cache_hits"] == 1 and st.n_pending == 0
    assert hit.result().dist == first.result().dist
    assert np.array_equal(hit.result().edge_ids, first.result().edge_ids)
    assert hit.result().d_top == first.result().d_top


def test_adaptive_chunk_tracks_backlog(seg_index):
    idx = seg_index
    rng = np.random.default_rng(11)
    pol = AdmissionPolicy(adaptive=True, chunk=4, min_chunk=2, max_chunk=32)
    st = StreamingService(idx, policy=pol)
    assert st.chunk == 4
    g = idx.graph
    us = rng.integers(0, g.n_vertices, size=24).astype(np.int32)
    vs = (us + 1 + rng.integers(0, g.n_vertices - 1, size=24)).astype(
        np.int32) % g.n_vertices
    st.submit_batch(us, vs)                # burst: backlog >> width
    assert st.chunk > 4                    # grew toward the backlog
    grown = st.chunk
    for _ in range(4):                     # trickle ticks with idle gaps
        st.submit(int(us[0]), int(vs[0]))
        st.drain()
    assert st.chunk < grown                # shrank back toward min_chunk
    # fixed policy never moves
    st2 = StreamingService(idx, policy=AdmissionPolicy(adaptive=False,
                                                       chunk=8))
    st2.submit_batch(us, vs)
    st2.drain()
    assert st2.chunk == 8


def test_admission_policy_snaps_to_pow2_ladder():
    """Off-ladder bounds snap (min up, max down) so the adaptive walk can
    neither escape [min, max] nor mint widths off the ladder."""
    pol = AdmissionPolicy(min_chunk=5, max_chunk=100)
    assert (pol.min_chunk, pol.max_chunk) == (8, 64)
    assert pol.initial_chunk(100) == 64     # never above the stated cap
    assert pol.initial_chunk(1) == 8
    with pytest.raises(ValueError):
        AdmissionPolicy(min_chunk=5, max_chunk=6)   # 8 > 4 after snapping
    with pytest.raises(ValueError):
        AdmissionPolicy(min_chunk=0)


def test_serve_iterator_arrival_order(seg_index):
    idx = seg_index
    rng = np.random.default_rng(7)
    us, vs = _mixed_trace(idx, rng, n=18)
    st = StreamingService(idx, cache_size=16)
    res = list(st.serve(zip(us.tolist(), vs.tolist())))
    assert_bit_identical(idx.graph, res, us, vs)


def test_mesh_stream_bit_identical(graph):
    """Streaming over a sharded (1-device mesh) service matches the
    oracle — the adaptive widths re-round to the shard multiple."""
    idx = QbSIndex.build(graph, n_landmarks=5, chunk=8)
    st = StreamingService(idx, devices=1,
                          policy=AdmissionPolicy(min_chunk=2, max_chunk=16))
    rng = np.random.default_rng(19)
    us, vs = _mixed_trace(idx, rng)
    futs = st.submit_batch(us, vs)
    st.drain()
    assert_bit_identical(idx.graph, [f.result() for f in futs], us, vs)


def test_hub_cache_protects_hot_hub_entries(seg_index):
    """Flooding a small cache with cold one-shot pairs evicts a hub-pair
    entry under LRU but not under the hub-skew policy."""
    idx = seg_index
    lms = np.asarray(idx.scheme.landmarks)
    non = np.flatnonzero(~idx._is_landmark_np)
    hot = (int(lms[0]), int(non[0]))       # landmark endpoint => protected
    flood = [(int(non[i]), int(non[i + 1])) for i in range(1, 13)]
    outcomes = {}
    for cpol in ("lru", "hub"):
        st = StreamingService(idx, cache_size=8, cache_policy=cpol)
        st.submit(*hot)
        st.drain()
        for u, v in flood:                 # 12 cold inserts > capacity 8
            st.submit(u, v)
            st.drain()
        before = st.stats["cache_hits"]
        st.submit(*hot)
        st.drain()
        outcomes[cpol] = st.stats["cache_hits"] - before
    assert outcomes["hub"] == 1            # survived the flood
    assert outcomes["lru"] == 0            # evicted


def test_plan_from_pairs_and_merge_plans(seg_index):
    idx = seg_index
    is_l = idx._is_landmark_np
    lms = np.asarray(idx.scheme.landmarks)
    non = np.flatnonzero(~is_l)
    cu = np.minimum([lms[0], non[0]], [lms[1], non[1]]).astype(np.int32)
    cv = np.maximum([lms[0], non[0]], [lms[1], non[1]]).astype(np.int32)
    plan = plan_from_pairs(cu, cv, is_l)
    assert plan.n == plan.n_unique == 2
    assert np.array_equal(plan.inv, [0, 1])
    ref = plan_queries(cu, cv, is_l)
    assert np.array_equal(plan.lane, ref.lane)
    # merging re-dedups across plan boundaries
    other = plan_from_pairs(cu[:1], cv[:1], is_l)   # overlaps pair 0
    merged = merge_plans([plan, other], is_l)
    assert merged.n == 3 and merged.n_unique == 2
    assert np.array_equal(merged.cu, plan.cu)
    assert merge_plans([plan], is_l) is plan
    assert merge_plans([], is_l).n == 0
