"""Substrate tests: optimizer, train loop, data pipeline determinism,
checkpoint/restart (preemption simulation), compressed collectives."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import Prefetcher, SyntheticLM, SyntheticLMConfig
from repro.models import build_model
from repro.training import adamw, compress_bf16, make_train_step, warmup_cosine
from repro import checkpoint as ckpt


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("qwen1.5-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # peak lr tuned for the reduced (d=64, 2-layer) model: 3e-3 learns the
    # Markov stream too slowly to clear test_loss_decreases' margin in 30
    # steps (drop 0.45); 2e-2 with a short warmup drops ~1.3 nats.
    opt = adamw(warmup_cosine(2e-2, 3, 100), weight_decay=0.01)
    opt_state = opt.init(params)
    data = SyntheticLM(SyntheticLMConfig(cfg.vocab_size, seq_len=32, global_batch=8))
    step_fn = jax.jit(make_train_step(model, opt))
    return cfg, model, params, opt, opt_state, data, step_fn


def test_loss_decreases(tiny_setup):
    """End-to-end training sanity: 30 steps on the synthetic Markov stream
    must reduce loss substantially (the stream is learnable)."""
    _, _, params, _, opt_state, data, step_fn = tiny_setup
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]
    assert np.isfinite(losses).all()


def test_grad_accumulation_matches_full_batch(tiny_setup):
    cfg, model, params, opt, opt_state, data, _ = tiny_setup
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    s1 = jax.jit(make_train_step(model, opt))
    s4 = jax.jit(make_train_step(model, opt, microbatches=4))
    p1, _, m1 = s1(params, opt_state, batch)
    p4, _, m4 = s4(params, opt_state, batch)
    # means of per-microbatch grads == full-batch grad (loss is per-token mean
    # over equal-sized microbatches)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        p1, p4)
    assert max(jax.tree_util.tree_leaves(d)) < 5e-2


def test_remat_matches(tiny_setup):
    cfg, model, params, opt, opt_state, data, _ = tiny_setup
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(1).items()}
    a = jax.jit(make_train_step(model, opt))(params, opt_state, batch)[2]
    b = jax.jit(make_train_step(model, opt, remat=True))(params, opt_state, batch)[2]
    assert abs(float(a["loss"]) - float(b["loss"])) < 1e-5


def test_bf16_compression_close(tiny_setup):
    cfg, model, params, opt, opt_state, data, _ = tiny_setup
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(2).items()}
    a = jax.jit(make_train_step(model, opt))(params, opt_state, batch)[0]
    b = jax.jit(make_train_step(model, opt, compress=compress_bf16))(
        params, opt_state, batch)[0]
    d = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32)))),
        a, b)
    assert max(jax.tree_util.tree_leaves(d)) < 1e-2


def test_data_pipeline_determinism_and_elasticity():
    cfg = SyntheticLMConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    one_host = SyntheticLM(cfg, host=0, n_hosts=1)
    two_a = SyntheticLM(cfg, host=0, n_hosts=2)
    two_b = SyntheticLM(cfg, host=1, n_hosts=2)
    b1 = one_host.batch_at(7)
    assert (one_host.batch_at(7)["tokens"] == b1["tokens"]).all()  # replayable
    # different hosts generate disjoint deterministic shards of the same step
    a = two_a.batch_at(7)["tokens"]
    b = two_b.batch_at(7)["tokens"]
    assert a.shape == (4, 16) and b.shape == (4, 16)
    assert not (a == b).all()


def test_prefetcher_orders_steps():
    cfg = SyntheticLMConfig(vocab_size=50, seq_len=8, global_batch=2)
    pf = Prefetcher(SyntheticLM(cfg), start_step=5, depth=2)
    try:
        steps = [pf.next()[0] for _ in range(4)]
        assert steps == [5, 6, 7, 8]
    finally:
        pf.close()


def test_file_backed_pipeline(tmp_path):
    from repro.data import FileBackedLM

    tokens = np.arange(10_000, dtype=np.int32) % 97
    FileBackedLM.write_corpus(tmp_path, tokens, n_hosts=2)
    ds = FileBackedLM(tmp_path, seq_len=16, local_batch=4, host=1, n_hosts=2)
    b0 = ds.batch_at(0)["tokens"]
    assert b0.shape == (4, 16)
    assert (ds.batch_at(0)["tokens"] == b0).all()


def test_checkpoint_resume_bitwise(tiny_setup, tmp_path):
    """Preemption simulation: train 6 steps, checkpoint at 3, 'crash',
    restore, continue — final params must be bitwise identical."""
    _, model, params0, opt, opt_state0, data, step_fn = tiny_setup

    params, opt_state = params0, opt_state0
    for step in range(6):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt_state, _ = step_fn(params, opt_state, batch)
        if step == 2:
            ckpt.save(tmp_path, step + 1, {"params": params, "opt": opt_state},
                      extra={"data_step": step + 1})
    want = jax.tree_util.tree_map(np.asarray, params)

    # "crash" -> fresh process state: restore and replay remaining steps
    step, tree, extra = ckpt.restore(
        tmp_path, {"params": params0, "opt": opt_state0})
    assert step == 3 and extra["data_step"] == 3
    params, opt_state = tree["params"], tree["opt"]
    for s in range(extra["data_step"], 6):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        params, opt_state, _ = step_fn(params, opt_state, batch)
    got = jax.tree_util.tree_map(np.asarray, params)
    for a, b in zip(jax.tree_util.tree_leaves(want), jax.tree_util.tree_leaves(got)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_rotation_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(5), "b": jnp.ones((2, 2), jnp.bfloat16)}
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, s, tree, keep=2)
    names = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert names == ["step_0000000003", "step_0000000004"]
    assert ckpt.latest_step(tmp_path) == 4
    # a stale tmp dir must never be picked up
    (tmp_path / ".tmp_step_0000000009").mkdir()
    assert ckpt.latest_step(tmp_path) == 4
    _, restored, _ = ckpt.restore(tmp_path, tree)
    assert restored["b"].dtype == jnp.bfloat16


def test_async_checkpoint(tmp_path):
    tree = {"w": jnp.arange(12).reshape(3, 4)}
    t = ckpt.save_async(tmp_path, 7, tree)
    t.join(timeout=30)
    s, restored, _ = ckpt.restore(tmp_path, tree)
    assert s == 7 and (np.asarray(restored["w"]) == np.arange(12).reshape(3, 4)).all()


def test_compressed_psum_shard_map():
    """bf16/int8-EF psum == exact psum within tolerance on a 1-dev mesh."""
    from jax.sharding import Mesh
    from repro.compat import shard_map
    from repro.distributed import psum_bf16, psum_int8_ef

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)}
    e0 = jax.tree_util.tree_map(jnp.zeros_like, g)

    def body(g):
        return psum_bf16(g, ("data",))

    out = jax.jit(shard_map(body, mesh=mesh, in_specs=({"w": jax.sharding.PartitionSpec()},),
                            out_specs={"w": jax.sharding.PartitionSpec()}))(g)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), rtol=1e-2, atol=1e-2)

    def body2(g, e):
        return psum_int8_ef(g, e, ("data",))

    out2, err = jax.jit(shard_map(
        body2, mesh=mesh,
        in_specs=({"w": jax.sharding.PartitionSpec()}, {"w": jax.sharding.PartitionSpec()}),
        out_specs=({"w": jax.sharding.PartitionSpec()}, {"w": jax.sharding.PartitionSpec()})))(g, e0)
    np.testing.assert_allclose(np.asarray(out2["w"]), np.asarray(g["w"]), atol=0.05)
    # error feedback captures the quantization residual
    assert float(jnp.max(jnp.abs(err["w"]))) <= 0.05


def test_greedy_generate_runs():
    cfg = get_config("deepseek-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.serving import greedy_generate

    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out = greedy_generate(model, params, prompt, n_new=4)
    assert out.shape == (2, 4)
    out_q = greedy_generate(model, params, prompt, n_new=4, kv_quant=True)
    assert out_q.shape == (2, 4)
