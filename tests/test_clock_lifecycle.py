"""Clock/timer lifecycle (``serving.clock`` + the stream's deadline
timer): drain/close must disarm SystemClock timers (no daemon timer
outlives the service), and ManualClock handles stay safe to cancel
after they have fired."""
import threading
import time

import pytest

from repro.core import QbSIndex, gnp_random_graph
from repro.serving import ManualClock, QoSClass, StreamingService
from repro.serving.clock import SystemClock


@pytest.fixture(scope="module")
def index():
    return QbSIndex.build(gnp_random_graph(40, 3.0, seed=5),
                          n_landmarks=4, chunk=8)


def _deadline_service(index, **kw):
    # a long max_wait keeps the timer armed until we drain explicitly
    return StreamingService(
        index, qos=[QoSClass("default", max_wait=60.0)], **kw)


def _timer_threads():
    return [t for t in threading.enumerate()
            if isinstance(t, threading.Timer) and t.is_alive()]


def _settle(deadline=2.0):
    """Give cancelled Timer threads a beat to wake up and exit."""
    t0 = time.perf_counter()
    while _timer_threads() and time.perf_counter() - t0 < deadline:
        time.sleep(0.01)


# ----------------------------------------------------------- SystemClock


def test_drain_cancels_system_timer(index):
    _settle()
    before = len(_timer_threads())
    svc = _deadline_service(index)
    svc.submit(1, 7)
    timer = svc._timer
    assert timer is not None and timer.is_alive()
    svc.drain()
    assert svc._timer is None
    assert timer.finished.is_set()            # cancel() reached the Timer
    _settle()
    assert len(_timer_threads()) <= before    # no leaked daemon timers


def test_close_is_idempotent_and_service_reusable(index):
    svc = _deadline_service(index)
    svc.submit(2, 9)
    svc.close()
    assert svc._timer is None and svc._armed_for is None
    svc.close()                               # idempotent
    r = svc.submit(3, 8).result()             # still usable after close
    assert r.dist >= 1
    svc.close()
    assert svc._timer is None


def test_context_manager_disarms_on_exit(index):
    with _deadline_service(index) as svc:
        fut = svc.submit(4, 11)
    assert fut.done()                         # __exit__ drained
    assert svc._timer is None
    _settle()


def test_system_clock_cancel_before_fire():
    clock = SystemClock()
    fired = threading.Event()
    timer = clock.call_at(clock.now() + 30.0, fired.set)
    assert timer.daemon
    timer.cancel()
    _settle()
    assert not fired.is_set()


# ----------------------------------------------------------- ManualClock


def test_manual_cancel_after_fire_is_noop():
    clock = ManualClock()
    fired = []
    h = clock.call_at(1.0, lambda: fired.append(clock.now()))
    clock.advance(2.0)
    assert fired == [1.0]                     # fired at its instant
    h.cancel()                                # after the fact: a no-op
    clock.advance(5.0)
    assert fired == [1.0]                     # and nothing re-fires


def test_manual_cancel_before_fire_suppresses():
    clock = ManualClock()
    fired = []
    h = clock.call_at(1.0, lambda: fired.append(1))
    h.cancel()
    clock.advance(10.0)
    assert fired == []


def test_manual_advance_fires_in_deadline_order():
    clock = ManualClock()
    order = []
    clock.call_at(3.0, lambda: order.append(("b", clock.now())))
    clock.call_at(1.0, lambda: order.append(("a", clock.now())))
    clock.call_at(2.0, lambda: order.append(("m", clock.now())))
    clock.advance_to(10.0)
    assert order == [("a", 1.0), ("m", 2.0), ("b", 3.0)]
    assert clock.now() == 10.0


def test_stream_timer_with_manual_clock_disarms_on_drain(index):
    clock = ManualClock()
    svc = _deadline_service(index, clock=clock)
    svc.submit(5, 12)
    assert svc._timer is not None and not svc._timer.cancelled
    svc.drain()
    assert svc._timer is None                 # disarmed, handle dropped
    clock.advance(120.0)                      # stale wakeups: none fire
    assert svc.n_pending == 0 and svc.n_inflight == 0
