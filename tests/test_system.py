"""End-to-end behaviour tests for the full system: offline labelling →
online batched serving → exactness, plus the LM substrate driven through
its public launcher APIs."""
import subprocess
import sys
import os

import numpy as np

from repro.core import INF, QbSIndex, barabasi_albert_graph, labelling_size_bytes
from repro.core.baselines import bfs_spg


def test_qbs_end_to_end_pipeline():
    """Build → sketch → guided search → exact SPGs on a 2k-vertex graph,
    the whole pipeline through the public facade."""
    g = barabasi_albert_graph(2_000, 3, seed=5)
    idx = QbSIndex.build(g, n_landmarks=20)

    # labelling invariants at system level
    sz = labelling_size_bytes(idx.scheme)
    assert sz["label_bytes"] == 2_000 * 20
    assert sz["n_meta_edges"] > 0

    rng = np.random.default_rng(0)
    us = rng.integers(0, 2_000, size=12)
    vs = rng.integers(0, 2_000, size=12)
    results = idx.query_batch(us, vs)
    n_checked = 0
    for r in results:
        o = bfs_spg(g, r.u, r.v)
        assert r.dist == o.dist
        assert r.edge_pairs(g) == o.edge_pairs(g)
        if r.dist < INF and r.dist > 1:
            n_checked += 1
    assert n_checked >= 6  # the graph regime actually exercised multi-hop SPGs


def test_train_launcher_end_to_end(tmp_path):
    """The public training driver: fresh run + checkpoint + resume."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen1.5-4b",
            "--reduced", "--steps", "30", "--seq-len", "32",
            "--global-batch", "4", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "10", "--log-every", "10"]
    out = subprocess.run(base, env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "loss=" in out.stdout

    out2 = subprocess.run(base + ["--resume", "--steps", "35"], env=env,
                          capture_output=True, text=True, timeout=900)
    assert out2.returncode == 0, out2.stdout + out2.stderr
    assert "resumed from step 30" in out2.stdout


def test_serve_launcher_end_to_end():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--graph", "ba",
         "--n", "3000", "--landmarks", "10", "--queries", "24"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "queries in" in out.stdout
