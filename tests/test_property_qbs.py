"""Hypothesis property tests for the system's invariants.

Graphs are padded to fixed (V, E) buckets so every example reuses one jit
cache entry (isolated pad vertices + self-loop pad edges are BFS no-ops).

``hypothesis`` is a dev dependency (pyproject ``dev`` extra) installed in
both CI matrix legs; the importorskip only covers bare containers.  The
examples budget scales with ``QBS_PROPERTY_EXAMPLES_SCALE`` (the nightly
CI job bumps it).
"""
import os

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # bare container: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import INF, QbSIndex, from_edges
from repro.core.baselines import bfs_spg

V_BUCKET = 48
E_BUCKET = 512  # directed slots
_SCALE = max(1, int(os.environ.get("QBS_PROPERTY_EXAMPLES_SCALE", "1")))


@st.composite
def padded_graphs(draw):
    n = draw(st.integers(min_value=6, max_value=V_BUCKET - 1))
    m = draw(st.integers(min_value=n // 2, max_value=min(3 * n, E_BUCKET // 2 - 4)))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(m, 2))
    g = from_edges(edges, n, pad_vertices_to=V_BUCKET, pad_edges_to=E_BUCKET)
    return g, n, seed


@given(padded_graphs(), st.integers(0, 3))
@settings(max_examples=25 * _SCALE, deadline=None)
def test_qbs_spg_equals_oracle(gn, nl_choice):
    g, n, seed = gn
    rng = np.random.default_rng(seed ^ 0xABCD)
    nl = [1, 2, 4, 6][nl_choice]
    # restrict landmark choice to real (non-pad) vertices
    deg = np.asarray(g.degrees())[:n]
    landmarks = np.sort(np.argsort(-deg)[:nl]).astype(np.int32)
    idx = QbSIndex.build(g, landmarks=landmarks)
    for _ in range(3):
        u = int(rng.integers(0, n))
        v = int(rng.integers(0, n))
        o = bfs_spg(g, u, v)
        r = idx.query(u, v)
        assert r.dist == o.dist, (u, v, r.dist, o.dist)
        assert r.edge_pairs(g) == o.edge_pairs(g), (u, v)


@given(padded_graphs())
@settings(max_examples=15 * _SCALE, deadline=None)
def test_spg_structural_invariants(gn):
    """Every returned SPG is a union of shortest paths: each edge lies on a
    shortest u-v path; u and v are in the vertex set when connected."""
    g, n, seed = gn
    rng = np.random.default_rng(seed ^ 0x1234)
    deg = np.asarray(g.degrees())[:n]
    landmarks = np.sort(np.argsort(-deg)[:3]).astype(np.int32)
    idx = QbSIndex.build(g, landmarks=landmarks)
    u = int(rng.integers(0, n))
    v = int(rng.integers(0, n))
    r = idx.query(u, v)
    if r.dist >= INF:
        assert r.edge_ids.size == 0
        return
    if r.dist == 0:
        return
    from repro.core.baselines import bfs_distances

    du = bfs_distances(g, u)
    dv = bfs_distances(g, v)
    verts = r.vertices(g)
    assert u in verts and v in verts
    for a, b in r.edge_pairs(g):
        on = (du[a] + 1 + dv[b] == r.dist) or (du[b] + 1 + dv[a] == r.dist)
        assert on, (a, b, r.dist, du[a], dv[a], du[b], dv[b])


@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
@settings(max_examples=10 * _SCALE, deadline=None)
def test_labelling_deterministic_under_permutation(seed, nl):
    from repro.core import build_labelling, select_landmarks

    rng = np.random.default_rng(seed)
    edges = rng.integers(0, V_BUCKET - 1, size=(60, 2))
    g = from_edges(edges, V_BUCKET - 1, pad_vertices_to=V_BUCKET, pad_edges_to=E_BUCKET)
    landmarks = select_landmarks(g, nl)
    perm = rng.permutation(nl)
    s1 = build_labelling(g, landmarks)
    s2 = build_labelling(g, np.asarray(landmarks)[perm])
    assert (np.asarray(s1.label_dist)[:, perm] == np.asarray(s2.label_dist)).all()
