"""Observability layer (DESIGN.md §12): log2-bucket latency histograms
recorded at future-resolution time on the injectable clock, the
``MetricsRegistry`` snapshot/text exposition, the stdlib scrape endpoint,
and the ``ResultCache`` eviction/byte attribution counters the replica
acceptance checks read."""
import math
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import QbSIndex, gnp_random_graph
from repro.serving import (
    LatencyHistogram,
    ManualClock,
    MetricsRegistry,
    QoSClass,
    StreamingService,
    merged_latency,
    serve_metrics,
)
from repro.serving.metrics import N_BUCKETS, bucket_of, bucket_upper_us
from repro.serving.service import ResultCache


@pytest.fixture(scope="module")
def index():
    return QbSIndex.build(gnp_random_graph(40, 3.0, seed=23),
                          n_landmarks=4, chunk=8)


def _non(index, k):
    return int(np.flatnonzero(~index._is_landmark_np)[k])


# ---------------------------------------------------------------- histogram


def test_bucket_edges_pin_the_issue_cases():
    """The four edges the ISSUE names: zero, the 1us boundary, the last
    finite bucket, and overflow."""
    assert bucket_of(0.0) == 0
    assert bucket_of(0.5) == 0              # sub-microsecond -> bucket 0
    assert bucket_of(1.0) == 1              # first finite log2 bucket
    assert bucket_of(2.0**31 - 1) == 31     # top finite bucket
    assert bucket_of(2.0**31) == N_BUCKETS - 1        # overflow
    assert bucket_of(1e300) == N_BUCKETS - 1
    assert bucket_upper_us(0) == 1.0
    assert bucket_upper_us(31) == float(2**31)
    assert math.isinf(bucket_upper_us(N_BUCKETS - 1))


def test_every_finite_bucket_brackets_its_values():
    """bucket b in [1,31] holds exactly [2^(b-1), 2^b)."""
    for b in range(1, N_BUCKETS - 1):
        lo, hi = 2 ** (b - 1), 2**b
        assert bucket_of(float(lo)) == b
        assert bucket_of(float(hi - 1)) == b
        assert bucket_of(float(hi)) == (b + 1 if b < 31 else N_BUCKETS - 1)
        assert bucket_upper_us(b) == float(hi)


def test_observe_counts_stay_python_ints():
    """Counts must be host-side Python ints even when fed numpy scalars
    (QBS007's spirit: no numpy scalars leak into the scrape path)."""
    h = LatencyHistogram()
    h.observe(np.float64(5.0))
    h.observe(np.int64(3))
    assert all(type(c) is int for c in h.counts)
    assert type(h.total) is int and h.total == 2
    assert isinstance(h.sum_us, float) and h.sum_us == 8.0


def test_quantile_is_conservative_bucket_upper_edge():
    h = LatencyHistogram()
    assert h.quantile(0.5) == 0.0           # empty histogram
    h.observe(3.0)                          # bucket 2: [2, 4)
    assert h.quantile(0.5) == 4.0
    assert h.quantile(0.99) == 4.0
    for _ in range(99):
        h.observe(0.0)
    # 100 observations, 99 in bucket 0: p50 rounds to bucket 0's edge,
    # p99 lands on the rank-99 observation (still bucket 0)
    assert h.quantile(0.50) == 1.0
    assert h.quantile(0.99) == 1.0
    assert h.quantile(1.00) == 4.0          # the single slow observation


def test_overflow_quantile_reports_inf_not_a_finite_lie():
    h = LatencyHistogram()
    h.observe(2.0**40)
    assert h.counts[N_BUCKETS - 1] == 1
    assert math.isinf(h.quantile(0.5))
    snap = h.snapshot()
    assert math.isinf(snap["p99_us"]) and snap["total"] == 1


def test_check_hook_fires_before_every_mutation():
    calls = []

    def probe():
        calls.append(1)
        raise AssertionError("off-lock observe")

    h = LatencyHistogram(check=probe)
    with pytest.raises(AssertionError, match="off-lock"):
        h.observe(1.0)
    assert calls == [1]
    assert h.total == 0 and sum(h.counts) == 0   # rejected before mutating


def test_merged_latency_is_exact_bucket_sum():
    a, b = LatencyHistogram(), LatencyHistogram()
    for us in (0.0, 3.0, 100.0):
        a.observe(us)
    for us in (3.0, 2.0**35):
        b.observe(us)
    m = merged_latency([a, b])
    assert m.total == 5 and m.sum_us == a.sum_us + b.sum_us
    assert m.counts == [x + y for x, y in zip(a.counts, b.counts)]
    assert math.isinf(m.quantile(0.99))


# ---------------------------------------------------------------- registry


def _traced_service(index):
    clk = ManualClock()
    st = StreamingService(
        index, clock=clk, cache_size=32, cache_policy="hub",
        qos=(QoSClass("interactive", max_wait=0.002, weight=4.0),
             QoSClass("bulk", max_wait=0.05, weight=1.0)))
    non = np.flatnonzero(~index._is_landmark_np)
    st.submit_batch(non[:4], non[4:8], qos="interactive")
    clk.advance(0.001)
    st.submit_batch(non[2:6], non[6:10], qos="bulk")   # repeats -> cache/join
    clk.advance(0.1)                                   # both deadlines fire
    st.submit_batch(non[:4], non[4:8], qos="interactive")   # cache hits
    st.drain()
    return st


def test_registry_snapshot_equals_service_counters(index):
    st = _traced_service(index)
    reg = MetricsRegistry()
    reg.register("svc", st)
    snap = reg.snapshot()
    assert set(snap) == {"svc"}
    s = snap["svc"]
    assert s["stats"] == dict(st.stats)
    for name, cs in st.qos_stats.items():
        want = {k: v for k, v in cs.items() if k != "waits"}
        want["n_waits"] = len(cs["waits"])
        assert s["qos"][name] == want
        assert s["latency_us"][name] == st.lat_hist[name].snapshot()
        # resolution accounting: one observation per resolved future
        assert st.lat_hist[name].total == cs["submitted"]
    assert sum(h["total"] for h in s["latency_us"].values()) \
        == st.stats["submitted"]
    assert s["admission"]["rounds"] == len(st.admission_log)
    assert s["cache"]["hits"] == st.service.cache.hits
    assert s["cache"]["evictions"] == st.service.cache.evictions
    assert s["cache"]["bytes"] == st.service.cache.bytes
    assert s["n_pending"] == 0 and s["n_inflight"] == 0
    st.close()


def test_registry_rejects_duplicate_names(index):
    st = StreamingService(index, clock=ManualClock())
    reg = MetricsRegistry()
    reg.register("svc", st)
    with pytest.raises(ValueError, match="duplicate"):
        reg.register("svc", st)
    st.close()


def test_render_text_cumulative_le_series(index):
    st = _traced_service(index)
    reg = MetricsRegistry()
    reg.register("svc", st)
    text = reg.render_text()
    assert text.endswith("\n")
    for cls in ("interactive", "bulk"):
        pre = f'qbs_latency_us_bucket{{service="svc",qos="{cls}",le='
        cums = [int(ln.rsplit(" ", 1)[1])
                for ln in text.splitlines() if ln.startswith(pre)]
        assert len(cums) == N_BUCKETS
        assert cums == sorted(cums)                  # cumulative: monotone
        assert cums[-1] == st.lat_hist[cls].total    # +Inf bucket == count
        assert f'qbs_latency_us_count{{service="svc",qos="{cls}"}} ' \
               f"{st.lat_hist[cls].total}" in text
    assert f'qbs_submitted_total{{service="svc"}} ' \
           f"{st.stats['submitted']}" in text
    assert 'qbs_cache_hits{service="svc"}' in text
    st.close()


def test_scrape_endpoint_serves_and_404s(index):
    st = _traced_service(index)
    reg = MetricsRegistry()
    reg.register("svc", st)
    server = serve_metrics(reg, port=0)
    port = server.server_address[1]
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=10) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith("text/plain")
            body = resp.read().decode()
        assert body == reg.render_text()
        assert "qbs_latency_us_bucket" in body
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(
                f"http://127.0.0.1:{port}/nope", timeout=10)
        assert ei.value.code == 404
    finally:
        server.shutdown()
        server.server_close()
        st.close()


# ---------------------------------------------------------------- cache


def _entry(dist, n):
    return (dist, np.arange(n, dtype=np.int32))


def test_cache_eviction_counter_and_bytes_for():
    cache = ResultCache(2)
    cache.put((0, 1), _entry(1, 4))
    cache.put((0, 2), _entry(2, 4))
    assert cache.evictions == 0
    cache.put((0, 3), _entry(3, 4))          # LRU (0,1) evicted
    assert cache.evictions == 1
    assert cache.get((0, 1)) is None
    present = [(0, 2), (0, 3)]
    assert cache.bytes_for(present) == cache.bytes > 0
    assert cache.bytes_for([(0, 1), (9, 9)]) == 0    # absent keys -> 0
    assert cache.bytes_for(present + [(9, 9)]) == cache.bytes


def test_bytes_for_covers_the_protected_tier():
    cache = ResultCache(4, protect=lambda k: k[0] == 0, protected_frac=0.5)
    cache.put((0, 1), _entry(1, 8))          # protected tier
    cache.put((5, 6), _entry(2, 8))          # unprotected tier
    assert cache.bytes_for([(0, 1)]) > 0
    assert cache.bytes_for([(5, 6)]) > 0
    assert cache.bytes_for([(0, 1), (5, 6)]) == cache.bytes
