"""Dynamic graph updates (DESIGN.md §13): epoch-versioned edge updates
with incremental label maintenance.

The contract under test: ``QbSIndex.apply_update`` returns a *new* index
for the next epoch whose tables are bit-identical to a fresh build on the
post-update graph with the same (pinned) landmark set — whichever branch
resolved it (affected-landmark recompute or the churn-threshold full
rebuild) — while the pre-update index stays untouched, so in-flight work
pinned to it keeps serving its own epoch.  The serving layer on top pins
admission epochs end-to-end: in-flight chunks resolve under the epoch
they were admitted at, the result cache keys carry the epoch (a stale
SPG is unreachable, never served), and every future records the epoch
that answered it (checked against the per-epoch numpy oracle).
"""
import numpy as np
import pytest

from helpers.serving_oracle import EpochOracle, oracle_spg

from repro.core import QbSIndex, gnp_random_graph
from repro.core.graph import edge_set, from_edges
from repro.serving import AdmissionPolicy, ManualClock, StreamingService

V = 48


@pytest.fixture(scope="module")
def graph():
    return gnp_random_graph(V, 3.0, seed=7)


@pytest.fixture(scope="module")
def index(graph):
    return QbSIndex.build(graph, n_landmarks=5, chunk=8)


def _update_trace(graph, rng, n_events):
    """Deterministic alternating insert/delete single-edge events."""
    events = []
    present = {tuple(int(x) for x in e) for e in edge_set(graph)}
    for i in range(n_events):
        if i % 2 == 0:
            while True:
                a, b = (int(x) for x in rng.integers(0, graph.n_vertices, 2))
                if a != b and (min(a, b), max(a, b)) not in present:
                    break
            edge = (min(a, b), max(a, b))
            present.add(edge)
            events.append({"inserts": [edge]})
        else:
            edge = sorted(present)[int(rng.integers(len(present)))]
            present.discard(edge)
            events.append({"deletes": [edge]})
    return events


def _assert_index_identical(a: QbSIndex, b: QbSIndex, us, vs) -> None:
    """Bit-identity of the full serving surface: scheme tables, landmark
    distances, packed tables (including the chosen dtype), and query
    results (dist + edge_mask)."""
    assert np.array_equal(a.scheme.landmarks, b.scheme.landmarks)
    assert np.array_equal(a.scheme.label_dist, b.scheme.label_dist)
    assert np.array_equal(a.scheme.meta_w, b.scheme.meta_w)
    assert np.array_equal(a.scheme.meta_dist, b.scheme.meta_dist)
    assert np.array_equal(a._lm_dist_host, b._lm_dist_host)
    assert a.packed.label_dist.dtype == b.packed.label_dist.dtype
    assert np.array_equal(a.packed.label_dist, b.packed.label_dist)
    assert np.array_equal(a.packed.lm_dist, b.packed.lm_dist)
    da, ma = a.query_batch_arrays(us, vs)
    db, mb = b.query_batch_arrays(us, vs)
    assert np.array_equal(da, db)
    assert np.array_equal(ma, mb)


# ------------------------------------------------------------- maintenance


@pytest.mark.parametrize("backend", ["segment", "csr", "hybrid"])
def test_incremental_update_bit_identical_to_fresh_build(graph, backend):
    """Six alternating single-edge updates: after each epoch the
    incrementally-maintained index equals a from-scratch build on the
    new graph with the same landmarks — on every backend."""
    rng = np.random.default_rng(3)
    cur = QbSIndex.build(graph, n_landmarks=5, chunk=8, backend=backend)
    lms = np.asarray(cur.scheme.landmarks)
    us = rng.integers(0, V, 12).astype(np.int32)
    vs = rng.integers(0, V, 12).astype(np.int32)
    for i, ev in enumerate(_update_trace(graph, rng, 6)):
        cur = cur.apply_update(**ev, churn_threshold=1.1)  # never rebuild
        assert cur.epoch == i + 1
        assert not cur.last_update_info["full_rebuild"]
        fresh = QbSIndex.build(cur.graph, landmarks=lms, chunk=8,
                               backend=backend)
        _assert_index_identical(cur, fresh, us, vs)


def test_rebuild_branch_bit_identical_and_source_untouched(graph, index):
    """churn_threshold=0 forces the full-rebuild branch; it must produce
    the same servable index as the incremental branch, and neither may
    mutate the source epoch's tables."""
    rng = np.random.default_rng(5)
    before = np.asarray(index.packed.label_dist).copy()
    es = edge_set(graph)
    ev = {"deletes": [tuple(int(x) for x in es[7])]}
    inc = index.apply_update(**ev, churn_threshold=1.1)
    reb = index.apply_update(**ev, churn_threshold=0.0)
    assert not inc.last_update_info["full_rebuild"]
    assert reb.last_update_info["full_rebuild"]
    assert inc.epoch == reb.epoch == index.epoch + 1
    us = rng.integers(0, V, 10).astype(np.int32)
    vs = rng.integers(0, V, 10).astype(np.int32)
    _assert_index_identical(inc, reb, us, vs)
    # the admitted epoch's tables survived both branches untouched
    assert np.array_equal(np.asarray(index.packed.label_dist), before)
    assert index.epoch == 0 and index.last_update_info == {}


def test_disconnect_and_reconnect_transitions():
    """Deleting a cut edge takes the pair to INF/no-edges; inserting a
    bridge brings it back — both epochs exact vs the numpy oracle."""
    edges = np.array([[0, 1], [1, 2], [2, 3], [3, 4], [0, 5]])
    g = from_edges(edges, 6)
    cur = QbSIndex.build(g, landmarks=np.array([0, 3]), chunk=4)
    oracle = EpochOracle(g)

    cur = cur.apply_update(deletes=[(0, 5)])
    oracle.advance(cur.graph, deletes=[(0, 5)])
    d, m = cur.query_batch_arrays([5, 0], [2, 4])
    assert d[0] >= (1 << 20) and not m[0].any()     # 5 cut off
    od, oe = oracle.spg(0, 4, 1)
    assert d[1] == od and np.array_equal(np.flatnonzero(m[1]), oe)

    cur = cur.apply_update(inserts=[(5, 4)])
    oracle.advance(cur.graph, inserts=[(5, 4)])
    d, m = cur.query_batch_arrays([5], [2])
    od, oe = oracle.spg(5, 2, 2)
    assert d[0] == od < (1 << 20)
    assert np.array_equal(np.flatnonzero(m[0]), oe)


def test_update_batch_semantics(graph, index):
    """Phantom inserts/deletes are no-ops, an insert wins a same-batch
    tie, self-loops are dropped — and the epoch graph always matches the
    oracle's independent edge algebra."""
    es = edge_set(graph)
    present = tuple(int(x) for x in es[0])
    absent = None
    allset = {tuple(int(x) for x in e) for e in es}
    for a in range(V):
        for b in range(a + 1, V):
            if (a, b) not in allset:
                absent = (a, b)
                break
        if absent:
            break
    oracle = EpochOracle(graph)
    ins = [present, absent, (3, 3)]          # phantom + real + self-loop
    dels = [absent, present]                 # tie with ins (insert wins) +
    nxt = index.apply_update(inserts=ins, deletes=dels)
    oracle.advance(nxt.graph, inserts=ins, deletes=dels)
    info = nxt.last_update_info
    # net effect: insert `absent` (ins wins its tie), keep `present`
    # (its delete ties a requested insert), drop the self-loop
    want = allset | {absent}
    assert {tuple(int(x) for x in e) for e in edge_set(nxt.graph)} == want
    assert nxt.epoch == index.epoch + 1
    assert info["n_affected"] == len(info["affected"])

    # an all-phantom batch still advances the epoch, touching nothing
    noop = index.apply_update(inserts=[present], deletes=[absent])
    assert noop.epoch == index.epoch + 1
    assert noop.last_update_info["n_affected"] == 0
    assert np.array_equal(noop.packed.label_dist, index.packed.label_dist)
    assert np.array_equal(edge_set(noop.graph), edge_set(index.graph))


def test_star_fixture_double_delete_one_batch():
    """Two deletes sharing an endpoint in ONE batch — the affected-set
    criteria must see the batch's joint effect, not each edge alone."""
    edges = np.array([[0, 1], [0, 2], [1, 3], [2, 3], [3, 4]])
    g = from_edges(edges, 5)
    cur = QbSIndex.build(g, landmarks=np.array([0, 3]), chunk=4)
    cur = cur.apply_update(deletes=[(1, 3), (2, 3)])
    fresh = QbSIndex.build(cur.graph, landmarks=np.array([0, 3]), chunk=4)
    us = np.array([0, 0, 3, 1], np.int32)
    vs = np.array([4, 3, 4, 2], np.int32)
    _assert_index_identical(cur, fresh, us, vs)
    d, m = cur.query_batch_arrays(us, vs)
    assert d[0] >= (1 << 20) and d[1] >= (1 << 20)  # {3,4} split off
    assert d[2] == 1 and d[3] == 2


# ---------------------------------------------------------------- serving


def test_inflight_chunks_resolve_under_admission_epoch(graph, index):
    """Chunks already dispatched when an update lands resolve from their
    admission epoch's tables; later submissions of the *same pairs*
    resolve from the new epoch — each checked against its own oracle."""
    st = StreamingService(
        index, clock=ManualClock(),
        policy=AdmissionPolicy(adaptive=False, chunk=4, min_chunk=4),
        async_depth=4, cache_size=64)
    rng = np.random.default_rng(11)
    us = rng.integers(0, V, 8).astype(np.int32)
    vs = (us + rng.integers(1, V - 1, 8).astype(np.int32)) % V
    oracle = EpochOracle(graph)

    futs0 = st.submit_batch(us, vs)          # size trigger: dispatches now
    assert st.n_inflight > 0                 # window still holds chunks
    es = edge_set(graph)
    ev = {"deletes": [tuple(int(x) for x in es[3])]}
    new = st.submit_update(**ev, churn_threshold=0.5)
    oracle.advance(new.graph, **ev)
    assert st.index.epoch == 1 and st.stats["updates"] == 1
    futs1 = st.submit_batch(us, vs)          # must NOT join the old flight
    st.drain()
    assert not st._flight and not st._waiting
    assert {f.epoch for f in futs0} == {0}
    assert {f.epoch for f in futs1} == {1}
    for f in futs0 + futs1:
        oracle.assert_future(f)
    st.close()


def test_stale_cache_entry_never_served_across_epochs(graph, index):
    """A pair whose cached SPG an update invalidates: the resident
    epoch-0 entry stays resident but unreachable, the post-update query
    misses and recomputes the new answer."""
    rng = np.random.default_rng(13)
    st = StreamingService(index, clock=ManualClock(), cache_size=64,
                          policy=AdmissionPolicy(adaptive=False, chunk=64))
    # pick a pair at distance >= 2 and delete an edge on its SPG
    u = v = None
    for _ in range(50):
        a, b = (int(x) for x in rng.integers(0, V, 2))
        d, eids = oracle_spg(graph, a, b)
        if 2 <= d < (1 << 20):
            u, v = a, b
            cut = (int(np.asarray(graph.src)[eids[0]]),
                   int(np.asarray(graph.dst)[eids[0]]))
            break
    assert u is not None
    st.submit(u, v)
    st.drain()
    key = (min(u, v), max(u, v))
    assert (key[0], key[1], 0) in st.service.cache
    hits0 = st.stats["cache_hits"]
    st.submit(u, v)                          # same epoch: pure cache hit
    assert st.stats["cache_hits"] == hits0 + 1

    new = st.submit_update(deletes=[cut])
    fut = st.submit(u, v)
    st.drain()
    assert st.stats["cache_hits"] == hits0 + 1   # stale entry not consulted
    assert (key[0], key[1], 0) in st.service.cache   # resident, unreachable
    d1, e1 = oracle_spg(new.graph, u, v)
    assert fut.epoch == 1 and fut.result().dist == d1
    assert np.array_equal(np.asarray(fut.result().edge_ids), e1)
    st.close()


def test_install_index_guards(index):
    svc = index.make_service()
    with pytest.raises(ValueError, match="not ahead"):
        svc.install_index(index)             # same epoch: stale install
    nxt = index.apply_update(inserts=[(0, 37)])
    svc.install_index(nxt)
    assert svc.index is nxt and svc.stats["installs"] == 1
    with pytest.raises(ValueError, match="not ahead"):
        svc.install_index(nxt)

    class FakeSharded:
        is_sharded = True
        epoch = 99

    with pytest.raises(ValueError, match="sharded"):
        svc.install_index(FakeSharded())


def test_sharded_index_reports_epoch_zero():
    from repro.core.sharded import ShardedIndex
    assert ShardedIndex.epoch == 0
