"""Per-architecture smoke tests on reduced configs (CPU, 1 device).

For every assigned arch: instantiate a tiny same-family config, run one
forward + loss + grad step, assert output shapes and finiteness.  For
decoder archs additionally check that prefill+decode agrees with the
full-sequence forward on the next-token logits (the serving-path
correctness invariant).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import SHAPES, build_model, cell_applicable, input_specs

REDUCED = {name: cfg.reduced() for name, cfg in ARCHS.items()}


def _batch_for(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio_frames":
        return {
            "features": jnp.asarray(rng.normal(size=(b, s, cfg.frontend_dim)), jnp.float32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            "loss_mask": jnp.asarray(rng.random((b, s)) < 0.3),
        }
    if cfg.frontend == "vision_patches":
        return {
            "patches": jnp.asarray(rng.normal(size=(b, 8, cfg.frontend_dim)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        }
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_grad_step(name):
    cfg = REDUCED[name]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    logits, aux = jax.jit(model.forward)(params, batch=batch)
    b, s = 2, 32
    expect_s = s + (8 if cfg.frontend == "vision_patches" else 0)
    assert logits.shape == (b, expect_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name

    def loss_of(p):
        l, m = model.loss(p, batch=batch)
        return l

    loss, grads = jax.jit(jax.value_and_grad(loss_of))(params)
    assert np.isfinite(float(loss)), name
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat), name
    # one SGD step must change the loss (the graph is actually connected)
    params2 = jax.tree_util.tree_map(lambda p, g: p - 0.3 * g.astype(p.dtype), params, grads)
    loss2 = jax.jit(loss_of)(params2)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("name", sorted(n for n, c in REDUCED.items() if not c.encoder_only))
def test_prefill_decode_matches_forward(name):
    """logits(prefill(x[:t]) -> decode(x[t])) == logits(forward(x[:t+1]))."""
    cfg = REDUCED[name]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 16
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32)

    if cfg.frontend == "vision_patches":
        patches = jnp.asarray(rng.normal(size=(b, 8, cfg.frontend_dim)), jnp.float32)
        batch_pre = {"patches": patches, "tokens": jnp.asarray(toks[:, :s])}
        batch_full = {"patches": patches, "tokens": jnp.asarray(toks)}
        prefix = 8
    else:
        batch_pre = {"tokens": jnp.asarray(toks[:, :s])}
        batch_full = {"tokens": jnp.asarray(toks)}
        prefix = 0

    # full forward logits at the position predicting token s+1
    full_logits, _ = jax.jit(model.forward)(params, batch=batch_full)
    want = np.asarray(full_logits[:, prefix + s - 1 + 1, :])  # position of token s (0-based)

    pre_logits, cache = jax.jit(model.prefill)(params, batch=batch_pre)
    # prefill last-position logits == forward at position prefix+s-1
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]), np.asarray(full_logits[:, prefix + s - 1]),
        rtol=2e-2, atol=2e-2,
    )

    if cfg.family in ("ssm", "hybrid"):
        cache_len = jnp.int32(s)
        if cfg.family == "hybrid":
            # hybrid prefill produced per-group attn caches of length prefix+s;
            # pad them into the fixed decode cache layout
            dec_cache = model.init_decode_cache(b, s + 8)
            dec_cache = _splice_hybrid_cache(dec_cache, cache, prefix + s)
            cache_len = jnp.int32(prefix + s)
        else:
            dec_cache = cache
        logits, _ = jax.jit(model.decode)(
            params, cache=dec_cache, cache_len=cache_len,
            tokens=jnp.asarray(toks[:, s:s + 1]))
    else:
        dec_cache = model.init_decode_cache(b, s + 8 + prefix)
        dec_cache = _splice_dense_cache(dec_cache, cache, prefix + s)
        logits, _ = jax.jit(model.decode)(
            params, cache=dec_cache, cache_len=jnp.int32(prefix + s),
            tokens=jnp.asarray(toks[:, s:s + 1]))

    got = np.asarray(logits[:, 0, :])
    np.testing.assert_allclose(got, want, rtol=6e-2, atol=6e-2)


def _splice_dense_cache(dec_cache, pre_cache, n):
    k_pre, v_pre = pre_cache["layers"]
    k_buf, v_buf = dec_cache["layers"]
    k_buf = k_buf.at[:, :, :n].set(k_pre.astype(k_buf.dtype))
    v_buf = v_buf.at[:, :, :n].set(v_pre.astype(v_buf.dtype))
    return {"layers": (k_buf, v_buf)}


def _splice_hybrid_cache(dec_cache, pre_cache, n):
    msts, (k_pre, v_pre) = pre_cache["mamba"], pre_cache["attn"]
    k_buf, v_buf = dec_cache["attn"]
    k_buf = k_buf.at[:, :, :n].set(k_pre.astype(k_buf.dtype))
    v_buf = v_buf.at[:, :, :n].set(v_pre.astype(v_buf.dtype))
    out = dict(dec_cache)
    out["attn"] = (k_buf, v_buf)
    out["mamba"] = msts
    return out


def test_moe_routing_conservation():
    """Gate weights of kept tokens sum to ~1; dropped fraction is tiny."""
    cfg = REDUCED["dbrx-132b"]
    from repro.models.moe import init_moe, moe

    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model), jnp.bfloat16)
    out, aux = jax.jit(lambda p, x: moe(p, x, cfg))(p, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out, np.float32)).all()
    assert float(aux) > 0


def test_mamba2_chunked_equals_decode_chain():
    """Chunked SSD == step-by-step recurrence on the same inputs."""
    cfg = REDUCED["zamba2-2.7b"]
    from repro.models.mamba2 import init_mamba2, mamba2_chunked, mamba2_decode

    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
    full, state = mamba2_chunked(p, x, cfg, chunk=8, return_state=True)

    from repro.models.mamba2 import ssm_dims
    d_inner, heads, hd = ssm_dims(cfg)
    st = {"ssm": jnp.zeros((b, heads, hd, cfg.ssm_state), jnp.float32),
          "conv": jnp.zeros((b, cfg.ssm_conv - 1, d_inner), x.dtype)}
    outs = []
    for t in range(s):
        o, st = mamba2_decode(p, x[:, t:t + 1], cfg, st)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(seq, np.float32), np.asarray(full, np.float32), rtol=8e-2, atol=8e-2)
    np.testing.assert_allclose(
        np.asarray(st["ssm"]), np.asarray(state["ssm"]), rtol=8e-2, atol=8e-2)


def test_rwkv6_chunked_equals_decode_chain():
    cfg = REDUCED["rwkv6-1.6b"]
    from repro.models.rwkv6 import init_rwkv6, rwkv6_time_mix, rwkv6_time_mix_decode

    p = init_rwkv6(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
    full, state = rwkv6_time_mix(p, x, cfg, chunk=8, return_state=True)

    from repro.models.rwkv6 import rwkv_dims
    heads, hd = rwkv_dims(cfg)
    st = {"wkv": jnp.zeros((b, heads, hd, hd), jnp.float32),
          "shift": jnp.zeros((b, 1, cfg.d_model), x.dtype)}
    outs = []
    for t in range(s):
        o, st = rwkv6_time_mix_decode(p, x[:, t:t + 1], cfg, st)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(seq, np.float32), np.asarray(full, np.float32), rtol=8e-2, atol=8e-2)
    np.testing.assert_allclose(
        np.asarray(st["wkv"]), np.asarray(state["wkv"]), rtol=8e-2, atol=8e-2)


def test_shape_cell_applicability_rules():
    grid = {(a, s): cell_applicable(ARCHS[a], SHAPES[s])[0]
            for a in ARCHS for s in SHAPES}
    assert grid[("hubert-xlarge", "decode_32k")] is False
    assert grid[("hubert-xlarge", "long_500k")] is False
    assert grid[("qwen1.5-32b", "long_500k")] is False
    assert grid[("zamba2-2.7b", "long_500k")] is True
    assert grid[("rwkv6-1.6b", "long_500k")] is True
    assert sum(grid.values()) == 31  # 40 nominal - 9 skips


def test_input_specs_shapes():
    cfg = get_config("qwen1.5-4b")
    spec = input_specs(cfg, SHAPES["train_4k"])
    assert spec["batch"]["tokens"].shape == (256, 4096)
    spec = input_specs(cfg, SHAPES["decode_32k"])
    assert spec["tokens"].shape == (128, 1)
    k, v = spec["cache"]["layers"]
    assert k.shape == (cfg.n_layers, 128, 32768, cfg.n_kv_heads, cfg.hd)
