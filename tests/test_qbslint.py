"""Fixture-based tests for tools/qbslint: every rule fires on its seeded
violation fixture, stays quiet on clean/suppressed code, and the CLI exit
codes match (0 = clean, 1 = findings)."""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.qbslint import ALL_RULES, lint_paths, lint_source  # noqa: E402

FIXTURES = REPO / "tests" / "fixtures" / "qbslint"


def _lint(path):
    findings, errors = lint_paths([path])
    assert not errors, errors
    return findings


def _rules(findings):
    return sorted({f.rule for f in findings})


def _cli(*argv, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.qbslint", *argv],
        cwd=cwd,
        capture_output=True,
        text=True,
    )


# ---------------------------------------------------------------- per-rule


def test_qbs001_catches_every_shard_map_route():
    findings = _lint(FIXTURES / "qbs001_bad.py")
    assert _rules(findings) == ["QBS001"]
    assert len(findings) == 6


def test_qbs002_serving_scope_and_clock_exemption():
    findings = _lint(FIXTURES / "qbs002")
    assert _rules(findings) == ["QBS002"]
    assert len(findings) == 7
    by_file: dict = {}
    for f in findings:
        by_file.setdefault(f.path.rsplit("/", 1)[-1], []).append(f)
    assert set(by_file) == {"bad_wallclock.py", "bad_metrics.py"}
    assert len(by_file["bad_wallclock.py"]) == 5
    assert len(by_file["bad_metrics.py"]) == 2


def test_qbs003_host_sync_in_jit_bodies():
    findings = _lint(FIXTURES / "qbs003_bad.py")
    assert _rules(findings) == ["QBS003"]
    assert len(findings) == 6


def test_qbs004_jit_in_loop_and_per_call_body():
    findings = _lint(FIXTURES / "qbs004_bad.py")
    assert _rules(findings) == ["QBS004"]
    assert sorted(f.line for f in findings) == [8, 14]


def test_qbs005_unlocked_guarded_field_mutations():
    findings = _lint(FIXTURES / "qbs005_bad.py")
    assert _rules(findings) == ["QBS005"]
    assert sorted(f.line for f in findings) == [21, 22, 23, 24]


def test_qbs006_cache_insert_bypass():
    findings = _lint(FIXTURES / "qbs006_bad.py")
    assert _rules(findings) == ["QBS006"]
    assert sorted(f.line for f in findings) == [12, 13, 17]


def test_qbs007_host_widening_of_packed_tables():
    findings = _lint(FIXTURES / "qbs007_bad.py")
    assert _rules(findings) == ["QBS007"]
    assert sorted(f.line for f in findings) == [8, 9, 10, 11]


def test_qbs007_serving_int64_scope_and_suppression():
    findings = _lint(FIXTURES / "qbs007")
    assert _rules(findings) == ["QBS007"]
    assert sorted(f.line for f in findings) == [6, 10]
    assert all(f.path.endswith("bad_int64.py") for f in findings)


def test_qbs008_host_gather_of_sharded_tables():
    findings = _lint(FIXTURES / "qbs008")
    assert _rules(findings) == ["QBS008"]
    by_file = sorted((f.path.rsplit("/", 1)[-1], f.line) for f in findings)
    assert by_file == [("bad_gather.py", 7), ("bad_gather.py", 8),
                       ("bad_gather.py", 9), ("sharded.py", 6)]


def test_qbs008_host_boundary_marker_exempts_def():
    src = (
        "import numpy as np\n"
        "\n"
        "\n"
        "def save_shards(labels_sh):  # qbslint: host-boundary\n"
        "    return np.asarray(labels_sh)\n"
    )
    assert lint_source("serving/ckpt.py", src) == []
    # the same def without the marker fires
    assert _rules(lint_source("serving/ckpt.py",
                              src.replace("  # qbslint: host-boundary",
                                          ""))) == ["QBS008"]


def test_qbs009_table_mutation_outside_epoch_entry_points():
    findings = _lint(FIXTURES / "qbs009")
    assert _rules(findings) == ["QBS009"]
    # every finding sits in the bad fixture; the clean counterpart's
    # entry-point writes (__init__/apply_update/install_index/build*) and
    # its reasoned suppression stay silent
    assert all(f.path.endswith("bad_mutation.py") for f in findings)
    assert sorted(f.line for f in findings) == [10, 13, 14, 15, 16, 20]


def test_qbs009_subscript_into_unversioned_state_is_fine():
    src = (
        "class S:\n"
        "    def bump(self):\n"
        "        self.stats['updates'] = 1\n"
        "        self.flags.index = 3\n"
    )
    # writing *into* a non-table dict is fine; rebinding a '.index'
    # attribute is not, whatever the receiver
    assert _rules(lint_source("s.py", src)) == ["QBS009"]
    assert [f.line for f in lint_source("s.py", src)] == [4]


def test_qbs007_jit_bodies_are_exempt():
    src = (
        "import jax\n"
        "import jax.numpy as jnp\n"
        "\n"
        "\n"
        "@jax.jit\n"
        "def widen(label_dist, rows):\n"
        "    return label_dist[rows].astype(jnp.int32)\n"
    )
    assert lint_source("widen.py", src) == []


# ------------------------------------------------------------- negatives


def test_clean_fixture_has_no_findings():
    assert _lint(FIXTURES / "clean.py") == []


def test_suppressions_silence_findings():
    assert _lint(FIXTURES / "suppressed.py") == []


def test_line_suppression_is_rule_specific():
    src = "import jax\n\n\ndef caller(fn, x):\n    return jax.jit(fn)(x)  # qbslint: disable=QBS001\n"
    findings = lint_source("caller.py", src)
    assert _rules(findings) == ["QBS004"]


def test_bare_disable_silences_all_rules_on_line():
    src = "import jax\n\n\ndef caller(fn, x):\n    return jax.jit(fn)(x)  # qbslint: disable\n"
    assert lint_source("caller.py", src) == []


def test_repo_src_tree_is_clean():
    findings, errors = lint_paths([REPO / "src"])
    assert not errors, errors
    assert findings == [], "\n".join(f.render() for f in findings)


# ------------------------------------------------------------------- CLI


@pytest.mark.parametrize(
    "fixture",
    [
        "qbs001_bad.py",
        "qbs002",
        "qbs003_bad.py",
        "qbs004_bad.py",
        "qbs005_bad.py",
        "qbs006_bad.py",
        "qbs007_bad.py",
        "qbs007",
        "qbs008",
        "qbs009",
    ],
)
def test_cli_nonzero_on_each_seeded_violation(fixture):
    proc = _cli(str(FIXTURES / fixture))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "qbslint:" in proc.stdout


def test_cli_zero_on_repo_src():
    proc = _cli("src")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_rule_filter_and_json_output():
    proc = _cli(str(FIXTURES / "qbs005_bad.py"), "--rules", "QBS006", "--format", "json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["findings"] == []

    proc = _cli(str(FIXTURES / "qbs005_bad.py"), "--rules", "QBS005", "--format", "json")
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert {f["rule"] for f in payload["findings"]} == {"QBS005"}


def test_cli_list_rules_names_all_nine():
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    for rule in ALL_RULES:
        assert rule.id in proc.stdout
    assert len(ALL_RULES) == 9
