"""Deadline- and QoS-aware streaming scheduler (DESIGN.md §8): wall-clock
admission deadlines through the injectable clock (no sleeps anywhere),
deficit-weighted class shares under flood, cache admission, and every
deadline edge case the ISSUE pins (max_wait=0, in-flight duplicate join,
empty-backlog timer wakeup, shard rounding on a 1-device mesh)."""
import numpy as np
import pytest

from helpers.serving_oracle import assert_bit_identical, oracle_spg

from repro.core import QbSIndex, gnp_random_graph
from repro.serving import (
    AdmissionPolicy,
    ManualClock,
    QoSClass,
    ServingService,
    StreamingService,
)

WIDE = AdmissionPolicy(adaptive=False, chunk=64)   # never size-triggers


@pytest.fixture(scope="module")
def graph():
    return gnp_random_graph(40, 3.0, seed=23)


@pytest.fixture(scope="module")
def index(graph):
    return QbSIndex.build(graph, n_landmarks=4, chunk=8)


def _stream(index, **kw):
    kw.setdefault("clock", ManualClock())
    return StreamingService(index, **kw)


def _non(index, k):
    return int(np.flatnonzero(~index._is_landmark_np)[k])


# -- the regression the deadline timer exists for ----------------------------


def test_lone_backlog_query_bounded_by_deadline(index):
    """A query sitting alone in the backlog must not wait forever when no
    further traffic arrives: the deadline timer admits *and resolves* it
    with zero driver calls after submit."""
    clk = ManualClock()
    st = _stream(index, clock=clk, policy=WIDE,
                 qos=(QoSClass("interactive", max_wait=0.05, weight=1.0),))
    fut = st.submit(_non(index, 0), _non(index, 1), qos="interactive")
    assert not fut.done() and st.n_pending == 1
    clk.advance(0.04)                       # before the deadline: still queued
    assert not fut.done() and st.n_pending == 1
    clk.advance(0.02)                       # past it: timer fires, no drain
    assert fut.done() and st.n_pending == 0 and st.n_inflight == 0
    d, eids = oracle_spg(index.graph, fut.u, fut.v)
    assert fut.result().dist == d
    assert np.array_equal(fut.result().edge_ids, eids)
    assert st.stats["deadline_flushes"] == 1
    w = st.qos_stats["interactive"]["waits"]
    assert len(w) == 1 and abs(w[0] - 0.05) < 1e-9   # admitted *at* the bound


def test_max_wait_zero_flushes_at_submit(index):
    """max_wait=0: the deadline is already due at submit, so the pair
    dispatches and resolves inline — no clock movement needed."""
    st = _stream(index, policy=WIDE,
                 qos=(QoSClass("now", max_wait=0.0),))
    fut = st.submit(_non(index, 2), _non(index, 3), qos="now")
    assert fut.done() and st.n_pending == 0 and st.n_inflight == 0
    d, _ = oracle_spg(index.graph, fut.u, fut.v)
    assert fut.result().dist == d


def test_deadline_fires_on_inflight_duplicate_join(index):
    """A tighter-deadline duplicate joining a pair already *in flight*
    (admitted, un-synced in the async window) arms the timer; the fire
    syncs the window so the joined future resolves within its bound."""
    clk = ManualClock()
    st = _stream(index, clock=clk,
                 policy=AdmissionPolicy(adaptive=False, chunk=2, min_chunk=2),
                 async_depth=4,
                 qos=(QoSClass("batch"),
                      QoSClass("interactive", max_wait=0.05, weight=4.0)))
    u, v = _non(index, 4), _non(index, 5)
    a = st.submit(u, v, qos="batch")
    b = st.submit(_non(index, 6), _non(index, 7), qos="batch")
    # size trigger fired (chunk=2) but async_depth=4 keeps both un-synced
    assert st.n_pending == 0 and st.n_inflight > 0
    assert not a.done()
    dup = st.submit(v, u, qos="interactive")     # joins the in-flight pair
    assert st.stats["joined"] == 1 and not dup.done()
    clk.advance(0.06)                            # deadline: sync, no drain
    assert dup.done() and a.done() and b.done()
    assert dup.result().dist == a.result().dist
    assert dup.result().edge_ids is a.result().edge_ids


def test_empty_backlog_timer_wakeup_is_noop(index):
    """Timer wakeups racing a drain (or plain polls on an idle service)
    must be no-ops: the deadline state is already clean."""
    clk = ManualClock()
    st = _stream(index, clock=clk, policy=WIDE,
                 qos=(QoSClass("interactive", max_wait=0.05),))
    st.poll()                                    # idle poll: nothing due
    fut = st.submit(_non(index, 0), _non(index, 2), qos="interactive")
    st.drain()                                   # resolves before the deadline
    assert fut.done()
    clk.advance(1.0)                             # stale wakeup window passes
    st.poll()
    assert st.n_pending == 0 and st.n_inflight == 0
    assert st.stats["deadline_flushes"] == 0
    # waits were recorded at the (early) drain, not the deadline
    w = st.qos_stats["interactive"]["waits"]
    assert len(w) == 1 and w[0] < 0.05


def test_deadline_with_shard_rounding_one_device_mesh(graph):
    """Deadline flushes through a sharded (1-device mesh) service: the
    round's width re-rounds to the shard multiple and the timer-admitted
    answers stay bit-identical to the oracle."""
    idx = QbSIndex.build(graph, n_landmarks=4, chunk=8)
    clk = ManualClock()
    st = StreamingService(idx, devices=1, clock=clk,
                          policy=AdmissionPolicy(min_chunk=2, max_chunk=16),
                          qos=(QoSClass("interactive", max_wait=0.01),))
    non = np.flatnonzero(~idx._is_landmark_np)
    us = non[:3].astype(np.int32)
    vs = non[3:6].astype(np.int32)
    futs = st.submit_batch(us, vs, qos="interactive")
    assert st.n_pending == 3                     # below every size trigger
    clk.advance(0.02)
    assert all(f.done() for f in futs)
    assert_bit_identical(idx.graph, [f.result() for f in futs], us, vs)


def test_system_clock_timer_admits_lone_query(index):
    """Smoke the production clock path once: a real threading.Timer fires
    the deadline admission with zero driver calls (the only wall-clock
    wait in the scheduler suite, bounded at a 10ms deadline)."""
    import time

    st = StreamingService(index, policy=WIDE,
                          qos=(QoSClass("interactive", max_wait=0.01),))
    fut = st.submit(_non(index, 0), _non(index, 1), qos="interactive")
    deadline = time.monotonic() + 10.0
    while not fut.done() and time.monotonic() < deadline:
        time.sleep(0.005)
    assert fut.done(), "SystemClock deadline timer never admitted the query"
    d, _ = oracle_spg(index.graph, fut.u, fut.v)
    assert fut.result().dist == d


# -- deficit-weighted fairness ----------------------------------------------


def test_weighted_shares_under_flood(index):
    """A round both classes are slot-limited in splits its chunk by
    weight (3:1 here), so the flooding tenant cannot starve the
    interactive one — and the flood still gets its own share."""
    st = _stream(index, policy=AdmissionPolicy(adaptive=False, chunk=8),
                 qos=(QoSClass("interactive", max_wait=1.0, weight=3.0),
                      QoSClass("bulk", max_wait=None, weight=1.0)))
    # interactive banks just under the trigger (7 < 8), then a deep bulk
    # burst crosses it: the flush's first round is oversubscribed on both
    # sides, so its slot split is pure weights.  All canonical pairs are
    # distinct by construction so no join blurs the admitted counts.
    iu = np.arange(7, dtype=np.int32)
    iv = iu + 20
    bu = np.concatenate([np.arange(20), np.arange(20)]).astype(np.int32)
    bv = np.concatenate([np.arange(20) + 19, np.arange(20) + 18]).astype(np.int32)
    assert len({(min(u, v), max(u, v))
                for u, v in zip(np.r_[iu, bu], np.r_[iv, bv])}) == 47
    st.submit_batch(iu, iv, qos="interactive")
    assert st.n_pending == 7                   # below the size trigger
    st.submit_batch(bu, bv, qos="bulk")        # crossing flushes everything
    assert st.n_pending == 0                   # work-conserving
    contended = [r for r in st.admission_log
                 if all(r["backlog"].get(c, 0) > 0   # post-round leftovers
                        for c in ("interactive", "bulk"))]
    assert contended, "flood never produced a slot-contended round"
    for r in contended:
        # 3:1 over 8 slots -> 6/2, give or take the deficit carry
        assert r["per_class"].get("interactive", 0) >= 4
        assert r["per_class"].get("bulk", 0) >= 1
    st.drain()
    assert st.qos_stats["bulk"]["admitted"] == 40
    assert st.qos_stats["interactive"]["admitted"] == 7
    assert st.n_pending == 0 and st.n_inflight == 0


def test_single_class_defaults_match_legacy_admission(index):
    """No qos= config: one default class, no deadlines, FIFO slots — the
    scheduler degenerates to the pre-QoS admission layer."""
    st = _stream(index, policy=AdmissionPolicy(adaptive=False, chunk=4))
    assert st.qos_classes == (QoSClass("default"),)
    non = np.flatnonzero(~index._is_landmark_np)
    futs = st.submit_batch(non[:4], non[1:5])
    assert all(f.done() or st.n_inflight for f in futs)
    st.drain()
    assert_bit_identical(index.graph, [f.result() for f in futs],
                         non[:4], non[1:5])
    assert st.stats["deadline_flushes"] == 0
    assert st.qos_stats["default"]["admitted"] == st.stats["admitted_pairs"]


def test_planner_class_tags_propagate(index):
    """QoS class tags ride the plan: plan_from_pairs keeps them verbatim,
    merge_plans dedups with first-appearance-wins (the class that got a
    pair admitted keeps it), untagged plans contribute class 0."""
    from repro.serving import merge_plans, plan_from_pairs, plan_queries

    is_l = index._is_landmark_np
    non = np.flatnonzero(~is_l)
    cu = non[:3].astype(np.int32)
    cv = non[3:6].astype(np.int32)
    plan = plan_from_pairs(np.minimum(cu, cv), np.maximum(cu, cv), is_l,
                           cls=[1, 0, 2])
    assert np.array_equal(plan.cls, [1, 0, 2])
    assert plan_from_pairs(cu[:1], cv[:1], is_l).cls is None
    other = plan_from_pairs(np.minimum(cu[:1], cv[:1]),
                            np.maximum(cu[:1], cv[:1]), is_l, cls=[2])
    merged = merge_plans([plan, other], is_l)     # pair 0 deduped across
    assert merged.n_unique == 3
    assert np.array_equal(merged.cls, [1, 0, 2])  # first appearance won
    untagged = plan_queries(cu[:1], cv[:1], is_l)
    merged = merge_plans([untagged, plan], is_l)
    assert merged.n_unique == 3               # pair 0 deduped again
    assert np.array_equal(merged.cls, [0, 0, 2])  # untagged first: class 0


def test_qos_validation(index):
    with pytest.raises(ValueError):
        QoSClass("bad", weight=0.0)
    with pytest.raises(ValueError):
        QoSClass("bad", max_wait=-1.0)
    with pytest.raises(ValueError):
        _stream(index, qos=(QoSClass("a"), QoSClass("a")))
    st = _stream(index)
    with pytest.raises(ValueError, match="unknown qos class"):
        st.submit(1, 2, qos="nope")


# -- cache admission ---------------------------------------------------------


def test_cache_admission_reuse_skips_one_shot_pairs(index):
    """cache_admission="reuse": a computed cold pair is not inserted on
    first sighting (predicted one-shot), is on its second; hub/landmark
    pairs insert immediately (Graph.hub_mask skew)."""
    lms = np.asarray(index.scheme.landmarks)
    non = np.flatnonzero(~index._is_landmark_np)
    st = _stream(index, cache_size=16, cache_admission="reuse")
    cache = st.service.cache
    cold = (int(non[0]), int(non[1]))
    # resident cache keys carry the serving epoch (0: no updates here)
    st.submit(*cold)
    st.drain()
    assert (*cold, 0) not in cache              # first sighting: refused
    st.submit(*cold)
    st.drain()
    assert (*cold, 0) in cache                  # second compute: admitted
    before = st.stats["cache_hits"]
    st.submit(*cold)
    st.drain()
    assert st.stats["cache_hits"] == before + 1
    hot = (int(lms[0]), int(non[2]))            # landmark endpoint
    st.submit(*hot)
    st.drain()
    assert (min(hot), max(hot), 0) in cache     # hub skew: admitted at once

    with pytest.raises(ValueError):
        ServingService(index, cache_size=4, cache_admission="nope")


def test_cache_admission_all_is_seed_behavior(index):
    non = np.flatnonzero(~index._is_landmark_np)
    st = _stream(index, cache_size=16)          # default cache_admission
    cold = (int(non[3]), int(non[4]))
    st.submit(*cold)
    st.drain()
    assert (*cold, 0) in st.service.cache
