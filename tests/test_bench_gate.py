"""CI benchmark-regression gate (``scripts/bench_gate.py``): compare
logic on synthetic trajectories — a >threshold regression on any tracked
metric fails, noise inside the threshold and improvements pass, missing
counterparts skip with a note, and only the latest record per (bench,
scale) is gated."""
import importlib.util
import json
from pathlib import Path

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_gate", Path(__file__).resolve().parent.parent
    / "scripts" / "bench_gate.py")
bench_gate = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_gate)

_RSPEC = importlib.util.spec_from_file_location(
    "bench_run", Path(__file__).resolve().parent.parent
    / "benchmarks" / "run.py")
bench_run = importlib.util.module_from_spec(_RSPEC)
_RSPEC.loader.exec_module(bench_run)


def _write(path: Path, records: list[dict]) -> Path:
    with path.open("w") as f:
        for rec in records:
            f.write(json.dumps(rec) + "\n")
    return path


def _rec(qps: float, us: float, *, bench="b", scale=0.25, ts=1.0,
         extra_rows=()) -> dict:
    return {"bench": bench, "ts": ts, "scale": scale, "rows": [
        {"mix": "uniform", "service": "sync", "qps": qps,
         "us_per_query": us, "speedup_vs_sync": 1.0},
        *extra_rows,
    ]}


def test_pass_on_identical_and_improved(tmp_path):
    base = _write(tmp_path / "base.json", [_rec(100.0, 50.0)])
    same = bench_gate.load_latest(base)
    regs, notes, _ = bench_gate.compare(same, same, 0.25)
    assert regs == [] and notes == []
    cur = bench_gate.load_latest(
        _write(tmp_path / "cur.json", [_rec(180.0, 20.0)]))  # improvement
    regs, _, _ = bench_gate.compare(same, cur, 0.25)
    assert regs == []


def test_fails_on_qps_regression_beyond_threshold(tmp_path):
    base = bench_gate.load_latest(
        _write(tmp_path / "base.json", [_rec(100.0, 50.0)]))
    cur = bench_gate.load_latest(
        _write(tmp_path / "cur.json", [_rec(70.0, 50.0)]))   # -30% qps
    regs, _, _ = bench_gate.compare(base, cur, 0.25)
    assert len(regs) == 1
    assert regs[0]["metric"] == "qps"
    assert regs[0]["ratio"] == pytest.approx(0.7)
    # 10% drop is inside the threshold
    ok = bench_gate.load_latest(
        _write(tmp_path / "ok.json", [_rec(90.0, 50.0)]))
    assert bench_gate.compare(base, ok, 0.25)[0] == []


def test_fails_on_latency_regression(tmp_path):
    base = bench_gate.load_latest(
        _write(tmp_path / "base.json", [_rec(100.0, 50.0)]))
    cur = bench_gate.load_latest(
        _write(tmp_path / "cur.json", [_rec(100.0, 80.0)]))  # +60% latency
    regs, _, _ = bench_gate.compare(base, cur, 0.25)
    assert [r["metric"] for r in regs] == ["us_per_query"]


def test_row_matching_is_structural_not_positional(tmp_path):
    extra = {"mix": "skewed", "service": "cached", "qps": 500.0,
             "us_per_query": 2000.0}
    base = bench_gate.load_latest(_write(
        tmp_path / "base.json", [_rec(100.0, 50.0, extra_rows=[extra])]))
    # current has the rows reordered and the derived float changed; only
    # the skewed row regressed
    cur_rec = _rec(100.0, 50.0)
    cur_rec["rows"] = [dict(extra, qps=100.0),
                       dict(cur_rec["rows"][0], speedup_vs_sync=9.9)]
    cur = bench_gate.load_latest(_write(tmp_path / "cur.json", [cur_rec]))
    regs, _, _ = bench_gate.compare(base, cur, 0.25)
    assert len(regs) == 1
    assert regs[0]["row"]["mix"] == "skewed"
    assert regs[0]["metric"] == "qps"


def test_missing_counterparts_skip_with_note(tmp_path):
    base = bench_gate.load_latest(_write(tmp_path / "base.json", [
        _rec(100.0, 50.0),
        _rec(100.0, 50.0, bench="nightly_only", scale=1.0),
    ]))
    cur = bench_gate.load_latest(
        _write(tmp_path / "cur.json", [_rec(100.0, 50.0)]))
    regs, notes, _ = bench_gate.compare(base, cur, 0.25)
    assert regs == []
    assert any("nightly_only" in n for n in notes)


def test_structurally_unmatched_rows_retire_not_fail(tmp_path):
    """A baseline row whose key changed shape across PRs (renamed field,
    different identifying value) is *retired*: reported in the third
    return, never a regression — while surviving rows still gate."""
    old_shape = {"mix": "skewed", "service": "cached", "qps": 500.0,
                 "us_per_query": 2000.0}
    base = bench_gate.load_latest(_write(
        tmp_path / "base.json", [_rec(100.0, 50.0, extra_rows=[old_shape])]))
    # current renamed the row's identifying field AND regressed the real row
    cur_rec = _rec(40.0, 50.0)
    cur_rec["rows"].append({"mix": "skewed-v2", "service": "cached",
                            "qps": 500.0, "us_per_query": 2000.0})
    cur = bench_gate.load_latest(_write(tmp_path / "cur.json", [cur_rec]))
    regs, notes, retired = bench_gate.compare(base, cur, 0.25)
    assert [r["row"].get("mix") for r in regs] == ["uniform"]   # real red
    assert [r["row"].get("mix") for r in retired] == ["skewed"]
    assert not any("skewed" in n for n in notes)   # retired, not noted
    # CLI: retired rows alone never fail the gate
    base_p = _write(tmp_path / "b2.json",
                    [_rec(100.0, 50.0, extra_rows=[old_shape])])
    cur_p = _write(tmp_path / "c2.json", [_rec(100.0, 50.0)])
    assert bench_gate.main(["--baseline", str(base_p),
                            "--current", str(cur_p)]) == 0


def _speedup_rec(speedup: float, *, ts=1.0) -> dict:
    return {"bench": "graph_updates", "ts": ts, "scale": 0.25, "rows": [
        {"graph": "ba-hub", "V": 12000, "R": 64, "op": "speedup",
         "update_speedup": speedup, "affected_med": 6.0},
    ]}


def test_update_speedup_rows_gate_on_absolute_floor(tmp_path):
    base = bench_gate.load_latest(
        _write(tmp_path / "base.json", [_speedup_rec(6.5)]))
    # halving that stays above the floor passes — the rule is absolute
    ok = bench_gate.load_latest(
        _write(tmp_path / "ok.json", [_speedup_rec(5.2)]))
    regs, _, _ = bench_gate.compare(base, ok, 0.25, update_speedup_floor=5.0)
    assert regs == []
    # dropping below the floor fails regardless of the baseline value
    bad = bench_gate.load_latest(
        _write(tmp_path / "bad.json", [_speedup_rec(3.8)]))
    regs, _, _ = bench_gate.compare(base, bad, 0.25, update_speedup_floor=5.0)
    assert [r["metric"] for r in regs] == ["update_speedup"]
    assert regs[0]["current"] == pytest.approx(3.8)
    assert regs[0]["baseline"] == pytest.approx(5.0)
    # update_speedup/affected_med are floats (out of the row key) and the
    # row carries no tracked metric: only the floor rule can fire on it
    cur_rec = _speedup_rec(6.5)
    cur_rec["rows"][0]["affected_med"] = 999.0
    cur = bench_gate.load_latest(_write(tmp_path / "cur.json", [cur_rec]))
    regs, _, _ = bench_gate.compare(base, cur, 0.25, update_speedup_floor=5.0)
    assert regs == []


def test_noise_floor_skips_microsecond_rows(tmp_path):
    """A sub-min_us baseline row (a cache-hit hot loop) is skipped even
    when its qps cratered; a real row in the same record still gates."""
    hot = {"mix": "skewed", "service": "cached", "qps": 125000.0,
           "us_per_query": 8.0}
    base = bench_gate.load_latest(_write(
        tmp_path / "base.json", [_rec(100.0, 50.0, extra_rows=[hot])]))
    cur_rec = _rec(40.0, 50.0)                       # real row regressed
    cur_rec["rows"].append(dict(hot, qps=60000.0))   # hot row halved too
    cur = bench_gate.load_latest(_write(tmp_path / "cur.json", [cur_rec]))
    regs, notes, _ = bench_gate.compare(base, cur, 0.25, min_us=50.0)
    assert [r["row"].get("mix") for r in regs] == ["uniform"]
    assert any("noise floor" in n for n in notes)


def test_scale_filter_excludes_other_scales(tmp_path):
    """--scale restricts gating to that scale's records, so committed
    full-scale (nightly/dev) rows can never produce a false red in CI."""
    path = _write(tmp_path / "t.json", [
        _rec(100.0, 50.0, scale=0.25),
        _rec(10.0, 5000.0, scale=1.0),      # dev full-scale record
    ])
    assert set(bench_gate.load_latest(path)) == {("b", 0.25), ("b", 1.0)}
    only = bench_gate.load_latest(path, scale=0.25)
    assert set(only) == {("b", 0.25)}
    # the regressed 1.0 record is invisible at --scale 0.25
    cur = bench_gate.load_latest(
        _write(tmp_path / "cur.json", [_rec(100.0, 50.0, scale=0.25),
                                       _rec(1.0, 50000.0, scale=1.0)]),
        scale=0.25)
    assert bench_gate.compare(only, cur, 0.25)[0] == []


def test_latest_record_wins(tmp_path):
    # the older (bad) record is superseded by a newer healthy one
    cur = bench_gate.load_latest(_write(
        tmp_path / "cur.json", [_rec(10.0, 500.0, ts=1.0),
                                _rec(100.0, 50.0, ts=2.0)]))
    base = bench_gate.load_latest(
        _write(tmp_path / "base.json", [_rec(100.0, 50.0)]))
    assert bench_gate.compare(base, cur, 0.25)[0] == []


def _roofline_rec(frac: float, *, ts=1.0) -> dict:
    return {"bench": "roofline", "ts": ts, "scale": 0.25, "rows": [
        {"kernel": "bitmap_expand", "shape": "64x512",
         "roofline_frac": frac, "wall_us": 900.0, "ideal_us": 600.0},
    ]}


def test_roofline_rows_gate_on_absolute_floor_not_relative(tmp_path):
    base = bench_gate.load_latest(
        _write(tmp_path / "base.json", [_roofline_rec(0.60)]))
    # a 10x relative drop that stays above the floor passes — the rule is
    # absolute, unlike the qps percentage rule
    ok = bench_gate.load_latest(
        _write(tmp_path / "ok.json", [_roofline_rec(0.06)]))
    regs, _, _ = bench_gate.compare(base, ok, 0.25, frac_floor=0.01)
    assert regs == []
    # a collapse below the floor fails regardless of the baseline value
    bad = bench_gate.load_latest(
        _write(tmp_path / "bad.json", [_roofline_rec(0.004)]))
    regs, _, _ = bench_gate.compare(base, bad, 0.25, frac_floor=0.01)
    assert [r["metric"] for r in regs] == ["roofline_frac"]
    assert regs[0]["current"] == pytest.approx(0.004)


def test_roofline_rows_never_hit_tracked_metric_rule(tmp_path):
    # wall_us/ideal_us are floats (out of the row key) and the row carries
    # no tracked metric, so only the floor rule can ever fire on it
    base = bench_gate.load_latest(
        _write(tmp_path / "base.json", [_roofline_rec(0.5)]))
    cur_rec = _roofline_rec(0.5)
    cur_rec["rows"][0]["wall_us"] = 90000.0     # 100x slower wall clock
    cur = bench_gate.load_latest(_write(tmp_path / "cur.json", [cur_rec]))
    regs, _, _ = bench_gate.compare(base, cur, 0.25, frac_floor=0.01)
    assert regs == []


def _shard_rec(frac: float, *, ts=1.0) -> dict:
    return {"bench": "sharded_memory", "ts": ts, "scale": 0.25, "rows": [
        {"graph": "ba-hub", "n_shards": 8, "per_device_frac": frac,
         "per_device_bytes": 1.0e6, "replicated_bytes": 5.0e6},
    ]}


def test_sharded_rows_gate_on_absolute_ceiling(tmp_path):
    base = bench_gate.load_latest(
        _write(tmp_path / "base.json", [_shard_rec(0.19)]))
    # growing within the ceiling passes (the rule is absolute, not
    # relative to the baseline value)
    ok = bench_gate.load_latest(
        _write(tmp_path / "ok.json", [_shard_rec(0.24)]))
    regs, _, _ = bench_gate.compare(base, ok, 0.25, shard_frac_ceiling=0.25)
    assert regs == []
    # climbing above the ceiling fails: sharding stopped scaling linearly
    bad = bench_gate.load_latest(
        _write(tmp_path / "bad.json", [_shard_rec(0.31)]))
    regs, _, _ = bench_gate.compare(base, bad, 0.25, shard_frac_ceiling=0.25)
    assert [r["metric"] for r in regs] == ["per_device_frac"]
    assert regs[0]["current"] == pytest.approx(0.31)
    # byte columns are floats (out of the key) and untracked: the ceiling
    # rule is the only one that can fire on a sharded-memory row
    cur_rec = _shard_rec(0.19)
    cur_rec["rows"][0]["per_device_bytes"] = 9.9e9
    cur = bench_gate.load_latest(_write(tmp_path / "cur.json", [cur_rec]))
    regs, _, _ = bench_gate.compare(base, cur, 0.25, shard_frac_ceiling=0.25)
    assert regs == []


def test_prune_bench_keeps_last_n_per_key(tmp_path):
    path = _write(tmp_path / "b.json", [
        _rec(1.0, 1.0, ts=1.0), _rec(2.0, 2.0, ts=2.0),
        _rec(3.0, 3.0, ts=3.0),
        _rec(9.0, 9.0, bench="other", ts=1.0),
    ])
    assert bench_run.prune_bench(path, 2) == 1
    recs = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [r["ts"] for r in recs] == [2.0, 3.0, 1.0]
    # the gate's view (latest record per key) is unchanged by pruning
    assert bench_gate.load_latest(path)[("b", 0.25)]["ts"] == 3.0
    assert bench_run.prune_bench(path, 2) == 0   # idempotent


def _p99_rec(p99_int: float, p99_bulk: float, *, ts=1.0) -> dict:
    rows = []
    for qos, p99 in (("interactive", p99_int), ("bulk", p99_bulk)):
        rows.append({"trace": "hub-steady", "n_replicas": 4, "qos": qos,
                     "p50_us": p99 / 2, "p99_us": p99, "n_obs": 100})
    return {"bench": "trace_replay", "ts": ts, "scale": 0.25, "rows": rows}


def test_p99_rows_gate_on_absolute_per_class_ceiling(tmp_path):
    ceilings = {"*": 200_000.0, "interactive": 2048.0, "bulk": 65536.0}
    base = bench_gate.load_latest(
        _write(tmp_path / "base.json", [_p99_rec(1024.0, 32768.0)]))
    # a 2x relative climb that stays at the ceiling passes — the rule is
    # absolute (deterministic simulated time has no noise to tolerate)
    ok = bench_gate.load_latest(
        _write(tmp_path / "ok.json", [_p99_rec(2048.0, 65536.0)]))
    regs, _, _ = bench_gate.compare(base, ok, 0.25, p99_ceiling_us=ceilings)
    assert regs == []
    # one bucket above its class ceiling fails, naming the class's row
    bad = bench_gate.load_latest(
        _write(tmp_path / "bad.json", [_p99_rec(4096.0, 65536.0)]))
    regs, _, _ = bench_gate.compare(base, bad, 0.25, p99_ceiling_us=ceilings)
    assert [r["metric"] for r in regs] == ["p99_us"]
    assert regs[0]["row"]["qos"] == "interactive"
    assert regs[0]["baseline"] == pytest.approx(2048.0)
    assert regs[0]["current"] == pytest.approx(4096.0)
    # an unknown class falls back to the generous * ceiling
    odd = _p99_rec(1024.0, 32768.0)
    odd["rows"][0]["qos"] = "background"
    odd["rows"][0]["p99_us"] = 150_000.0
    base2 = bench_gate.load_latest(_write(tmp_path / "b2.json", [odd]))
    regs, _, _ = bench_gate.compare(base2, base2, 0.25, p99_ceiling_us=ceilings)
    assert regs == []


def test_p50_rides_along_untracked(tmp_path):
    # p50_us is a float (out of the row key) and carries no rule: only
    # the p99 ceiling can fire on a trace-replay row
    base = bench_gate.load_latest(
        _write(tmp_path / "base.json", [_p99_rec(1024.0, 32768.0)]))
    cur_rec = _p99_rec(1024.0, 32768.0)
    cur_rec["rows"][0]["p50_us"] = 1e9           # absurd, but untracked
    cur = bench_gate.load_latest(_write(tmp_path / "cur.json", [cur_rec]))
    regs, _, _ = bench_gate.compare(
        base, cur, 0.25,
        p99_ceiling_us={"*": 200_000.0, "interactive": 2048.0})
    assert regs == []


def test_parse_p99_spec():
    d = bench_gate.parse_p99_spec(None)
    assert d == {"*": bench_gate.P99_DEFAULT_CEILING_US}
    assert bench_gate.parse_p99_spec("5000") == {"*": 5000.0}
    assert bench_gate.parse_p99_spec("interactive=2048,bulk=65536") == {
        "*": bench_gate.P99_DEFAULT_CEILING_US,
        "interactive": 2048.0, "bulk": 65536.0}
    assert bench_gate.parse_p99_spec("1000, interactive=2048") == {
        "*": 1000.0, "interactive": 2048.0}


def test_synthetic_p99_regression_fails_main(tmp_path):
    """End-to-end: a synthetic p99 regression trips the CLI gate with the
    CI ceilings, and the emitted failure set names trace_replay."""
    base = _write(tmp_path / "base.json", [_p99_rec(2048.0, 65536.0)])
    bad = _write(tmp_path / "bad.json", [_p99_rec(8192.0, 65536.0)])
    out = tmp_path / "failing.txt"
    rc = bench_gate.main([
        "--baseline", str(base), "--current", str(bad),
        "--p99-ceiling-us", "interactive=2048,bulk=65536",
        "--emit-failures", str(out)])
    assert rc == 1
    assert out.read_text() == "trace_replay"
    rc = bench_gate.main([
        "--baseline", str(base), "--current", str(base),
        "--p99-ceiling-us", "interactive=2048,bulk=65536",
        "--emit-failures", str(out)])
    assert rc == 0
    assert out.read_text() == ""                 # pass empties the set


def test_only_restricts_gating_to_named_benches(tmp_path):
    recs = [_rec(100.0, 50.0, bench="a"), _rec(100.0, 50.0, bench="c")]
    base = _write(tmp_path / "base.json", recs)
    cur = _write(tmp_path / "cur.json", [
        _rec(10.0, 50.0, bench="a"),             # regressed
        _rec(100.0, 50.0, bench="c"),
    ])
    argv = ["--baseline", str(base), "--current", str(cur)]
    assert bench_gate.main(argv) == 1
    # the retry path: --only on the healthy bench ignores the failing one
    assert bench_gate.main(argv + ["--only", "c"]) == 0
    assert bench_gate.main(argv + ["--only", "a,c"]) == 1


def test_emit_failures_joins_failing_bench_set(tmp_path):
    base = _write(tmp_path / "base.json", [
        _rec(100.0, 50.0, bench="a"), _rec(100.0, 50.0, bench="b"),
        _rec(100.0, 50.0, bench="c")])
    cur = _write(tmp_path / "cur.json", [
        _rec(10.0, 50.0, bench="a"), _rec(100.0, 50.0, bench="b"),
        _rec(10.0, 50.0, bench="c")])
    out = tmp_path / "failing.txt"
    rc = bench_gate.main(["--baseline", str(base), "--current", str(cur),
                          "--emit-failures", str(out)])
    assert rc == 1
    assert out.read_text() == "a,c"              # sorted, deduped, joined


def test_main_exit_codes_and_refresh(tmp_path):
    base = _write(tmp_path / "base.json", [_rec(100.0, 50.0)])
    good = _write(tmp_path / "good.json", [_rec(100.0, 50.0)])
    bad = _write(tmp_path / "bad.json", [_rec(10.0, 50.0)])
    argv = ["--baseline", str(base)]
    assert bench_gate.main(argv + ["--current", str(good)]) == 0
    assert bench_gate.main(argv + ["--current", str(bad)]) == 1
    # --refresh rewrites the baseline from the current file, then passes
    assert bench_gate.main(argv + ["--current", str(bad), "--refresh"]) == 0
    assert bench_gate.main(argv + ["--current", str(bad)]) == 0
    # no baseline at all: gate is a no-op pass
    assert bench_gate.main(["--baseline", str(tmp_path / "none.json"),
                            "--current", str(good)]) == 0
