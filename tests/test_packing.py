"""Packed-layout invariants (core.packing, DESIGN.md §10): exact
pack/widen round-trips, the uint8 -> uint16 escape hatch and sentinel
boundary, bit-packed reachability words + the packed Pallas expand
kernel, packed-index bit-identity against the numpy oracle across
frontier backends, and the packed ResultCache encodings with byte-based
capacity accounting."""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from helpers.serving_oracle import assert_bit_identical

from repro.core import (
    INF,
    QbSIndex,
    build_labelling,
    gnp_random_graph,
    grid_graph,
    pack_bits,
    unpack_bits,
    widen_dist,
)
from repro.core.packing import (
    choose_pack_dtype,
    pack_dist,
    pack_labelling,
    packed_size_bytes,
    sentinel_of,
)
from repro.core.sketch import compute_sketch_batch
from repro.kernels.frontier import bitmap_expand, bitmap_expand_packed
from repro.kernels.minplus import minplus
from repro.serving.service import ResultCache, _pack_result, _unpack_result


# ------------------------------------------------------ pack/widen dtypes


def test_pack_widen_round_trip_is_exact():
    rng = np.random.default_rng(0)
    for dtype, hi in ((np.uint8, 254), (np.uint16, 65534)):
        a = rng.integers(0, hi + 1, size=(40, 7)).astype(np.int32)
        a[rng.random((40, 7)) < 0.3] = INF
        a[0, 0] = hi          # pin the dtype boundary into the sample
        a[0, 1] = INF
        packed = pack_dist(a, dtype)
        assert packed.dtype == dtype
        assert np.array_equal(np.asarray(widen_dist(packed)), a)


def test_choose_pack_dtype_escape_hatch_at_sentinel():
    a = np.array([[0, 254, INF]], np.int32)
    b = np.array([[0, 255, INF]], np.int32)
    assert choose_pack_dtype(a) == np.uint8          # 254 < sentinel 255
    assert choose_pack_dtype(b) == np.uint16         # 255 collides -> promote
    assert choose_pack_dtype(a, b) == np.uint16      # max across all tables
    assert choose_pack_dtype(a, None, b) == np.uint16  # optional tables skip
    with pytest.raises(ValueError, match="sentinel"):
        choose_pack_dtype(np.array([sentinel_of(np.uint16)], np.int32))


def test_pack_dist_refuses_sentinel_collision():
    with pytest.raises(ValueError, match="sentinel"):
        pack_dist(np.array([255], np.int32), np.uint8)


def test_widen_dist_signed_passthrough():
    a = jnp.asarray(np.array([0, 3, INF], np.int32))
    out = widen_dist(a)
    assert out.dtype == jnp.int32
    assert np.array_equal(np.asarray(out), [0, 3, INF])


def test_minplus_rejects_packed_unsigned_inputs():
    a = jnp.zeros((4, 4), jnp.uint8)
    with pytest.raises(ValueError, match="widen"):
        minplus(a, a)


# ------------------------------------------------- escape hatch end-to-end


def test_high_diameter_path_promotes_to_uint16_and_stays_exact():
    # path of 300 vertices, landmarks at the ends: label distances reach
    # 298 > 254, so the build must take the uint16 escape hatch
    g = grid_graph(1, 300)
    scheme = build_labelling(g, np.array([0, 299], np.int32), max_levels=400)
    packed = scheme.packed()
    assert packed.dtype == np.uint16
    assert packed.sentinel == sentinel_of(np.uint16)
    assert np.array_equal(np.asarray(widen_dist(packed.label_dist)),
                          np.asarray(scheme.label_dist))

    idx = QbSIndex(g, scheme, chunk=8)
    assert idx.packed.dtype == np.uint16
    us = np.array([0, 10, 150, 299, 42], np.int32)
    vs = np.array([299, 290, 150, 0, 257], np.int32)
    assert_bit_identical(g, idx.query_batch(us, vs), us, vs)


def test_low_diameter_graph_packs_uint8():
    g = gnp_random_graph(80, 3.0, seed=5)
    idx = QbSIndex.build(g, n_landmarks=8, chunk=8)
    s = packed_size_bytes(idx.packed)
    assert s["dtype"] == "uint8"
    assert s["ratio"] == 4.0                 # the acceptance floor is 3.5x
    assert s["int32_bytes"] == idx.packed.nbytes * 4


# ----------------------------------------------- packed pipeline identity


@pytest.mark.parametrize("backend", ["segment", "csr", "hybrid"])
def test_packed_index_bit_identical_to_oracle(backend):
    g = gnp_random_graph(60, 3.0, seed=3)
    idx = QbSIndex.build(g, n_landmarks=6, chunk=8, backend=backend)
    assert idx.packed.dtype == np.uint8
    assert idx.ctx.label_dist.dtype == idx.packed.dtype  # one HBM copy
    rng = np.random.default_rng(1)
    us = rng.integers(0, g.n_vertices, 25).astype(np.int32)
    vs = rng.integers(0, g.n_vertices, 25).astype(np.int32)
    assert_bit_identical(g, idx.query_batch(us, vs), us, vs)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_packed_sketch_matches_unpacked(use_pallas):
    g = gnp_random_graph(50, 3.2, seed=7)
    idx = QbSIndex.build(g, n_landmarks=6, chunk=8)
    scheme, packed = idx.scheme, idx.packed
    rng = np.random.default_rng(2)
    us = jnp.asarray(rng.integers(0, g.n_vertices, 16), jnp.int32)
    vs = jnp.asarray(rng.integers(0, g.n_vertices, 16), jnp.int32)
    ref = compute_sketch_batch(
        scheme.label_dist[us], scheme.label_dist[vs],
        scheme.meta_w, scheme.meta_dist, use_pallas=use_pallas)
    got = compute_sketch_batch(
        packed.label_dist[us], packed.label_dist[vs],
        packed.meta_w, packed.meta_dist, use_pallas=use_pallas)
    for r, g_ in zip(ref, got):
        assert np.array_equal(np.asarray(r), np.asarray(g_))


# ----------------------------------------------------- bit-packed words


def test_pack_bits_round_trip_ragged_widths():
    rng = np.random.default_rng(4)
    for n in (1, 31, 32, 33, 100, 256):
        x = rng.random((5, n)) < 0.4
        words = pack_bits(jnp.asarray(x))
        assert words.shape == (5, -(-n // 32))
        assert words.dtype == jnp.uint32
        assert np.array_equal(np.asarray(unpack_bits(words, n)), x)


def test_bitmap_expand_packed_matches_dense():
    rng = np.random.default_rng(6)
    f = rng.random((17, 70)) < 0.3
    adj = rng.random((70, 90)) < 0.1
    dense = bitmap_expand(jnp.asarray(f), jnp.asarray(adj))
    packed = bitmap_expand_packed(
        jnp.asarray(f), pack_bits(jnp.asarray(adj)), n_cols=90)
    assert np.array_equal(np.asarray(dense), np.asarray(packed))


# ----------------------------------------------------- packed ResultCache


def test_pack_result_delta_and_raw_round_trip():
    # sorted flatnonzero-style ids with small gaps -> delta encoding
    eids = np.array([5, 6, 10, 60000], np.int32)  # max gap 59990 < 2^16
    entry = _pack_result((7, eids))
    assert entry[2][0] == "delta"
    assert entry[0] == 3 * 2 + 6             # 3 uint16 gaps + anchor + dist
    d, out = _unpack_result(entry)
    assert d == 7 and out.dtype == np.int32
    assert np.array_equal(out, eids)
    assert not out.flags.writeable

    # a gap >= 2^16 cannot delta-encode
    wide = np.array([0, 1 << 17], np.int32)
    entry = _pack_result((3, wide))
    assert entry[2][0] == "raw"
    assert entry[0] == wide.nbytes + 2
    d, out = _unpack_result(entry)
    assert d == 3 and np.array_equal(out, wide)

    # empty edge lists (trivial/disconnected lanes) stay raw and tiny
    empty = np.zeros((0,), np.int32)
    entry = _pack_result((0, empty))
    assert entry[2][0] == "raw" and entry[0] == 2
    assert _unpack_result(entry)[1].size == 0


def test_result_cache_byte_accounting_and_byte_eviction():
    def val(n):
        return (n, np.arange(n, dtype=np.int32))   # delta: (n-1)*2 + 6 bytes

    c = ResultCache(100, capacity_bytes=40)
    c.put((0, 0), val(8))                    # 20 bytes
    assert c.bytes == 20
    c.put((1, 1), val(8))                    # 40 bytes total
    assert c.bytes == 40 and len(c) == 2
    c.put((2, 2), val(8))                    # 60 > 40 -> evict LRU (0, 0)
    assert c.bytes == 40 and len(c) == 2
    assert (0, 0) not in c and c.get((0, 0)) is None
    # re-put replaces the resident bytes, never double-counts
    c.put((1, 1), val(2))                    # 20 + 8
    assert c.bytes == 28 and len(c) == 2
    got = c.get((1, 1))
    assert got[0] == 2 and np.array_equal(got[1], [0, 1])
