"""Labelling-scheme invariants (Definitions 4.1/4.2, Lemma 5.2)."""
import numpy as np
import pytest

from repro.core import (
    INF,
    build_labelling,
    gnp_random_graph,
    labelling_size_bytes,
    meta_apsp,
    select_landmarks,
    to_networkx,
)
from repro.core.baselines import bfs_distances


@pytest.fixture(scope="module")
def setup():
    g = gnp_random_graph(40, 3.0, seed=17)
    landmarks = select_landmarks(g, 5)
    scheme = build_labelling(g, landmarks)
    return g, landmarks, scheme


def test_label_distances_are_exact(setup):
    """Every label entry (r, delta) must satisfy delta == d_G(v, r)."""
    g, landmarks, scheme = setup
    ld = np.asarray(scheme.label_dist)
    for i, r in enumerate(landmarks):
        true = bfs_distances(g, int(r))
        valid = ld[:, i] < INF
        assert (ld[valid, i] == true[valid]).all()


def test_label_iff_landmark_free_path(setup):
    """Definition 4.2: (r, d) in L(u) iff some shortest u-r path has no other
    landmark in its interior.  Checked against networkx all_shortest_paths."""
    import networkx as nx

    g, landmarks, scheme = setup
    nxg = to_networkx(g)
    lset = set(int(x) for x in landmarks)
    ld = np.asarray(scheme.label_dist)
    for u in range(g.n_vertices):
        if u in lset:
            assert (ld[u] >= INF).all()  # landmarks carry no labels
            continue
        for i, r in enumerate(landmarks):
            r = int(r)
            if not nx.has_path(nxg, u, r):
                assert ld[u, i] >= INF
                continue
            free = any(
                all(x not in lset for x in p[:-1] if x != u)
                for p in nx.all_shortest_paths(nxg, u, r)
            )
            assert (ld[u, i] < INF) == free, (u, r)


def test_meta_graph_definition(setup):
    """Definition 4.1: meta edge (r, r') iff some shortest path between them
    avoids all other landmarks; weight = d_G(r, r')."""
    import networkx as nx

    g, landmarks, scheme = setup
    nxg = to_networkx(g)
    lset = set(int(x) for x in landmarks)
    mw = np.asarray(scheme.meta_w)
    for i, r in enumerate(landmarks):
        for j, r2 in enumerate(landmarks):
            if i == j:
                continue
            r, r2 = int(r), int(r2)
            if not nx.has_path(nxg, r, r2):
                assert mw[i, j] >= INF
                continue
            free = any(
                all(x not in (lset - {r, r2}) for x in p)
                for p in nx.all_shortest_paths(nxg, r, r2)
            )
            if free:
                assert mw[i, j] == nx.shortest_path_length(nxg, r, r2)
            else:
                assert mw[i, j] >= INF


def test_meta_apsp_equals_true_distances(setup):
    """d_M(r,r') == d_G(r,r') (§4.1: the meta graph preserves distances)."""
    g, landmarks, scheme = setup
    md = np.asarray(scheme.meta_dist)
    for i, r in enumerate(landmarks):
        true = bfs_distances(g, int(r))
        for j, r2 in enumerate(landmarks):
            t = true[int(r2)]
            if t >= INF:
                assert md[i, j] >= INF
            else:
                assert md[i, j] == t


def test_determinism_wrt_landmark_order(setup):
    """Lemma 5.2: the scheme is deterministic w.r.t. the landmark *set* —
    permuting the order must permute, not change, the labelling."""
    g, landmarks, scheme = setup
    perm = np.array([3, 1, 4, 0, 2])
    scheme2 = build_labelling(g, np.asarray(landmarks)[perm])
    ld1 = np.asarray(scheme.label_dist)
    ld2 = np.asarray(scheme2.label_dist)
    assert (ld1[:, perm] == ld2).all()
    mw1 = np.asarray(scheme.meta_w)
    mw2 = np.asarray(scheme2.meta_w)
    assert (mw1[np.ix_(perm, perm)] == mw2).all()


def test_nearest_landmark_always_labelled(setup):
    """A vertex's nearest landmark can never be pruned (interior landmark
    would be strictly closer) — guarantees non-empty labels everywhere in a
    connected component containing a landmark."""
    g, landmarks, scheme = setup
    ld = np.asarray(scheme.label_dist)
    dists = np.stack([bfs_distances(g, int(r)) for r in landmarks], axis=1)
    lset = set(int(x) for x in landmarks)
    for u in range(g.n_vertices):
        if u in lset or dists[u].min() >= INF:
            continue
        nearest = np.flatnonzero(dists[u] == dists[u].min())
        assert (ld[u, nearest] < INF).all(), u


def test_size_accounting():
    g = gnp_random_graph(100, 4.0, seed=23)
    scheme = build_labelling(g, select_landmarks(g, 8))
    sz = labelling_size_bytes(scheme)
    assert sz["label_bytes"] == 100 * 8
    assert sz["n_meta_edges"] >= 0


def test_meta_apsp_disconnected():
    w = np.full((3, 3), INF, np.int64)
    w[0, 1] = w[1, 0] = 2
    d = np.asarray(meta_apsp(np.asarray(w, np.int32)))
    assert d[0, 1] == 2 and d[0, 2] >= INF and d[0, 0] == 0
