"""Distributed (shard_map) labelling + serving == single-device results.

The 1-device mesh runs in-process; the true multi-device check spawns a
subprocess with ``--xla_force_host_platform_device_count=8`` because the
device count is locked at first jax init.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import QbSIndex, build_labelling, gnp_random_graph, select_landmarks
from repro.core.baselines import bfs_spg
from repro.core.distributed import (
    distributed_build_labelling,
    make_serve_step,
    partition_edges,
)


def test_partition_edges_covers_all_edges():
    g = gnp_random_graph(50, 4.0, seed=3)
    part = partition_edges(g, 4)
    dst = np.asarray(g.dst)
    total = 0
    vend = np.concatenate([part.vstart[1:], [g.n_vertices]])
    for s in range(4):
        valid = part.dst_local[s] < part.v_loc
        total += int(valid.sum())
        d_glob = part.dst_local[s][valid] + part.vstart[s]
        assert (d_glob >= part.vstart[s]).all() and (d_glob < vend[s]).all()
    assert total == dst.shape[0]


def test_partition_balances_by_edges_not_vertices():
    # hub graph: vertex 0 has half of all edges
    from repro.core import barabasi_albert_graph

    g = barabasi_albert_graph(100, 3, seed=1)
    part = partition_edges(g, 8)
    counts = (part.dst_local < part.v_loc).sum(axis=1)
    # no shard should be pathologically overloaded vs the mean
    assert counts.max() <= max(4 * counts.mean(), counts.max() * 0 + g.degrees().max())


@pytest.mark.parametrize("mode", ["bool", "bitmap", "pull"])
def test_distributed_labelling_single_device_mesh(mode):
    g = gnp_random_graph(40, 3.0, seed=42)
    landmarks = select_landmarks(g, 4)
    ref = build_labelling(g, landmarks)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    got = distributed_build_labelling(g, landmarks, mesh, frontier_mode=mode)
    assert (np.asarray(got.label_dist) == np.asarray(ref.label_dist)).all()
    assert (np.asarray(got.meta_w) == np.asarray(ref.meta_w)).all()


def test_sharded_serving_single_device_mesh():
    g = gnp_random_graph(40, 3.0, seed=7)
    idx = QbSIndex.build(g, n_landmarks=4)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    serve = make_serve_step(idx.ctx, idx.scheme, mesh, n_vertices=g.n_vertices)
    rng = np.random.default_rng(0)
    nl = np.asarray(idx.scheme.is_landmark)
    cand = np.flatnonzero(~nl)
    us = rng.choice(cand, size=4).astype(np.int32)
    vs = rng.choice(cand, size=4).astype(np.int32)
    mask, dist = serve(us, vs)
    mask = np.asarray(mask)
    for k in range(4):
        o = bfs_spg(g, int(us[k]), int(vs[k]))
        m = mask[k] | mask[k][idx._rev_edge]
        pairs = {
            (int(min(a, b)), int(max(a, b)))
            for a, b in zip(np.asarray(g.src)[m], np.asarray(g.dst)[m])
        }
        assert int(dist[k]) == o.dist
        assert pairs == o.edge_pairs(g)


@pytest.mark.slow
def test_distributed_eight_devices_subprocess():
    """Full 8-device exactness check in a fresh process."""
    script = os.path.join(os.path.dirname(__file__), "helpers", "dist_check.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]
    )
    out = subprocess.run(
        [sys.executable, script], env=env, capture_output=True, text=True, timeout=900
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ALL-OK" in out.stdout
