"""Planner routing + service execution: every lane, every frontier
backend, bit-identical to the pure-numpy seed-semantics oracle
(``helpers.serving_oracle``); plus the service-level policy machinery
(result-cache tiers and eviction, chunk padding, shard rounding)."""
import warnings
from types import SimpleNamespace

import numpy as np
import pytest

from helpers.serving_oracle import assert_bit_identical, oracle_query_batch

from repro.core import QbSIndex, gnp_random_graph
from repro.serving import (
    LANE_GENERAL,
    LANE_LANDMARK_PAIR,
    LANE_ONE_SIDED,
    LANE_TRIVIAL,
    ResultCache,
    ServingService,
    plan_queries,
    round_chunk_to_shards,
)
from repro.serving.planner import chunk_padded, onesided_roots

BACKEND_OPTS = {
    "segment": {},
    "csr": {"engine_opts": {"block_size": 64}},
    "hybrid": {"engine_opts": {"n_hubs": 16}},
}


@pytest.fixture(scope="module")
def graph():
    return gnp_random_graph(45, 3.2, seed=17)


@pytest.fixture(scope="module", params=sorted(BACKEND_OPTS))
def index(request, graph):
    return QbSIndex.build(graph, n_landmarks=5, chunk=8,
                          backend=request.param,
                          **BACKEND_OPTS[request.param])


def _mixed_batch(idx, rng, n=24):
    """A batch that interleaves all four lanes, duplicates (same and
    swapped orientation), and repeats across chunk boundaries."""
    g = idx.graph
    lms = np.asarray(idx.scheme.landmarks)
    non = np.flatnonzero(~idx._is_landmark_np)
    us = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    vs = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
    us[0] = vs[0] = int(non[0])            # trivial, non-landmark
    us[1] = vs[1] = int(lms[0])            # trivial, landmark
    us[2], vs[2] = lms[0], lms[1]          # landmark-landmark
    us[3], vs[3] = lms[2], non[1]          # one-sided
    us[4], vs[4] = non[2], non[3]          # general
    us[5], vs[5] = us[4], vs[4]            # exact duplicate
    us[6], vs[6] = vs[4], us[4]            # swapped-orientation duplicate
    us[7], vs[7] = lms[1], lms[0]          # swapped landmark pair
    return us, vs


def test_lane_classification_and_dedup(index):
    idx = index
    rng = np.random.default_rng(0)
    us, vs = _mixed_batch(idx, rng)
    plan = plan_queries(us, vs, idx._is_landmark_np)
    assert plan.n == us.size
    # canonical: cu <= cv, every original query maps back to its pair
    assert (plan.cu <= plan.cv).all()
    assert np.array_equal(plan.cu[plan.inv], np.minimum(us, vs))
    assert np.array_equal(plan.cv[plan.inv], np.maximum(us, vs))
    # dedup folded at least the three forced duplicates (rows 5, 6 of 4;
    # row 7 of 2) — the random tail may collide further
    assert plan.n_unique <= plan.n - 3
    assert plan.inv[5] == plan.inv[4] and plan.inv[6] == plan.inv[4]
    assert plan.inv[7] == plan.inv[2]
    # lane assignment
    lane_of = {i: plan.lane[plan.inv[i]] for i in range(8)}
    assert lane_of[0] == LANE_TRIVIAL and lane_of[1] == LANE_TRIVIAL
    assert lane_of[2] == LANE_LANDMARK_PAIR
    assert lane_of[3] == LANE_ONE_SIDED
    assert lane_of[4] == LANE_GENERAL
    # lanes partition the unique rows
    assert sum(l.size for l in plan.lanes) == plan.n_unique


def test_mixed_batch_bit_identical_to_oracle(index):
    """All four lanes interleaved with duplicates, across several chunk
    boundaries, on every backend."""
    idx = index
    rng = np.random.default_rng(7)
    for trial in range(3):
        us, vs = _mixed_batch(idx, rng, n=21 + trial)
        assert_bit_identical(idx.graph, idx.query_batch(us, vs), us, vs)


def test_landmark_only_batches(index):
    """Batches touching only the landmark lanes (no general traffic)."""
    idx = index
    lms = np.asarray(idx.scheme.landmarks)
    non = np.flatnonzero(~idx._is_landmark_np)
    us = np.array([lms[0], lms[1], lms[2], lms[0], non[0], non[5]], np.int32)
    vs = np.array([lms[1], lms[2], lms[0], lms[0], lms[3], lms[4]], np.int32)
    assert_bit_identical(idx.graph, idx.query_batch(us, vs), us, vs)
    plan = plan_queries(us, vs, idx._is_landmark_np)
    assert plan.lanes[LANE_GENERAL].size == 0


def test_cache_hit_lanes_bit_identical(index):
    """A cached service must return bit-identical answers on re-query, and
    the second pass must be served entirely from the cache."""
    idx = index
    svc = ServingService(idx, cache_size=128)
    rng = np.random.default_rng(3)
    us, vs = _mixed_batch(idx, rng)
    first = svc.query_batch(us, vs)
    assert svc.cache.hits == 0
    second = svc.query_batch(np.flip(us), np.flip(vs))  # reordered re-query
    plan = plan_queries(us, vs, idx._is_landmark_np)
    n_device = plan.n_unique - plan.lanes[LANE_TRIVIAL].size
    assert svc.cache.hits == n_device  # every non-trivial unique pair hit
    assert_bit_identical(idx.graph, first, us, vs)
    assert_bit_identical(idx.graph, second, np.flip(us), np.flip(vs))


def test_async_depths_identical(index):
    """Sync (depth 1) and deeper double-buffering give identical results."""
    idx = index
    rng = np.random.default_rng(5)
    us, vs = _mixed_batch(idx, rng, n=30)
    ref = ServingService(idx, async_depth=1).query_batch(us, vs)
    for depth in (2, 4):
        got = ServingService(idx, async_depth=depth).query_batch(us, vs)
        for a, b in zip(ref, got):
            assert a.dist == b.dist and np.array_equal(a.edge_ids, b.edge_ids)


def test_arrays_path_matches_results(index):
    idx = index
    rng = np.random.default_rng(9)
    us, vs = _mixed_batch(idx, rng)
    dist, mask = ServingService(idx, cache_size=16).query_arrays(us, vs)
    for k, (d, eids) in enumerate(oracle_query_batch(idx.graph, us, vs)):
        assert int(dist[k]) == d
        assert np.array_equal(np.flatnonzero(mask[k]), eids)


def test_mesh_service_matches_default(graph):
    """The batch-sharded multi-device general lane (1-device mesh here) is
    bit-identical to the single-device service."""
    idx = QbSIndex.build(graph, n_landmarks=5, chunk=8)
    rng = np.random.default_rng(13)
    us, vs = _mixed_batch(idx, rng)
    d_ref, m_ref = idx.query_batch_arrays(us, vs)
    d_got, m_got = ServingService(idx, devices=1).query_arrays(us, vs)
    assert np.array_equal(d_ref, d_got)
    assert np.array_equal(m_ref, m_got)


def test_chunk_padded_shapes():
    idx = np.arange(11)
    chunks = list(chunk_padded(idx, 4))
    assert [(c.shape[0], live) for c, live in chunks] == [(4, 4), (4, 4), (4, 3)]
    assert np.array_equal(chunks[-1][0], [8, 9, 10, 10])  # tail repeats last
    assert list(chunk_padded(np.arange(0), 4)) == []


def test_chunk_padded_edge_cases():
    # exact multiple: last chunk is fully live, nothing padded
    chunks = list(chunk_padded(np.arange(8), 4))
    assert [live for _, live in chunks] == [4, 4]
    # chunk wider than the lane: one padded chunk, live == lane size
    (sel, live), = chunk_padded(np.arange(3), 8)
    assert sel.shape == (8,) and live == 3
    assert np.array_equal(sel, [0, 1, 2, 2, 2, 2, 2, 2])
    # single element through a wide chunk
    (sel, live), = chunk_padded(np.array([5]), 4)
    assert live == 1 and np.array_equal(sel, [5, 5, 5, 5])


def _v(i):
    # a real (dist, edge_ids) value: the cache packs values internally
    # (delta-uint16 edge gaps + byte accounting), so every assertion here
    # also exercises the pack/decode round-trip
    return (i, np.arange(i % 4, dtype=np.int32) * 3)


def _veq(got, i):
    want = _v(i)
    return (got is not None and got[0] == want[0]
            and np.array_equal(got[1], want[1]))


def test_result_cache_capacity_zero_and_one():
    c = ResultCache(0)
    c.put((1, 2), _v(1))
    assert len(c) == 0 and c.get((1, 2)) is None
    assert (c.hits, c.misses) == (0, 1)
    with pytest.raises(ValueError):
        ResultCache(-1)
    c = ResultCache(1)
    c.put((1, 2), _v(1))
    c.put((3, 4), _v(2))                    # evicts the only slot
    assert len(c) == 1
    assert c.get((1, 2)) is None and _veq(c.get((3, 4)), 2)


def test_result_cache_lru_eviction_order():
    c = ResultCache(2)
    c.put((0, 1), _v(1))
    c.put((0, 2), _v(2))
    assert _veq(c.get((0, 1)), 1)           # refresh (0, 1)'s recency
    c.put((0, 3), _v(3))                    # evicts (0, 2), the LRU entry
    assert c.get((0, 2)) is None
    assert _veq(c.get((0, 1)), 1) and _veq(c.get((0, 3)), 3)
    c.put((0, 1), _v(9))                    # re-put refreshes, no growth
    assert len(c) == 2 and _veq(c.get((0, 1)), 9)


def test_result_cache_protected_slots():
    def protect(key):
        return key[0] == 0                  # "hub" endpoint is vertex 0
    c = ResultCache(4, protect=protect, protected_frac=0.5)  # 2 protected
    c.put((0, 1), _v(1))                    # protected
    for i in range(2, 7):                   # cold flood: 5 unprotected
        c.put((1, i), _v(i))
    assert len(c) == 4
    assert _veq(c.get((0, 1)), 1)           # survived the flood
    assert c.get((1, 2)) is None            # cold LRU entries evicted
    # protected overflow demotes (LRU-first) into the unprotected tier
    c = ResultCache(4, protect=protect, protected_frac=0.5)
    for i in range(1, 4):
        c.put((0, i), _v(i))                # 3 protected > cap 2
    assert len(c) == 3
    assert _veq(c.get((0, 1)), 1)           # demoted, still resident
    c.put((1, 9), _v(9))
    c.put((1, 10), _v(10))                  # overflow evicts demoted (0, 1)
    assert c.get((0, 1)) is None
    assert _veq(c.get((0, 2)), 2) and _veq(c.get((0, 3)), 3)
    # fully-protected cache (frac=1.0) still bounds at capacity: overflow
    # demotes the protected LRU entry, which then evicts
    c = ResultCache(2, protect=lambda k: True, protected_frac=1.0)
    for i in range(1, 4):
        c.put((0, i), _v(i))
    assert len(c) == 2 and c.get((0, 1)) is None
    assert _veq(c.get((0, 2)), 2) and _veq(c.get((0, 3)), 3)


def test_round_chunk_to_shards():
    assert round_chunk_to_shards(32, 1) == 32
    assert round_chunk_to_shards(32, 4) == 32
    assert round_chunk_to_shards(10, 4) == 12
    assert round_chunk_to_shards(1, 8) == 8
    with pytest.raises(ValueError):
        round_chunk_to_shards(0, 4)


def test_service_rounds_chunk_to_shard_multiple(index, monkeypatch):
    """A chunk that doesn't divide over the mesh rounds up with a warning
    instead of raising (the seed behaviour)."""
    import repro.core.distributed as distributed
    monkeypatch.setattr(distributed, "make_serve_step",
                        lambda *a, **kw: None)
    mesh = SimpleNamespace(shape={"q": 4})
    with pytest.warns(UserWarning, match="rounding up to 12"):
        svc = ServingService(index, mesh=mesh, chunk=10)
    assert svc.chunk == 12
    with warnings.catch_warnings():        # exact multiple: no warning
        warnings.simplefilter("error")
        svc = ServingService(index, mesh=mesh, chunk=8)
    assert svc.chunk == 8


def test_admission_chunk_rounding_warns_once_and_counts(index, monkeypatch):
    """A misaligned *admitted* chunk override (the streaming layer's
    adaptive ladder) warns once per service instance and increments
    ``stats['chunk_roundings']`` on every rounding, so sustained
    misaligned traffic is visible in metrics without per-admission
    warning spam."""
    import repro.core.distributed as distributed
    monkeypatch.setattr(distributed, "make_serve_step",
                        lambda *a, **kw: None)
    mesh = SimpleNamespace(shape={"q": 4})
    with warnings.catch_warnings():        # aligned construction: silent
        warnings.simplefilter("error")
        svc = ServingService(index, mesh=mesh, chunk=8)
    assert svc.stats["chunk_roundings"] == 0
    non = np.flatnonzero(~index._is_landmark_np)
    plan = plan_queries(non[:3], non[3:6], index._is_landmark_np)
    with pytest.warns(UserWarning, match="chunk_roundings"):
        list(svc._chunks(plan, chunk=10))
    assert svc.stats["chunk_roundings"] == 1
    with warnings.catch_warnings():        # warned once; still counted
        warnings.simplefilter("error")
        list(svc._chunks(plan, chunk=6))
        list(svc._chunks(plan, chunk=8))   # aligned: not a rounding
    assert svc.stats["chunk_roundings"] == 2


def test_onesided_roots_split(index):
    idx = index
    lms = np.asarray(idx.scheme.landmarks)
    non = np.flatnonzero(~idx._is_landmark_np)
    cu = np.array([min(lms[0], non[0]), min(non[1], lms[2])], np.int32)
    cv = np.array([max(lms[0], non[0]), max(non[1], lms[2])], np.int32)
    roots, r_idx = onesided_roots(cu, cv, idx._is_landmark_np, idx._lid_np)
    assert np.array_equal(roots, [non[0], non[1]])
    assert np.array_equal(r_idx, [0, 2])


def test_empty_batch(index):
    assert index.query_batch([], []) == []
    dist, mask = index.query_batch_arrays([], [])
    assert dist.shape == (0,) and mask.shape[0] == 0


def test_d_top_reporting_convention(index):
    """Pins the documented d_top convention: general-lane results report
    the dist-derived d_top; planner-answered lanes (trivial — including
    non-landmark u == v, which the seed routed through the general
    pipeline with d_top 0 — and both landmark lanes) report INF, since no
    sketch ran for them."""
    idx = index
    lms = np.asarray(idx.scheme.landmarks)
    non = np.flatnonzero(~idx._is_landmark_np)
    us = np.array([non[0], lms[0], lms[0], lms[1], non[1]], np.int32)
    vs = np.array([non[0], lms[0], lms[1], non[2], non[3]], np.int32)
    res = idx.query_batch(us, vs)
    inf = 1 << 20
    for r in res[:4]:                       # trivial + landmark lanes
        assert r.d_top >= inf, (r.u, r.v)
    general = res[4]
    assert general.d_top == (general.dist if general.dist < inf else inf)
