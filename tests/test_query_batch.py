"""Serving-path edge cases for ``QbSIndex.query_batch`` and the jitted
pipeline: landmark-endpoint routing (the vectorized landmark lanes),
u == v trivial queries, ragged batches that exercise the fixed-shape
padding, and bit-identity against the seed-semantics oracle
(``helpers.serving_oracle`` — the fixture that replaced the retired
``query_batch_legacy`` loop)."""
import numpy as np
import pytest

import jax.numpy as jnp

from helpers.serving_oracle import assert_bit_identical

from repro.core import QbSIndex, gnp_random_graph, grid_graph
from repro.core.baselines import bfs_spg
from repro.serving import make_spg_serve_step, serve_spg_batch


@pytest.fixture(scope="module")
def setup():
    g = gnp_random_graph(45, 3.2, seed=17)
    idx = QbSIndex.build(g, n_landmarks=5, chunk=8)
    return g, idx


def _assert_matches_oracle(g, res):
    for r in res:
        o = bfs_spg(g, r.u, r.v)
        assert r.dist == o.dist, (r.u, r.v, r.dist, o.dist)
        assert r.edge_pairs(g) == o.edge_pairs(g), (r.u, r.v)


def test_landmark_endpoint_batch(setup):
    """Every query touches a landmark endpoint -> all answered from labels."""
    g, idx = setup
    lms = np.asarray(idx.scheme.landmarks)
    non = np.flatnonzero(~np.asarray(idx.scheme.is_landmark))
    us = np.array([lms[0], lms[1], non[0], lms[2], lms[0]], np.int32)
    vs = np.array([non[1], lms[2], lms[3], lms[4], lms[0]], np.int32)  # incl. lm-lm, lm==lm
    res = idx.query_batch(us, vs)
    _assert_matches_oracle(g, res)


def test_trivial_u_equals_v_batch(setup):
    g, idx = setup
    lms = np.asarray(idx.scheme.landmarks)
    non = np.flatnonzero(~np.asarray(idx.scheme.is_landmark))
    us = np.array([non[0], lms[0], non[3], non[3]], np.int32)
    vs = np.array([non[0], lms[0], non[3], non[4]], np.int32)
    res = idx.query_batch(us, vs)
    for r in res[:3]:
        assert r.dist == 0 and r.edge_ids.size == 0
    _assert_matches_oracle(g, res)


def test_ragged_batch_exercises_padding(setup):
    """Batch size not a multiple of chunk: the tail chunk is padded with a
    repeated query whose lanes must be discarded."""
    g, idx = setup
    assert idx.chunk == 8
    rng = np.random.default_rng(3)
    for n in (1, 7, 11, 19):  # 1 partial, partial, 1 full + partial, 2 + partial
        us = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
        vs = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
        res = idx.query_batch(us, vs)
        assert len(res) == n
        _assert_matches_oracle(g, res)


def test_empty_and_all_landmark_batches(setup):
    g, idx = setup
    assert idx.query_batch([], []) == []
    lms = np.asarray(idx.scheme.landmarks)
    res = idx.query_batch(lms[:3], lms[1:4])
    _assert_matches_oracle(g, res)


def test_bit_identical_to_seed_oracle(setup):
    """Acceptance: dist + edge-id arrays bit-identical to the pure-numpy
    seed-semantics oracle on randomized batches including landmark-endpoint
    and u==v queries (the fixture that replaced ``query_batch_legacy``)."""
    g, idx = setup
    rng = np.random.default_rng(11)
    lms = np.asarray(idx.scheme.landmarks)
    for trial in range(3):
        n = int(rng.integers(5, 30))
        us = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
        vs = rng.integers(0, g.n_vertices, size=n).astype(np.int32)
        # force the corner cases into every batch
        us[0] = vs[0] = int(rng.integers(0, g.n_vertices))      # u == v
        us[1] = int(lms[trial % lms.size])                       # landmark endpoint
        assert_bit_identical(g, idx.query_batch(us, vs), us, vs)


def test_query_batch_arrays_matches_results(setup):
    g, idx = setup
    rng = np.random.default_rng(23)
    us = rng.integers(0, g.n_vertices, size=13).astype(np.int32)
    vs = rng.integers(0, g.n_vertices, size=13).astype(np.int32)
    dist, mask = serve_spg_batch(idx, us, vs)
    res = idx.query_batch(us, vs)
    for k, r in enumerate(res):
        assert int(dist[k]) == r.dist
        assert np.array_equal(np.flatnonzero(mask[k]), r.edge_ids)


def test_spg_serve_step_matches_query_batch():
    """The raw jitted step == query_batch on non-landmark traffic, on a
    graph with many tied shortest paths (edge-mask stress)."""
    g = grid_graph(6, 6)
    idx = QbSIndex.build(g, n_landmarks=4, chunk=8)
    step = make_spg_serve_step(idx)
    rng = np.random.default_rng(5)
    cand = np.flatnonzero(~np.asarray(idx.scheme.is_landmark))
    us = rng.choice(cand, size=idx.chunk).astype(np.int32)
    vs = rng.choice(cand, size=idx.chunk).astype(np.int32)
    dist, mask = step(jnp.asarray(us), jnp.asarray(vs))
    dist, mask = np.asarray(dist), np.asarray(mask)
    res = idx.query_batch(us, vs)
    for k, r in enumerate(res):
        assert int(dist[k]) == r.dist
        assert np.array_equal(np.flatnonzero(mask[k]), r.edge_ids)
        o = bfs_spg(g, int(us[k]), int(vs[k]))
        assert r.edge_pairs(g) == o.edge_pairs(g)
