"""Replica serving tier (DESIGN.md §12): deterministic consistent-hash
placement, bit-identity of N replicas vs one service vs the numpy oracle,
cache partitioning (each hot key cached on exactly one replica), and the
drain/handoff protocol for rolling restarts — no future dropped or
double-resolved, per-replica accounting exact."""
import numpy as np
import pytest

from helpers.serving_oracle import OracleCache

from repro.core import QbSIndex, gnp_random_graph
from repro.serving import (
    AdmissionPolicy,
    ManualClock,
    QoSClass,
    ReplicaRouter,
    StreamingService,
)
from repro.serving.replicas import key_point, mix64
from repro.serving.stream import QueryFuture

WIDE = AdmissionPolicy(adaptive=False, chunk=64)   # never size-triggers
QOS = (QoSClass("interactive", max_wait=0.002, weight=4.0),
       QoSClass("bulk", max_wait=0.05, weight=1.0))


@pytest.fixture(scope="module")
def graph():
    return gnp_random_graph(40, 3.0, seed=23)


@pytest.fixture(scope="module")
def index(graph):
    return QbSIndex.build(graph, n_landmarks=4, chunk=8)


def _clocks(n):
    return [ManualClock() for _ in range(n)]


def _advance(clocks, dt):
    for c in clocks:
        c.advance(dt)


def _pairs(rng, n, k):
    us = rng.integers(0, n, size=k)
    vs = rng.integers(0, n, size=k)
    return us, vs


def _accounting(rep):
    s = rep.stats
    fresh = (s["submitted"] - s["trivial"] - s["cache_hits"] - s["joined"]
             - s["handed_off"])
    assert s["admitted_pairs"] == fresh, dict(s)


# ---------------------------------------------------------------- placement


def test_mix64_and_key_point_are_deterministic_and_orientation_free():
    assert mix64(0) == mix64(0)
    assert mix64(1) != mix64(2)
    assert key_point((3, 7)) == key_point((3, 7))
    # the router canonicalizes before hashing; key_point itself is raw
    assert key_point((3, 7)) != key_point((7, 3))


def test_owner_map_deterministic_across_instances(index):
    a = ReplicaRouter(index, n_replicas=4, clocks=_clocks(4), policy=WIDE)
    b = ReplicaRouter(index, n_replicas=4, clocks=_clocks(4), policy=WIDE)
    rng = np.random.default_rng(7)
    owners = set()
    try:
        for u, v in rng.integers(0, 40, size=(200, 2)):
            u, v = int(u), int(v)
            i = a.owner_of(u, v)
            assert i == b.owner_of(u, v)            # same ring, same owner
            assert i == a.owner_of(v, u)            # canonical (min, max)
            owners.add(i)
        assert owners == {0, 1, 2, 3}               # every replica owns keys
    finally:
        a.close()
        b.close()


def test_router_validates_construction(index):
    with pytest.raises(ValueError, match="n_replicas"):
        ReplicaRouter(index, n_replicas=0)
    with pytest.raises(ValueError, match="clocks"):
        ReplicaRouter(index, n_replicas=3, clocks=_clocks(2))


# ---------------------------------------------------------------- identity


def test_four_replicas_bit_identical_to_one_service(index):
    """Hub-skewed repeat-heavy trace through n=4 vs a single service on
    lockstep ManualClocks: identical (dist, edge_ids) per future, both
    matching the numpy oracle."""
    rng = np.random.default_rng(11)
    hot = [tuple(int(x) for x in p) for p in rng.integers(0, 40, size=(6, 2))]

    def trace(submit, advance):
        futs = []
        for step in range(8):
            if step % 2 == 0:       # hot repeats (cache hits + joins)
                pairs = [hot[int(rng_t.integers(len(hot)))]
                         for _ in range(5)]
            else:
                pairs = [tuple(int(x) for x in p)
                         for p in rng_t.integers(0, 40, size=(5, 2))]
            qos = "interactive" if step % 3 else "bulk"
            futs.extend(submit([p[0] for p in pairs],
                               [p[1] for p in pairs], qos))
            advance((0.0, 0.001, 0.003, 0.06)[step % 4])
        return futs

    rng_t = np.random.default_rng(13)
    clk1 = ManualClock()
    single = StreamingService(index, clock=clk1, policy=WIDE, qos=QOS,
                              cache_size=256, cache_policy="hub")
    futs1 = trace(lambda us, vs, q: single.submit_batch(us, vs, qos=q),
                  clk1.advance)
    single.drain()

    rng_t = np.random.default_rng(13)               # identical trace
    clks = _clocks(4)
    router = ReplicaRouter(index, n_replicas=4, clocks=clks, policy=WIDE,
                           qos=QOS, cache_size=256, cache_policy="hub")
    futs4 = trace(lambda us, vs, q: router.submit_batch(us, vs, qos=q),
                  lambda dt: _advance(clks, dt))
    router.drain()

    assert len(futs1) == len(futs4)
    oracle = OracleCache(index.graph)
    for f1, f4 in zip(futs1, futs4):
        r1, r4 = f1.result(), f4.result()
        assert (f1.u, f1.v) == (f4.u, f4.v)
        assert r1.dist == r4.dist
        assert np.array_equal(r1.edge_ids, r4.edge_ids)
        oracle.assert_result(r4)
    # every replica saw traffic on this trace
    assert all(rep.stats["submitted"] > 0 for rep in router.replicas)
    for rep in router.replicas:
        _accounting(rep)
    single.close()
    router.close()


def test_hot_keys_cache_on_exactly_one_replica(index):
    """The cache key is the routing key: a repeated pair caches on its
    owner only, so summed hot-key bytes across N replicas equal the
    single-service footprint instead of N copies."""
    rng = np.random.default_rng(3)
    hot = {(min(int(u), int(v)), max(int(u), int(v)))
           for u, v in rng.integers(0, 40, size=(12, 2)) if u != v}
    # resident cache keys carry the serving epoch (0: no updates here)
    hot_keys = [(u, v, 0) for u, v in hot]
    us = np.array([k[0] for k in hot], np.int32)
    vs = np.array([k[1] for k in hot], np.int32)

    single = StreamingService(index, clock=ManualClock(), policy=WIDE,
                              cache_size=256, cache_policy="hub")
    single.query_batch(us, vs)          # fills the cache
    single.query_batch(us, vs)          # pure cache hits
    single_bytes = single.service.cache.bytes_for(hot_keys)
    assert single_bytes > 0

    n = 4
    router = ReplicaRouter(index, n_replicas=n, clocks=_clocks(n),
                           policy=WIDE, cache_size=256, cache_policy="hub")
    router.query_batch(us, vs)
    router.query_batch(us, vs)
    for key in hot_keys:
        holders = [i for i, rep in enumerate(router.replicas)
                   if key in rep.service.cache]
        assert holders == [router.owner_of(key[0], key[1])]  # the owner only
    summed = sum(rep.service.cache.bytes_for(hot_keys)
                 for rep in router.replicas)
    assert summed == single_bytes                   # partitioned, not copied
    assert summed < n * single_bytes
    assert sum(rep.service.cache.hits for rep in router.replicas) \
        == single.service.cache.hits > 0
    single.close()
    router.close()


# ---------------------------------------------------------------- handoff


def test_drain_replica_hands_off_pending_without_loss(index, monkeypatch):
    """Sub-chunk pending batches (no size trigger, no clock advance) sit
    in the backlog; draining their owner re-homes every pair and, after
    the final drain, each future resolved exactly once with the oracle
    answer."""
    resolve_counts: dict[int, int] = {}
    orig = QueryFuture._resolve

    def counting(self, *a, **kw):
        resolve_counts[id(self)] = resolve_counts.get(id(self), 0) + 1
        return orig(self, *a, **kw)

    monkeypatch.setattr(QueryFuture, "_resolve", counting)

    clks = _clocks(3)
    router = ReplicaRouter(index, n_replicas=3, clocks=clks, policy=WIDE,
                           qos=QOS, cache_size=64)
    rng = np.random.default_rng(29)
    us, vs = _pairs(rng, 40, 12)
    futs = router.submit_batch(us, vs, qos="bulk")
    pending = {i: rep.n_pending for i, rep in enumerate(router.replicas)}
    victim = max(pending, key=pending.get)
    assert pending[victim] > 0                      # backlog actually held

    handed = router.drain_replica(victim)
    assert handed == pending[victim]
    assert router.stats["drains"] == 1
    assert router.stats["handoffs"] == handed
    assert router.replicas[victim].stats["handed_off"] == handed
    assert router.replicas[victim].n_pending == 0
    assert victim not in router.live_replicas()
    # handed-off pairs now route (and later cache) on the survivors
    for u, v in zip(us.tolist(), vs.tolist()):
        assert router.owner_of(u, v) != victim

    router.drain()
    oracle = OracleCache(index.graph)
    for f in futs:
        assert f.done()
        assert resolve_counts.get(id(f), 0) == 1    # never dropped/doubled
        oracle.assert_result(f.result())
    for rep in router.replicas:
        _accounting(rep)
    router.close()


def test_drain_replica_resolves_inflight_in_place(index, monkeypatch):
    """Pairs already dispatched (in the async window) are NOT handed off:
    the drain resolves them on the draining replica itself."""
    resolve_counts: dict[int, int] = {}
    orig = QueryFuture._resolve

    def counting(self, *a, **kw):
        resolve_counts[id(self)] = resolve_counts.get(id(self), 0) + 1
        return orig(self, *a, **kw)

    monkeypatch.setattr(QueryFuture, "_resolve", counting)

    router = ReplicaRouter(
        index, n_replicas=2, clocks=_clocks(2),
        policy=AdmissionPolicy(adaptive=False, chunk=2, min_chunk=2),
        async_depth=8, cache_size=64)
    rng = np.random.default_rng(31)
    us, vs = _pairs(rng, 40, 10)
    futs = router.submit_batch(us, vs)
    inflight = {i: rep.n_inflight for i, rep in enumerate(router.replicas)}
    victim = max(inflight, key=inflight.get)
    assert inflight[victim] > 0

    pending_before = router.replicas[victim].n_pending
    handed = router.drain_replica(victim)
    assert handed == pending_before                 # in-flight stayed put
    assert router.replicas[victim].n_inflight == 0  # resolved by the drain
    router.drain()
    oracle = OracleCache(index.graph)
    for f in futs:
        assert resolve_counts.get(id(f), 0) == 1
        oracle.assert_result(f.result())
    for rep in router.replicas:
        _accounting(rep)
    router.close()


def test_handoff_preserves_deadlines_on_the_adopter(index):
    """An adopted pair keeps its original deadline: the new owner's timer
    resolves it within the class bound (simulated time)."""
    clks = _clocks(2)
    router = ReplicaRouter(index, n_replicas=2, clocks=clks, policy=WIDE,
                           qos=QOS)
    rng = np.random.default_rng(37)
    us, vs = _pairs(rng, 40, 6)
    futs = router.submit_batch(us, vs, qos="interactive")
    victims = [i for i, rep in enumerate(router.replicas)
               if rep.n_pending > 0]
    router.drain_replica(victims[0])
    _advance(clks, 0.003)                           # past max_wait=0.002
    assert all(f.done() for f in futs)              # timer, not drain
    survivor = router.replicas[1 - victims[0]]
    waits = survivor.qos_stats["interactive"]["waits"]
    assert waits and all(w <= 0.002 + 1e-9 for w in waits)
    router.close()


def test_drain_guards_and_restore(index):
    router = ReplicaRouter(index, n_replicas=2, clocks=_clocks(2),
                           policy=WIDE)
    baseline = {(u, v): router.owner_of(u, v)
                for u in range(8) for v in range(u + 1, 10)}
    router.drain_replica(0)
    with pytest.raises(ValueError, match="already draining"):
        router.drain_replica(0)
    with pytest.raises(ValueError, match="last live"):
        router.drain_replica(1)
    assert router.live_replicas() == [1]
    assert all(router.owner_of(u, v) == 1 for u, v in baseline)
    router.restore_replica(0)
    with pytest.raises(ValueError, match="already live"):
        router.restore_replica(0)
    # consistent hashing: restoring returns the exact original placement
    assert {k: router.owner_of(*k) for k in baseline} == baseline
    assert router.stats["drains"] == 1 and router.stats["restores"] == 1
    router.close()


def test_drain_and_restore_ship_warm_cache(index):
    """Cache residency moves with ownership: draining a replica ships its
    packed entries to the survivors (re-routed traffic keeps hitting),
    and restoring it ships its keys back — a restored replica rejoins
    *warm*, not cold (the bugfix this PR pins)."""
    n = 3
    router = ReplicaRouter(index, n_replicas=n, clocks=_clocks(n),
                           policy=WIDE, cache_size=256, cache_policy="hub")
    rng = np.random.default_rng(41)
    hot = {(min(int(u), int(v)), max(int(u), int(v)))
           for u, v in rng.integers(0, 40, size=(16, 2)) if u != v}
    us = np.array([k[0] for k in hot], np.int32)
    vs = np.array([k[1] for k in hot], np.int32)
    router.query_batch(us, vs)              # warm every owner's cache
    victim = max(range(n),
                 key=lambda i: len(router.replicas[i].service.cache))
    owned = [k for k in hot if router.owner_of(*k) == victim]
    n_victim = len(router.replicas[victim].service.cache)
    assert owned and n_victim > 0

    router.drain_replica(victim)
    assert len(router.replicas[victim].service.cache) == 0   # shipped out
    assert router.stats["cache_shipped"] >= n_victim
    hits0 = sum(rep.service.cache.hits for rep in router.replicas)
    res = router.query_batch(us, vs)        # all pairs peer-served, warm
    hits1 = sum(rep.service.cache.hits for rep in router.replicas)
    assert hits1 - hits0 == len(hot)
    oracle = OracleCache(index.graph)
    for r in res:
        oracle.assert_result(r)

    shipped = router.stats["cache_shipped"]
    router.restore_replica(victim)
    back = router.replicas[victim].service.cache
    assert all((u, v, 0) in back for u, v in owned)   # came home warm
    assert router.stats["cache_shipped"] >= shipped + len(owned)
    hits2 = sum(rep.service.cache.hits for rep in router.replicas)
    router.query_batch(us, vs)
    hits3 = sum(rep.service.cache.hits for rep in router.replicas)
    assert hits3 - hits2 == len(hot)        # restored replica hits at once
    router.close()


def test_apply_update_fans_out_epochs(index):
    """``apply_update`` computes the next-epoch index once and installs
    the SAME object on every replica — draining ones included — under the
    router lock; post-update traffic answers against the new graph."""
    from repro.core.graph import edge_set

    from helpers.serving_oracle import EpochOracle

    router = ReplicaRouter(index, n_replicas=3, clocks=_clocks(3),
                           policy=WIDE, cache_size=64)
    oracle = EpochOracle(index.graph)
    router.drain_replica(0)                 # drained replicas update too
    cut = [tuple(int(x) for x in edge_set(index.graph)[0])]
    new = router.apply_update(deletes=cut)
    oracle.advance(new.graph, deletes=cut)
    assert router.index is new and new.epoch == 1
    assert router.stats["updates"] == 1
    for rep in router.replicas:
        assert rep.index is new and rep.service.index is new
        assert rep.stats["updates"] == 1
    rng = np.random.default_rng(43)
    us, vs = _pairs(rng, 40, 8)
    for r, u, v in zip(router.query_batch(us, vs),
                       us.tolist(), vs.tolist()):
        d, eids = oracle.spg(u, v, 1)
        assert r.dist == d
        assert np.array_equal(np.asarray(r.edge_ids), eids)
    router.restore_replica(0)
    router.close()


# ------------------------------------------------------------- wall clock


def test_system_clock_replica_trace_smoke(index):
    """Wall-clock smoke: a short trace through a ``ReplicaRouter`` on
    real ``SystemClock``s — real deadline timers, real threads — drains
    clean with the exact accounting identity per replica, oracle
    bit-identity per future, and metrics latency totals equal to the
    resolved-query count (the simulated-time numbers validated against
    reality)."""
    import time

    from repro.serving import MetricsRegistry

    qos = (QoSClass("interactive", max_wait=0.01, weight=4.0),
           QoSClass("bulk", max_wait=0.05, weight=1.0))
    registry = MetricsRegistry()
    with ReplicaRouter(index, n_replicas=2, policy=WIDE, qos=qos,
                       cache_size=64) as router:   # clocks=None: SystemClock
        for i, rep in enumerate(router.replicas):
            registry.register(f"replica{i}", rep)
        rng = np.random.default_rng(47)
        futs = []
        for step in range(4):
            us, vs = _pairs(rng, 40, 5)
            futs.extend(router.submit_batch(
                us, vs, qos="interactive" if step % 2 else "bulk"))
            if step == 1:
                time.sleep(0.02)            # let real timers admit a round
        router.drain()

        oracle = OracleCache(index.graph)
        for f in futs:
            assert f.done()
            oracle.assert_result(f.result())
        for rep in router.replicas:
            _accounting(rep)
        snap = registry.snapshot()
        assert set(snap) == {"replica0", "replica1"}
        submitted = sum(s["stats"]["submitted"] for s in snap.values())
        assert submitted == len(futs)
        resolved_via_hist = sum(
            sum(h["total"] for h in s["latency_us"].values())
            for s in snap.values())
        assert resolved_via_hist == len(futs)   # each future observed once
        assert all(s["n_pending"] == 0 and s["n_inflight"] == 0
                   for s in snap.values())


def test_router_context_manager_and_single_replica(index):
    with ReplicaRouter(index, n_replicas=1, clocks=_clocks(1),
                       policy=WIDE) as router:
        res = router.query_batch([1, 2], [3, 4])
        oracle = OracleCache(index.graph)
        for r in res:
            oracle.assert_result(r)
        with pytest.raises(ValueError, match="last live"):
            router.drain_replica(0)
        assert router.stats["routed"] == 2
