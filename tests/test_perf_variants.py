"""Equivalence tests for the §Perf optimization variants: every beyond-
baseline path must produce the same math as its baseline."""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.models.moe import init_moe, moe, moe_onehot, moe_sort


@pytest.fixture(scope="module")
def moe_setup():
    cfg = replace(get_config("dbrx-132b").reduced(), moe_capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    return cfg, p, x


def test_moe_grouped_equals_global_when_capacity_free(moe_setup):
    cfg, p, x = moe_setup
    o0, a0 = jax.jit(lambda p, x: moe_onehot(p, x, cfg))(p, x)
    cfg_g = replace(cfg, moe_group_size=32)
    oG, aG = jax.jit(lambda p, x: moe_onehot(p, x, cfg_g))(p, x)
    np.testing.assert_allclose(np.asarray(o0, np.float32),
                               np.asarray(oG, np.float32), atol=2e-2)
    assert abs(float(a0) - float(aG)) < 1e-5


def test_moe_sort_equals_onehot(moe_setup):
    cfg, p, x = moe_setup
    o0, _ = jax.jit(lambda p, x: moe_onehot(p, x, cfg))(p, x)
    o1, _ = jax.jit(lambda p, x: moe_sort(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(o0, np.float32),
                               np.asarray(o1, np.float32), atol=2e-2)


def test_moe_dispatch_config_switch(moe_setup):
    cfg, p, x = moe_setup
    o_sort, _ = jax.jit(lambda p, x: moe(p, x, replace(cfg, moe_dispatch="sort")))(p, x)
    o_hot, _ = jax.jit(lambda p, x: moe(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(o_sort, np.float32),
                               np.asarray(o_hot, np.float32), atol=2e-2)


def test_chunked_attention_equals_naive():
    cfg = get_config("qwen1.5-32b").reduced()
    cfg_f = replace(cfg, attn_impl="chunked", attn_chunk=16)
    m0, mf = build_model(cfg), build_model(cfg_f)
    params = m0.init(jax.random.PRNGKey(2))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 64)), jnp.int32)}
    l0, _ = jax.jit(m0.forward)(params, batch=batch)
    lf, _ = jax.jit(mf.forward)(params, batch=batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(lf), atol=5e-2)


def test_chunked_attention_encoder_path():
    cfg = replace(get_config("hubert-xlarge").reduced(),
                  attn_impl="chunked", attn_chunk=16)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = {
        "features": jnp.asarray(rng.normal(size=(2, 64, cfg.frontend_dim)), jnp.float32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
        "loss_mask": jnp.asarray(rng.random((2, 64)) < 0.3),
    }
    loss, _ = jax.jit(m.loss)(params, batch=batch)
    assert np.isfinite(float(loss))


def test_layer_remat_same_loss_and_grads():
    cfg = get_config("deepseek-7b").reduced()
    cfg_r = replace(cfg, remat_policy="layer")
    m0, mr = build_model(cfg), build_model(cfg_r)
    params = m0.init(jax.random.PRNGKey(3))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 32)), jnp.int32)}

    def loss_of(model):
        return jax.jit(jax.value_and_grad(lambda p: model.loss(p, batch=batch)[0]))

    l0, g0 = loss_of(m0)(params)
    lr, gr = loss_of(mr)(params)
    # jax.checkpoint moves XLA fusion boundaries, so the bf16 forward is
    # re-rounded at different points: same math, not bitwise — compare at
    # bf16-accumulation tolerance (observed ~1.6e-5 on a ~5.7 loss).
    assert abs(float(l0) - float(lr)) < 1e-4, (float(l0), float(lr))
    # grads flow through bf16 params, so recompute rounding shows up at
    # bf16 ulp scale (2^-7 at magnitude ~1): compare at two ulps
    for a, b in zip(jax.tree_util.tree_leaves(g0), jax.tree_util.tree_leaves(gr)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1.6e-2)


def test_remat_hybrid_and_ssm_paths():
    for arch in ("zamba2-2.7b", "rwkv6-1.6b"):
        cfg = replace(get_config(arch).reduced(), remat_policy="layer")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 32)), jnp.int32)}
        loss, grads = jax.jit(
            jax.value_and_grad(lambda p: m.loss(p, batch=batch)[0]))(params)
        assert np.isfinite(float(loss)), arch
        assert all(np.isfinite(np.asarray(g, np.float32)).all()
                   for g in jax.tree_util.tree_leaves(grads)), arch
