"""Vertex-sharded index (born-sharded labels + sharded serving) ==
replicated ``QbSIndex``, bit for bit — single-shard in-process, 8-device
emulated mesh in a subprocess."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import QbSIndex, gnp_random_graph, grid_graph
from repro.core.distributed import distributed_build_sharded
from repro.core.sharded import ShardedIndex


def _graphs():
    return [(gnp_random_graph(60, 3.5, seed=42), 5), (grid_graph(6, 6), 3)]


def _queries(g, lms, n_q=24, seed=0):
    rng = np.random.default_rng(seed)
    us = rng.integers(0, g.n_vertices, n_q).astype(np.int32)
    vs = rng.integers(0, g.n_vertices, n_q).astype(np.int32)
    us[:3] = lms[:3]          # exercise the landmark lanes
    vs[1:4] = lms[:3]
    return us, vs


def test_sharded_build_single_shard_matches_packed():
    mesh = Mesh(np.array(jax.devices()[:1]), ("shards",))
    for g, nl in _graphs():
        ref = QbSIndex.build(g, n_landmarks=nl, use_pallas=False)
        lms = np.asarray(ref.scheme.landmarks)
        sl, part = distributed_build_sharded(g, lms, mesh)
        v = g.n_vertices
        assert sl.pack_dtype == ref.packed.dtype
        np.testing.assert_array_equal(np.asarray(sl.labels_sh)[0, :v],
                                      np.asarray(ref.packed.label_dist))
        np.testing.assert_array_equal(np.asarray(sl.lm_sh)[0, :, :v],
                                      np.asarray(ref.packed.lm_dist))
        np.testing.assert_array_equal(np.asarray(sl.meta_w),
                                      np.asarray(ref.packed.meta_w))
        np.testing.assert_array_equal(np.asarray(sl.meta_dist),
                                      np.asarray(ref.packed.meta_dist))


def test_sharded_serving_single_shard_matches_replicated():
    for g, nl in _graphs():
        ref = QbSIndex.build(g, n_landmarks=nl, use_pallas=False)
        lms = np.asarray(ref.scheme.landmarks)
        sh = ShardedIndex.build(g, landmarks=lms, mesh=1)
        us, vs = _queries(g, lms)
        d_ref, m_ref = ref.query_batch_arrays(us, vs)
        d_sh, m_sh = sh.query_batch_arrays(us, vs)
        np.testing.assert_array_equal(d_sh, d_ref)
        np.testing.assert_array_equal(m_sh, m_ref)


def test_qbs_build_sharded_kwarg_returns_sharded_index():
    g = gnp_random_graph(40, 3.0, seed=1)
    idx = QbSIndex.build(g, n_landmarks=4, sharded=1)
    assert isinstance(idx, ShardedIndex) and idx.is_sharded
    ref = QbSIndex.build(g, n_landmarks=4, use_pallas=False,
                         landmarks=np.asarray(idx.labels.landmarks))
    a, b = idx.query(1, 17), ref.query(1, 17)
    assert a.dist == b.dist
    np.testing.assert_array_equal(a.edge_ids, b.edge_ids)


def test_service_rejects_batch_sharding_a_sharded_index():
    g = gnp_random_graph(40, 3.0, seed=1)
    sh = ShardedIndex.build(g, n_landmarks=4, mesh=1)
    with pytest.raises(ValueError, match="sharded index"):
        sh.make_service(devices=1)


def test_sharded_size_accounting():
    g = gnp_random_graph(40, 3.0, seed=1)
    sh = ShardedIndex.build(g, n_landmarks=4, mesh=1)
    info = sh.sharded_size_bytes()
    item = sh.labels.pack_dtype.itemsize
    v, r = g.n_vertices, sh.labels.n_landmarks
    assert sh.labels.per_device_label_bytes() == \
        2 * sh.labels.v_loc * r * item + 2 * r * r * item
    assert info["n_shards"] == 1
    assert info["per_device_label_bytes"] == \
        sh.labels.per_device_label_bytes()
    assert info["per_device_csr_bytes"] == 4 * sh.part.e_max * 4
    assert info["replicated_label_bytes"] == (2 * v * r + 2 * r * r) * item
    assert info["replicated_csr_bytes"] == 3 * g.n_edges * 4
    assert info["per_device_bytes"] == \
        info["per_device_label_bytes"] + info["per_device_csr_bytes"]
    assert info["replicated_bytes"] == \
        info["replicated_label_bytes"] + info["replicated_csr_bytes"]
    assert info["per_device_frac"] == pytest.approx(
        info["per_device_bytes"] / info["replicated_bytes"])
    # one shard holds the whole label table: bytes match the replicated one
    assert info["per_device_label_bytes"] == info["replicated_label_bytes"]


@pytest.mark.slow
def test_sharded_eight_devices_bit_identical_subprocess():
    script = os.path.join(os.path.dirname(__file__), "helpers",
                          "sharded_check.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ALL-OK" in out.stdout
