"""Sketch invariants (Definition 4.5, Corollary 4.6, Eq. 3/4)."""
import numpy as np

import jax.numpy as jnp

from repro.core import (
    INF,
    build_labelling,
    compute_sketch_batch,
    d_top_only,
    gnp_random_graph,
    select_landmarks,
)
from repro.core.baselines import bfs_distances


def _setup(seed=29, n=45, nl=5):
    g = gnp_random_graph(n, 3.0, seed=seed)
    scheme = build_labelling(g, select_landmarks(g, nl))
    return g, scheme


def test_d_top_upper_bounds_distance():
    """Corollary 4.6: d_top >= d_G(u, v)."""
    g, scheme = _setup()
    rng = np.random.default_rng(0)
    us = rng.integers(0, g.n_vertices, size=16)
    vs = rng.integers(0, g.n_vertices, size=16)
    sk = compute_sketch_batch(
        scheme.label_dist[jnp.asarray(us)], scheme.label_dist[jnp.asarray(vs)],
        scheme.meta_w, scheme.meta_dist,
    )
    for k, (u, v) in enumerate(zip(us, vs)):
        d = bfs_distances(g, int(u))[int(v)]
        assert int(sk.d_top[k]) >= min(int(d), INF)


def test_d_top_exact_through_landmarks():
    """For u, v whose every shortest path crosses a landmark, d_top == d_G."""
    g, scheme = _setup()
    is_l = np.asarray(scheme.is_landmark)
    rng = np.random.default_rng(1)
    found = 0
    for _ in range(200):
        u, v = int(rng.integers(0, g.n_vertices)), int(rng.integers(0, g.n_vertices))
        if u == v or is_l[u] or is_l[v]:
            continue
        du = bfs_distances(g, u)
        dv = bfs_distances(g, v)
        d = du[v]
        if d >= INF:
            continue
        # does some landmark sit on a shortest path?
        lm_on = any(du[r] + dv[r] == d for r in np.asarray(scheme.landmarks))
        sk = compute_sketch_batch(
            scheme.label_dist[jnp.asarray([u])], scheme.label_dist[jnp.asarray([v])],
            scheme.meta_w, scheme.meta_dist,
        )
        if lm_on:
            assert int(sk.d_top[0]) == int(d)
            found += 1
    assert found > 0  # the regime was actually exercised


def test_sketch_edges_attain_minimum():
    g, scheme = _setup()
    rng = np.random.default_rng(2)
    us = rng.integers(0, g.n_vertices, size=8)
    vs = rng.integers(0, g.n_vertices, size=8)
    lu = scheme.label_dist[jnp.asarray(us)]
    lv = scheme.label_dist[jnp.asarray(vs)]
    sk = compute_sketch_batch(lu, lv, scheme.meta_w, scheme.meta_dist)
    lu_n, lv_n = np.asarray(lu), np.asarray(lv)
    md = np.asarray(scheme.meta_dist)
    for b in range(8):
        dt = int(sk.d_top[b])
        du_land = np.asarray(sk.du_land[b])
        dv_land = np.asarray(sk.dv_land[b])
        for r in np.flatnonzero(du_land < INF):
            # r participates in a pair attaining d_top
            best = (lu_n[b, r] + md[r, :] + lv_n[b, :]).min()
            assert best == dt
            assert du_land[r] == lu_n[b, r]
        for r2 in np.flatnonzero(dv_land < INF):
            best = (lu_n[b, :] + md[:, r2] + lv_n[b, r2]).min()
            assert best == dt


def test_budgets_eq4():
    g, scheme = _setup()
    rng = np.random.default_rng(3)
    us = rng.integers(0, g.n_vertices, size=8)
    vs = rng.integers(0, g.n_vertices, size=8)
    sk = compute_sketch_batch(
        scheme.label_dist[jnp.asarray(us)], scheme.label_dist[jnp.asarray(vs)],
        scheme.meta_w, scheme.meta_dist,
    )
    for b in range(8):
        du_land = np.asarray(sk.du_land[b])
        present = du_land < INF
        want = max(int(du_land[present].max()) - 1, 0) if present.any() else 0
        assert int(sk.d_star_u[b]) == want


def test_sketch_pallas_path_bit_identical():
    """use_pallas=True (Eq. 3 on the kernel) == the pure-jnp reference,
    field by field — the (min, +) semiring is exact integer arithmetic."""
    g, scheme = _setup()
    rng = np.random.default_rng(7)
    us = rng.integers(0, g.n_vertices, size=24)
    vs = rng.integers(0, g.n_vertices, size=24)
    lu = scheme.label_dist[jnp.asarray(us)]
    lv = scheme.label_dist[jnp.asarray(vs)]
    ref = compute_sketch_batch(lu, lv, scheme.meta_w, scheme.meta_dist)
    got = compute_sketch_batch(lu, lv, scheme.meta_w, scheme.meta_dist,
                               use_pallas=True)
    for name, a, b in zip(ref._fields, ref, got):
        assert (np.asarray(a) == np.asarray(b)).all(), name


def test_d_top_only_matches_full_sketch():
    g, scheme = _setup()
    rng = np.random.default_rng(4)
    us = rng.integers(0, g.n_vertices, size=32)
    vs = rng.integers(0, g.n_vertices, size=32)
    lu = scheme.label_dist[jnp.asarray(us)]
    lv = scheme.label_dist[jnp.asarray(vs)]
    sk = compute_sketch_batch(lu, lv, scheme.meta_w, scheme.meta_dist)
    fast = d_top_only(lu, lv, scheme.meta_dist)
    assert (np.asarray(fast) == np.asarray(sk.d_top)).all()
