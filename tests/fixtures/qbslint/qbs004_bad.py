"""Fixture: jit constructed off the setup path (QBS004)."""
import jax


def hot(xs):
    fns = []
    for x in xs:
        fns.append(jax.jit(lambda a: a + x))    # QBS004 inside a loop
    return fns


class Service:
    def step(self, fn, x):
        return jax.jit(fn)(x)                   # QBS004 per-call body


def make_step(fn):
    return jax.jit(fn)                          # allowed: make_* factory
