"""Seeded QBS007 serving-scope violations: np.int64 on the host tier."""
import numpy as np


def dedup_key(cu, cv, v):
    return cu.astype(np.int64) * (v + 1) + cv  # line 6: fires (np.int64)


def empty_edges():
    return np.zeros((0,), np.int64)            # line 10: fires


def justified_key(cu, cv, v):
    # products can exceed int32; suppression keeps the width auditable
    return cu.astype(np.int64) * (v + 1) + cv  # qbslint: disable=QBS007
