"""Fixture: clock.py is the one serving file allowed to touch time."""
import threading
import time


class SystemClock:
    def now(self):
        return time.monotonic()

    def call_at(self, t, fn):
        timer = threading.Timer(max(0.0, t - self.now()), fn)
        timer.daemon = True
        timer.start()
        return timer
