"""Fixture: wall-clock calls in serving code (QBS002)."""
import threading
import time
from time import monotonic                  # QBS002
from threading import Timer                 # QBS002


def admit(backlog):
    t0 = time.time()                        # QBS002
    time.sleep(0.01)                        # QBS002
    timer = threading.Timer(1.0, admit)     # QBS002
    return t0, timer, monotonic, Timer
