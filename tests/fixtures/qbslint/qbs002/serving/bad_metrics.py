"""Fixture: a metrics exporter stamping scrapes with wall time (QBS002).

Latency histograms record at future-resolution time on the *injected*
clock; reaching for ``time`` here would make the histogram counts depend
on host speed instead of the trace."""
import time


def snapshot(histogram):
    scraped_at = time.monotonic()           # QBS002
    time.sleep(0.0)                         # QBS002
    return {"scraped_at": scraped_at, "counts": list(histogram)}
