"""Fixture: guarded-field mutations outside the lock (QBS005)."""
import heapq
import threading


class Sched:
    _QBS_GUARDED_FIELDS = ("_pending", "_heap", "stats")

    def __init__(self):
        self._lock = threading.RLock()
        self._pending = {}
        self._heap = []
        self.stats = {"n": 0}

    def ok(self, key):
        with self._lock:
            self._pending[key] = 1              # guarded: fine
            self.stats["n"] += 1

    def bad(self, key):
        self._pending[key] = 1                  # QBS005 write
        self._pending.pop(key, None)            # QBS005 mutator call
        heapq.heappush(self._heap, key)         # QBS005 heapq mutation
        self.stats["n"] += 1                    # QBS005 write

    def marked(self, key):                      # qbslint: locked
        self._pending[key] = 1                  # fine: caller holds lock
