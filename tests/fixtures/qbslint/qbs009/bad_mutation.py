"""Seeded QBS009 violations: Graph/label-table/index writes outside the
construction and epoch-advance entry points."""


class Service:
    def __init__(self, index):
        self.index = index                   # construction: allowed

    def hot_swap(self, new):
        self.index = new                     # rebind outside entry point

    def patch_tables(self, d):
        self.index.graph = d                 # nested receiver still fires
        self.packed.label_dist[0] = 7        # in-place write into a table
        self.scheme, keep = d, 1             # tuple target
        del self.labels                      # delete is a write too


def mutate(idx, rows):
    idx.lm_dist = rows                       # free function, any receiver
