"""Clean QBS009 counterpart: every table write sits in a construction or
epoch-advance entry point (or is suppressed with a stated reason)."""


class Index:
    def __init__(self, graph):
        self.graph = graph
        self.epoch = 0

    def apply_update(self, inserts):
        self.graph = inserts                 # the epoch-advance entry point


class Serving:
    def install_index(self, index):
        self.index = index                   # the swap entry point

    def restore(self, snapshot):
        # checkpoint restore IS an epoch install in disguise; say so
        self.index = snapshot  # qbslint: disable=QBS009


def build_index(graph):
    idx = Index(graph)
    idx.labels = graph                       # build* factories construct
    return idx
