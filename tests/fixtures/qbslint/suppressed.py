"""Fixture: inline and file-wide suppressions silence findings."""
# qbslint: disable-file=QBS001
from jax.experimental.shard_map import shard_map    # file-wide suppressed

import jax


def caller(fn, x):
    return jax.jit(fn)(x)  # qbslint: disable=QBS004


def caller2(fn, x):
    return jax.jit(shard_map(fn))  # qbslint: disable
