"""Fixture: ResultCache writes bypassing cache_put (QBS006)."""


class Service:
    def __init__(self, cache):
        self.cache = cache

    def cache_put(self, key, value):
        self.cache.put(key, value)              # fine: the insertion path

    def sneaky(self, key, value):
        self.cache.put(key, value)              # QBS006 direct put
        self.cache._store[key] = value          # QBS006 internals


def loose(cache, key, value):
    cache.put(key, value)                       # QBS006 direct put
