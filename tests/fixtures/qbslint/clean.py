"""Fixture: idiomatic patterns every rule must accept unflagged."""
import heapq
import threading

import jax

from repro.compat import shard_map              # the blessed QBS001 route


def make_step(fn, mesh):
    return jax.jit(shard_map(fn, mesh=mesh))    # factory: QBS004 ok


class Stream:
    _QBS_GUARDED_FIELDS = ("_pending", "_heap")

    def __init__(self):
        self._lock = threading.RLock()
        self._pending = {}
        self._heap = []

    def submit(self, key):
        with self._lock:
            self._pending[key] = 1
            heapq.heappush(self._heap, key)
            self._locked_helper(key)

    def _locked_helper(self, key):              # qbslint: locked
        self._pending.pop(key, None)

    def snapshot(self):
        with self._lock:
            return dict(self._pending)
