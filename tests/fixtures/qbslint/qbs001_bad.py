"""Fixture: every shard_map import/use form QBS001 must catch."""
import jax
import jax.experimental.shard_map                         # QBS001
from jax.experimental.shard_map import shard_map          # QBS001
from jax.experimental import shard_map as sm              # QBS001
from jax import shard_map as jsm                          # QBS001


def f(fn, mesh):
    return jax.experimental.shard_map.shard_map(fn, mesh=mesh)   # QBS001


def g(fn):
    return jax.shard_map(fn)                              # QBS001


__all__ = ["f", "g", "shard_map", "sm", "jsm"]
