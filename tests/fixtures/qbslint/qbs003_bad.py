"""Fixture: host syncs inside jitted bodies (QBS003)."""
import functools

import jax
import numpy as np


@jax.jit
def decorated(x):
    return x + int(x.sum())                 # QBS003 int() on traced value


@functools.partial(jax.jit, static_argnames=("n",))
def partial_decorated(x, n):
    y = np.asarray(x)                       # QBS003 np.asarray
    return y.sum().item() + n               # QBS003 .item()


def wrapped(x):
    jax.device_get(x)                       # QBS003 (jit-wrapped below)
    return x.block_until_ready()            # QBS003


step = jax.jit(wrapped)
lam = jax.jit(lambda x: float(x))           # QBS003 float() in jitted lambda
