"""Seeded QBS007 violations: packed tables widened on the host."""
import jax
import jax.numpy as jnp
import numpy as np


def host_widen(ctx, packed):
    a = ctx.label_dist.astype(jnp.int32)       # line 8: fires
    b = packed.meta_w[0].astype(np.int64)      # line 9: fires
    c = ctx.meta_dist.astype("int32")          # line 10: fires
    d = packed.lm_dist[0].astype(np.int32)     # line 11: fires
    return a, b, c, d


@jax.jit
def widen_in_registers(label_dist, rows):
    # OK: gathered packed rows widen inside the jit body
    return label_dist[rows].astype(jnp.int32)


def _impl(meta_dist):
    return meta_dist.astype(jnp.int32)         # OK: wrapped by jax.jit below


widened = jax.jit(_impl)


def unrelated(x):
    return x.astype(np.int64)                  # OK: not a packed table


def narrow(packed):
    return packed.label_dist.astype(np.uint16)  # OK: stays packed
