"""Basename scoping: a file named sharded.py is in QBS008 scope anywhere."""
import numpy as np


def snapshot(eid_sh):
    return np.asarray(eid_sh)                  # line 6: fires
