"""Seeded QBS008 violations: sharded tables gathered whole to host."""
import jax
import numpy as np


def debug_dump(idx):
    a = jax.device_get(idx.labels.labels_sh)   # line 7: fires
    b = np.asarray(idx._src_sh)                # line 8: fires
    c = np.array(idx.lm_sh[0])                 # line 9: fires
    return a, b, c


def checkpoint_sharded(labels_sh):  # qbslint: host-boundary
    # a declared boundary: persisting the shards to disk is its job
    return np.asarray(labels_sh)


def audited_peek(vstart_sh):
    # justified one-off; suppression keeps the gather auditable
    return np.asarray(vstart_sh)  # qbslint: disable=QBS008


def replicated_ok(out, mask):
    # replicated outputs gather freely — no sharded receiver
    return jax.device_get(out), np.asarray(mask)
