"""Out of QBS008 scope: not serving/, not distributed.py / sharded.py."""
import numpy as np


def snapshot(eid_sh):
    return np.asarray(eid_sh)   # quiet: offline analysis gathers freely
