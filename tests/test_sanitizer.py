"""Runtime concurrency sanitizer (``serving.debug``): owner-tracked
lock, guarded containers, StreamingService wiring (``sanitize=`` /
``QBS_SANITIZE``), and a multi-threaded submit regression that runs the
real scheduler under the sanitizer."""
import threading

import numpy as np
import pytest

from repro.core import QbSIndex, gnp_random_graph
from repro.serving import ServingService, StreamingService
from repro.serving.debug import (
    ConcurrencyViolation,
    GuardedDict,
    OwnedRLock,
    Sanitizer,
    enabled,
)


@pytest.fixture(scope="module")
def index():
    return QbSIndex.build(gnp_random_graph(45, 3.2, seed=17),
                          n_landmarks=5, chunk=8)


# ------------------------------------------------------------ primitives


def test_owned_rlock_tracks_owner_across_threads():
    lock = OwnedRLock()
    assert not lock.owned()
    with lock:
        assert lock.owned()
        with lock:                       # reentrant: still owned
            assert lock.owned()
        assert lock.owned()
        seen = []
        t = threading.Thread(target=lambda: seen.append(lock.owned()))
        t.start()
        t.join()
        assert seen == [False]           # other thread does not own it
    assert not lock.owned()


def test_guarded_containers_raise_off_lock_and_allow_under_lock():
    san = Sanitizer()
    d = san.dict({"a": 1}, what="d")
    q = san.deque(what="q")
    ls = san.list([3, 1, 2], what="l")

    with pytest.raises(ConcurrencyViolation):
        d["b"] = 2
    with pytest.raises(ConcurrencyViolation):
        d.pop("a")
    with pytest.raises(ConcurrencyViolation):
        q.append(1)
    with pytest.raises(ConcurrencyViolation):
        ls[0] = 9
    with pytest.raises(ConcurrencyViolation):
        ls.sort()

    assert d["a"] == 1                   # reads never require the lock
    assert list(ls) == [3, 1, 2]

    with san.lock:
        d["b"] = 2
        del d["b"]
        q.append(1)
        assert q.popleft() == 1
        ls.append(4)
        ls.sort()
    assert list(ls) == [1, 2, 3, 4]


def test_enabled_reads_env(monkeypatch):
    for val, want in [("1", True), ("true", True), ("YES", True),
                      ("on", True), ("0", False), ("", False),
                      ("off", False)]:
        monkeypatch.setenv("QBS_SANITIZE", val)
        assert enabled() is want, val
    monkeypatch.delenv("QBS_SANITIZE")
    assert enabled() is False


# ------------------------------------------------------- service wiring


def test_sanitize_kwarg_overrides_env(index, monkeypatch):
    monkeypatch.setenv("QBS_SANITIZE", "1")
    assert isinstance(StreamingService(index)._pending, GuardedDict)
    assert isinstance(StreamingService(index, sanitize=False)._pending, dict)
    assert not isinstance(
        StreamingService(index, sanitize=False)._pending, GuardedDict)
    monkeypatch.delenv("QBS_SANITIZE")
    assert not isinstance(StreamingService(index)._pending, GuardedDict)
    assert isinstance(
        StreamingService(index, sanitize=True)._pending, GuardedDict)


def test_sanitized_service_matches_plain(index):
    rng = np.random.default_rng(3)
    us = rng.integers(0, 45, size=40).astype(np.int32)
    vs = rng.integers(0, 45, size=40).astype(np.int32)
    plain = ServingService(index).query_batch(us, vs)
    got = StreamingService(index, sanitize=True).query_batch(us, vs)
    for a, b in zip(got, plain):
        assert a.dist == b.dist and a.d_top == b.d_top
        assert np.array_equal(a.edge_ids, b.edge_ids)


def test_external_off_lock_mutations_are_caught(index):
    svc = StreamingService(index, sanitize=True)
    with pytest.raises(ConcurrencyViolation):
        svc._pending[(1, 2)] = (0, 0.0, 0)
    with pytest.raises(ConcurrencyViolation):
        svc.stats["submitted"] += 1
    with pytest.raises(ConcurrencyViolation):
        svc._inflight.append(None)
    with pytest.raises(ConcurrencyViolation):
        svc._chunk = 64                          # plain-attr rebind guard
    with pytest.raises(ConcurrencyViolation):
        svc.qos_stats["default"]["expired"] += 1
    # the same mutations are legal for the lock holder
    with svc._lock:
        svc.stats["submitted"] += 1
        svc.stats["submitted"] -= 1
        svc._chunk = svc._chunk
    # non-guarded attributes stay unrestricted
    svc.some_annotation = "ok"


def test_concurrent_submit_burst_under_sanitizer(index):
    """Satellite regression: many threads hammering submit_batch while
    the scheduler pumps inline must neither trip the sanitizer nor lose
    or corrupt a single result."""
    svc = StreamingService(index, sanitize=True)
    expected = {}
    ref = ServingService(index)
    rng = np.random.default_rng(11)
    per_thread = []
    for _ in range(4):
        us = rng.integers(0, 45, size=30).astype(np.int32)
        vs = rng.integers(0, 45, size=30).astype(np.int32)
        per_thread.append((us, vs))
        for r in ref.query_batch(us, vs):
            expected[(r.u, r.v)] = (r.dist, r.d_top)

    futs = [None] * len(per_thread)
    errors = []

    def worker(i):
        us, vs = per_thread[i]
        try:
            futs[i] = svc.submit_batch(us, vs)
        except BaseException as e:                # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(per_thread))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    svc.drain()
    for i, (us, vs) in enumerate(per_thread):
        for fut, u, v in zip(futs[i], us.tolist(), vs.tolist()):
            r = fut.result()
            assert (r.dist, r.d_top) == expected[(u, v)], (u, v)
    assert svc.stats["submitted"] == sum(len(u) for u, _ in per_thread)
