"""Vertex-sharded (billion-scale layout) serving == exact oracle, on
1-device and 8-device meshes."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import INF, QbSIndex, gnp_random_graph, grid_graph
from repro.core.baselines import bfs_spg
from repro.core.scale_serve import scale_serve


def _check(mesh, g, nl, n_q=6, seed=0):
    idx = QbSIndex.build(g, n_landmarks=nl)
    rng = np.random.default_rng(seed)
    cand = np.flatnonzero(~np.asarray(idx.scheme.is_landmark))
    us = rng.choice(cand, size=n_q).astype(np.int32)
    vs = rng.choice(cand, size=n_q).astype(np.int32)
    pairs, dist = scale_serve(g, idx.scheme, mesh, us, vs)
    for k in range(n_q):
        o = bfs_spg(g, int(us[k]), int(vs[k]))
        assert min(int(dist[k]), INF) == min(o.dist, INF), (us[k], vs[k])
        assert pairs[k] == o.edge_pairs(g), (us[k], vs[k])


def test_scale_serve_single_device_mesh():
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    _check(mesh, gnp_random_graph(50, 3.5, seed=11), nl=4)
    _check(mesh, grid_graph(6, 6), nl=3)


@pytest.mark.slow
def test_scale_serve_eight_devices_subprocess():
    script = os.path.join(os.path.dirname(__file__), "helpers", "scale_serve_check.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ALL-OK" in out.stdout
