# Makes in-test imports like ``from helpers.serving_oracle import ...``
# resolve (pytest prepends tests/ to sys.path).  dist_check.py and
# scale_serve_check.py stay standalone subprocess scripts.
