"""Subprocess helper: vertex-sharded serving exactness on 8 host devices."""
import numpy as np
import jax
from jax.sharding import Mesh

from repro.core import INF, QbSIndex, gnp_random_graph, grid_graph
from repro.core.baselines import bfs_spg
from repro.core.scale_serve import scale_serve

assert len(jax.devices()) == 8
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
for g, nl in [(gnp_random_graph(60, 3.5, seed=42), 5), (grid_graph(7, 7), 4)]:
    idx = QbSIndex.build(g, n_landmarks=nl)
    rng = np.random.default_rng(0)
    cand = np.flatnonzero(~np.asarray(idx.scheme.is_landmark))
    us = rng.choice(cand, size=8).astype(np.int32)
    vs = rng.choice(cand, size=8).astype(np.int32)
    pairs, dist = scale_serve(g, idx.scheme, mesh, us, vs)
    for k in range(8):
        o = bfs_spg(g, int(us[k]), int(vs[k]))
        assert min(int(dist[k]), INF) == min(o.dist, INF), (us[k], vs[k])
        assert pairs[k] == o.edge_pairs(g), (us[k], vs[k])
print("ALL-OK")
