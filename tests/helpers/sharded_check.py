"""Subprocess helper: vertex-sharded index bit-identity on 8 host devices.

For two small graphs on an 8-device ``("shards",)`` mesh, asserts:

* the born-sharded packed tables (labels, landmark-to-vertex table,
  meta_w, meta_dist) reassemble bit-identically to ``pack_labelling``'s
  replicated output, pad rows hold the sentinel, and the pack dtype
  matches;
* the sketch over the sharded label rows equals the sketch over the
  replicated rows, leaf for leaf;
* served results (dist + symmetrized SPG edge mask and per-query
  edge_ids) match the replicated ``QbSIndex`` oracle on every frontier
  backend (segment / csr / hybrid), with landmark lanes exercised;
* per-device label+CSR bytes are <= 1/4 of the replicated footprint.
"""
import numpy as np

import jax
from jax.sharding import Mesh

from repro.core import (
    QbSIndex,
    compute_sketch_batch,
    gnp_random_graph,
    grid_graph,
)
from repro.core.distributed import distributed_build_sharded
from repro.core.sharded import ShardedIndex

assert len(jax.devices()) == 8
mesh = Mesh(np.array(jax.devices()), ("shards",))

for g, nl in [(gnp_random_graph(60, 3.5, seed=42), 5), (grid_graph(7, 7), 4)]:
    ref = QbSIndex.build(g, n_landmarks=nl, use_pallas=False)
    lms = np.asarray(ref.scheme.landmarks)
    packed = ref.packed
    v = g.n_vertices

    # --- build bit-identity: reassemble the sharded tables on host
    sl, part = distributed_build_sharded(g, lms, mesh)
    lab_sh = np.asarray(sl.labels_sh)   # (S, v_loc, R)
    lm_sh = np.asarray(sl.lm_sh)        # (S, R, v_loc)
    lab_full = np.zeros((v, sl.n_landmarks), lab_sh.dtype)
    lm_full = np.zeros((sl.n_landmarks, v), lm_sh.dtype)
    for s in range(lab_sh.shape[0]):
        a, n = int(sl.vstart[s]), int(sl.nloc[s])
        lab_full[a:a + n] = lab_sh[s, :n]
        lm_full[:, a:a + n] = lm_sh[s, :, :n]
        assert (lab_sh[s, n:] == sl.sentinel).all(), "pad rows not sentinel"
        assert (lm_sh[s, :, n:] == sl.sentinel).all(), "pad cols not sentinel"
    assert sl.pack_dtype == packed.dtype, (sl.pack_dtype, packed.dtype)
    np.testing.assert_array_equal(lab_full, np.asarray(packed.label_dist))
    np.testing.assert_array_equal(lm_full, np.asarray(packed.lm_dist))
    np.testing.assert_array_equal(np.asarray(sl.meta_w),
                                  np.asarray(packed.meta_w))
    np.testing.assert_array_equal(np.asarray(sl.meta_dist),
                                  np.asarray(packed.meta_dist))

    rng = np.random.default_rng(0)
    us = rng.integers(0, v, 32).astype(np.int32)
    vs = rng.integers(0, v, 32).astype(np.int32)
    us[:4] = lms[:4]          # exercise the landmark lanes too
    vs[2:6] = lms[:4]

    # --- sketch bit-identity over the two label layouts
    s_ref = compute_sketch_batch(packed.label_dist[us], packed.label_dist[vs],
                                 packed.meta_w, packed.meta_dist,
                                 use_pallas=False)
    s_shd = compute_sketch_batch(lab_full[us], lab_full[vs],
                                 np.asarray(sl.meta_w),
                                 np.asarray(sl.meta_dist), use_pallas=False)
    for a, b in zip(jax.tree_util.tree_leaves(s_ref),
                    jax.tree_util.tree_leaves(s_shd)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # --- serving bit-identity vs the oracle, on all three backends
    sh = ShardedIndex.build(g, landmarks=lms, mesh=8)
    d_sh, m_sh = sh.query_batch_arrays(us, vs)
    for backend in ("segment", "csr", "hybrid"):
        rb = QbSIndex.build(g, n_landmarks=nl, use_pallas=False,
                            backend=backend)
        d_ref, m_ref = rb.query_batch_arrays(us, vs)
        np.testing.assert_array_equal(d_sh, d_ref, err_msg=f"dist {backend}")
        np.testing.assert_array_equal(m_sh, m_ref, err_msg=f"mask {backend}")

    # --- per-query edge_ids through the full SPGResult path
    res_sh = sh.query_batch(us[:8], vs[:8])
    res_ref = ref.query_batch(us[:8], vs[:8])
    for k, (a, b) in enumerate(zip(res_sh, res_ref)):
        assert a.dist == b.dist, k
        np.testing.assert_array_equal(a.edge_ids, b.edge_ids, err_msg=str(k))

    # --- the point of the exercise: per-device bytes drop ~linearly
    info = sh.sharded_size_bytes()
    assert info["n_shards"] == 8
    assert info["per_device_frac"] <= 0.25, info
    print(f"graph V={v}: per_device_frac={info['per_device_frac']:.3f}")

print("ALL-OK")
