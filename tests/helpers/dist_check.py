"""Subprocess helper: exactness of distributed labelling/serving on 8 host
devices (spawned by tests/test_distributed.py with XLA_FLAGS set)."""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import QbSIndex, build_labelling, gnp_random_graph, select_landmarks
from repro.core.baselines import bfs_spg
from repro.core.distributed import distributed_build_labelling, make_serve_step

assert len(jax.devices()) == 8, jax.devices()
g = gnp_random_graph(60, 3.5, seed=42)
landmarks = select_landmarks(g, 5)
ref = build_labelling(g, landmarks)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))

for mode in ("bool", "bitmap", "pull"):
    got = distributed_build_labelling(g, landmarks, mesh, frontier_mode=mode)
    assert (np.asarray(got.label_dist) == np.asarray(ref.label_dist)).all(), mode
    assert (np.asarray(got.meta_w) == np.asarray(ref.meta_w)).all(), mode
    assert (np.asarray(got.meta_dist) == np.asarray(ref.meta_dist)).all(), mode

idx = QbSIndex(g, ref)
serve = make_serve_step(idx.ctx, ref, mesh, n_vertices=g.n_vertices)
rng = np.random.default_rng(0)
cand = np.flatnonzero(~np.asarray(ref.is_landmark))
us = rng.choice(cand, size=32).astype(np.int32)
vs = rng.choice(cand, size=32).astype(np.int32)
mask, dist = serve(jnp.asarray(us), jnp.asarray(vs))
mask = np.asarray(mask)
for k in range(32):
    o = bfs_spg(g, int(us[k]), int(vs[k]))
    m = mask[k] | mask[k][idx._rev_edge]
    pairs = {
        (int(min(a, b)), int(max(a, b)))
        for a, b in zip(np.asarray(g.src)[m], np.asarray(g.dst)[m])
    }
    assert int(dist[k]) == o.dist, k
    assert pairs == o.edge_pairs(g), k
print("ALL-OK")
