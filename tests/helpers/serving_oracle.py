"""Seed-semantics serving oracle: pure numpy, no jax, no jit.

The retired ``QbSIndex.query_batch_legacy`` played two roles: the old-path
column in ``benchmarks/query_time.py`` (gone — the live service now
benchmarks against its own sync/async modes in
``benchmarks/serving_throughput.py``) and the bit-identity oracle for the
serving pipeline.  The oracle role lives here, as a from-scratch
reimplementation of the SPG contract the whole system must satisfy
(Theorem 5.1): ``dist`` plus the exact symmetrized set of directed
edge-slot ids lying on any shortest u-v path.  Because the contract is
exact, any correct serving path — seed loop, planner lanes, sharded step —
must be *bit-identical* to this on ``(dist, edge_ids)``.
"""
from __future__ import annotations

import numpy as np

INF = 1 << 20  # mirrors repro.core.graph.INF; kept literal so the oracle
               # stays importable without jax


def _bfs_depths(src: np.ndarray, dst: np.ndarray, n: int,
                root: int) -> np.ndarray:
    depth = np.full((n,), INF, np.int64)
    depth[root] = 0
    frontier = np.zeros((n,), bool)
    frontier[root] = True
    level = 0
    while frontier.any():
        nxt = np.zeros((n,), bool)
        nxt[dst[frontier[src]]] = True
        nxt &= depth == INF
        depth[nxt] = level + 1
        frontier = nxt
        level += 1
    return depth


def _reverse_edge_map(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    rkey = dst.astype(np.int64) * n + src.astype(np.int64)
    order = np.argsort(key, kind="stable")
    return order[np.searchsorted(key[order], rkey)]


def oracle_spg(graph, u: int, v: int) -> tuple[int, np.ndarray]:
    """One query: ``(dist, edge_ids)`` with the exact serving conventions
    (dist == INF sentinel when disconnected, 0 and no edges when u == v,
    edge ids symmetrized over both orientations)."""
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    n = graph.n_vertices
    if u == v:
        return 0, np.zeros((0,), np.int64)
    du = _bfs_depths(src, dst, n, u)
    dv = _bfs_depths(src, dst, n, v)
    d = int(du[v])
    if d >= INF:
        return INF, np.zeros((0,), np.int64)
    mask = (du[src] + 1 + dv[dst]) == d
    mask |= mask[_reverse_edge_map(src, dst, n)]
    return d, np.flatnonzero(mask)


def oracle_query_batch(graph, us, vs) -> list[tuple[int, np.ndarray]]:
    return [oracle_spg(graph, int(u), int(v)) for u, v in zip(us, vs)]


class OracleCache:
    """Memoized ``oracle_spg`` over one graph, keyed on the canonical
    pair.  Randomized serving traces are duplicate-heavy by design (the
    dedup/join paths are what they fuzz), so the property harness checks
    every future against this instead of re-running two BFSs per
    duplicate."""

    def __init__(self, graph):
        self.graph = graph
        self._memo: dict[tuple[int, int], tuple[int, np.ndarray]] = {}

    def spg(self, u: int, v: int) -> tuple[int, np.ndarray]:
        key = (min(u, v), max(u, v))
        got = self._memo.get(key)
        if got is None:
            got = self._memo[key] = oracle_spg(self.graph, u, v)
        return got

    def assert_result(self, res) -> None:
        """One SPGResult (orientation-preserving) vs the oracle."""
        d, eids = self.spg(res.u, res.v)
        assert res.dist == d, (res.u, res.v, res.dist, d)
        assert np.array_equal(np.asarray(res.edge_ids), eids), (res.u, res.v)


class EpochOracle:
    """Per-epoch oracle over a dynamic graph (DESIGN.md §13).

    One ``Graph`` snapshot per epoch, plus an *independently* maintained
    canonical edge set per epoch: ``advance`` re-derives the post-update
    edge set with plain set algebra (self-loops dropped, phantom
    inserts/deletes are no-ops, an insert wins a same-batch tie — the
    documented ``apply_update`` semantics) and asserts the system's
    epoch graph matches it exactly, so the graph-mutation layer is
    checked against the oracle too, not trusted.  Queries then answer by
    memoized numpy BFS (``oracle_spg``) on the epoch's snapshot — the
    snapshot is what fixes edge-slot numbering, which the bit-identity
    contract on ``edge_ids`` is stated in."""

    def __init__(self, graph):
        self._graphs = [graph]
        self._edges = [self._pairs(graph)]
        self._memo: dict[tuple[int, int, int], tuple[int, np.ndarray]] = {}

    @staticmethod
    def _pairs(graph) -> frozenset:
        src = np.asarray(graph.src)
        dst = np.asarray(graph.dst)
        m = src < dst                      # one canonical slot per edge
        return frozenset(zip(src[m].tolist(), dst[m].tolist()))

    @staticmethod
    def _canon(pairs) -> set:
        return {(min(int(a), int(b)), max(int(a), int(b)))
                for a, b in (pairs or []) if int(a) != int(b)}

    @property
    def epoch(self) -> int:
        return len(self._graphs) - 1

    def at(self, epoch: int):
        """The ``Graph`` snapshot serving that epoch."""
        return self._graphs[epoch]

    def advance(self, graph_new, inserts=None, deletes=None) -> int:
        """Register the next epoch's graph, asserting it equals the
        oracle's own edge algebra for the update batch.  Returns the new
        epoch number."""
        ins = self._canon(inserts)
        dels = self._canon(deletes)
        want = (self._edges[-1] | ins) - (dels - ins)   # inserts win ties
        got = self._pairs(graph_new)
        assert got == want, (
            f"epoch {self.epoch + 1} graph disagrees with the oracle edge "
            f"algebra: extra={sorted(got - want)} missing={sorted(want - got)}")
        self._graphs.append(graph_new)
        self._edges.append(frozenset(want))
        return self.epoch

    def spg(self, u: int, v: int, epoch: int) -> tuple[int, np.ndarray]:
        key = (min(u, v), max(u, v), epoch)
        got = self._memo.get(key)
        if got is None:
            got = self._memo[key] = oracle_spg(self._graphs[epoch], u, v)
        return got

    def assert_future(self, fut) -> None:
        """One resolved ``QueryFuture`` vs the oracle *at the epoch the
        future resolved under* — the §13 pinning contract."""
        assert fut.done(), (fut.u, fut.v)
        assert fut.epoch is not None, (fut.u, fut.v)
        res = fut.result()
        d, eids = self.spg(res.u, res.v, fut.epoch)
        assert res.dist == d, (res.u, res.v, fut.epoch, res.dist, d)
        assert np.array_equal(np.asarray(res.edge_ids), eids), \
            (res.u, res.v, fut.epoch)


def assert_bit_identical(graph, results, us, vs) -> None:
    """Assert a list of SPGResults matches the oracle bit-for-bit on
    (u, v, dist, edge_ids)."""
    assert len(results) == len(us)
    for r, u, v, (d, eids) in zip(results, us, vs,
                                  oracle_query_batch(graph, us, vs)):
        assert (r.u, r.v) == (int(u), int(v))
        assert r.dist == d, (r.u, r.v, r.dist, d)
        assert np.array_equal(np.asarray(r.edge_ids), eids), (r.u, r.v)
