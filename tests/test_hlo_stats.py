"""Unit tests for the HLO collective-bytes parser and roofline helpers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_stats import _shape_bytes, collective_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert _shape_bytes("f32[100]") == 400
    assert _shape_bytes("(f32[4], s8[16])") == 16 + 16
    assert _shape_bytes("pred[]") == 1
    assert _shape_bytes("token[]") == 0


def test_collective_parse_synthetic():
    hlo = """
HloModule m
  %ar = bf16[1024,8]{1,0} all-reduce(%x), replica_groups={}
  %ag = f32[64]{0} all-gather(%y), dimensions={0}
  %rs = f32[32]{0} reduce-scatter(%z), dimensions={0}
  %a2a = (s8[16], s8[16]) all-to-all(%p, %q)
  %cp = u32[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %cps = u32[128]{0} collective-permute-start(%w)
  %add = f32[2] add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 1024 * 8 * 2
    assert out["all-gather"] == 256
    assert out["reduce-scatter"] == 128
    assert out["all-to-all"] == 32
    # -start counted once, plain counted once
    assert out["collective-permute"] == 2 * 128 * 4
    assert out["_counts"]["all-reduce"] == 1


def test_collective_parse_real_program():
    """psum under shard_map must show up as all-reduce bytes."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))

    def f(a):
        return jax.lax.psum(a, "x")

    fn = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P()))
    lowered = fn.lower(jax.ShapeDtypeStruct((256,), jnp.float32))
    text = lowered.compile().as_text()
    out = collective_bytes(text)
    assert out.get("all-reduce", 0) >= 256 * 4


def test_param_count_sanity():
    from benchmarks.roofline import _param_count
    from repro.configs import get_config

    n, a = _param_count(get_config("deepseek-7b"))
    assert 6e9 < n < 8.5e9 and a == n
    n, a = _param_count(get_config("qwen1.5-32b"))
    assert 28e9 < n < 37e9
    n, a = _param_count(get_config("dbrx-132b"))
    assert 110e9 < n < 145e9
    assert 25e9 < a < 45e9  # top-4 of 16 experts + attention
    n, a = _param_count(get_config("phi3.5-moe-42b-a6.6b"))
    assert 38e9 < n < 46e9
    assert 5e9 < a < 9e9
    n, a = _param_count(get_config("rwkv6-1.6b"))
    assert 1.2e9 < n < 2.2e9
