"""Property-based serving fuzz harness (DESIGN.md §8).

The serving policy surface is now a product space — admission (fixed /
adaptive widths) x QoS (classes, weights, deadlines) x cache (off / lru /
hub eviction, all / reuse admission) x async depth x duplicate joins x
interleaved drains/polls/clock jumps — far too many corners for
example-based tests.  This harness drives *randomized arrival traces*
through ``StreamingService`` on randomized small graphs and checks, for
every configuration drawn:

* **bit-identity**: every resolved future matches the pure-numpy serving
  oracle (``tests/helpers/serving_oracle.py``) on ``(dist, edge_ids)``;
* **future resolution**: after the final drain nothing is pending or in
  flight and every future is done; duplicates of one canonical pair
  resolved identically;
* **no starvation / deadline bound**: every recorded admission wait of a
  deadline class is ``<= max_wait`` — in *simulated* time through the
  injected ``ManualClock``, so the whole suite runs without a single
  wall-clock sleep;
* **accounting**: submitted == trivial + cache hits + joins + admitted
  unique pairs, and the service's lane counters agree.

Two drivers share one trace generator: a deterministic seed sweep that
always runs in tier-1 (>= 50 examples, hypothesis not required), and a
hypothesis ``@given`` wrapper that explores/shrinks the same space when
hypothesis is installed (examples budget scales via
``QBS_PROPERTY_EXAMPLES_SCALE`` — bumped in the nightly CI job).

Graphs are padded to fixed ``(V, E)`` buckets so examples reuse jit cache
entries, and index builds are memoized per graph seed.
"""
import functools
import os

import numpy as np
import pytest

from helpers.serving_oracle import EpochOracle, OracleCache

from repro.core import QbSIndex, from_edges
from repro.serving import (
    AdmissionPolicy,
    ManualClock,
    MetricsRegistry,
    QoSClass,
    ReplicaRouter,
    StreamingService,
)

V_BUCKET = 32
E_BUCKET = 256          # directed slots
N_GRAPH_SEEDS = 6       # distinct (graph, index) builds, memoized
_SCALE = max(1, int(os.environ.get("QBS_PROPERTY_EXAMPLES_SCALE", "1")))

# the whole policy-surface catalog the fuzzer draws from; chunk widths
# stay on a tiny ladder so every (index, width) lane compiles once
QOS_CONFIGS = (
    None,                                                    # legacy default
    (QoSClass("interactive", max_wait=0.02, weight=4.0),
     QoSClass("batch", max_wait=None, weight=1.0)),
    (QoSClass("now", max_wait=0.0, weight=1.0),
     QoSClass("soon", max_wait=0.05, weight=2.0),
     QoSClass("whenever", max_wait=0.5, weight=0.5)),
    (QoSClass("a", max_wait=0.01, weight=1.0),
     QoSClass("b", max_wait=0.01, weight=1.0)),
)
POLICIES = (
    AdmissionPolicy(adaptive=True, min_chunk=2, max_chunk=8),
    AdmissionPolicy(adaptive=False, chunk=4, min_chunk=2, max_chunk=8),
    AdmissionPolicy(adaptive=True, chunk=2, min_chunk=2, max_chunk=4),
)
CACHES = (
    {},
    {"cache_size": 8},
    {"cache_size": 8, "cache_policy": "hub"},
    {"cache_size": 8, "cache_admission": "reuse"},
    {"cache_size": 8, "cache_policy": "hub", "cache_admission": "reuse"},
)
DTS = (0.0, 0.005, 0.02, 0.1, 0.6)


@functools.lru_cache(maxsize=None)
def _built(graph_seed: int, backend: str = "segment"):
    """(graph, index) for one fuzz graph seed — memoized because the
    index build (and its per-index jit cache) dominates example cost."""
    rng = np.random.default_rng(1000 + graph_seed)
    n = int(rng.integers(8, V_BUCKET))
    m = int(rng.integers(n, 2 * n))
    edges = rng.integers(0, n, size=(m, 2))
    g = from_edges(edges, n, pad_vertices_to=V_BUCKET, pad_edges_to=E_BUCKET)
    deg = np.asarray(g.degrees())[:n]
    nl = int(rng.integers(1, 5))
    landmarks = np.sort(np.argsort(-deg)[:nl]).astype(np.int32)
    return g, n, QbSIndex.build(g, landmarks=landmarks, chunk=4,
                                backend=backend)


def _run_trace(seed: int, n_ops: int = 24) -> None:
    """One fuzz example: draw a config + arrival trace from ``seed``, run
    it, assert every invariant.  Deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    g, n, idx = _built(int(rng.integers(N_GRAPH_SEEDS)))
    qos = QOS_CONFIGS[int(rng.integers(len(QOS_CONFIGS)))]
    clk = ManualClock()
    st = StreamingService(
        idx, policy=POLICIES[int(rng.integers(len(POLICIES)))],
        qos=qos, clock=clk,
        async_depth=int(rng.integers(1, 3)),
        **CACHES[int(rng.integers(len(CACHES)))])
    names = [c.name for c in st.qos_classes]
    max_wait = {c.name: c.max_wait for c in st.qos_classes}

    futs: list = []
    recent: list[tuple[int, int]] = []

    def draw_pair():
        if recent and rng.random() < 0.3:       # duplicate (maybe swapped)
            u, v = recent[int(rng.integers(len(recent)))]
            return (v, u) if rng.random() < 0.5 else (u, v)
        u, v = int(rng.integers(n)), int(rng.integers(n))
        recent.append((u, v))
        return u, v

    for _ in range(n_ops):
        r = rng.random()
        if r < 0.45:
            u, v = draw_pair()
            futs.append(st.submit(u, v, qos=names[int(rng.integers(len(names)))]))
        elif r < 0.60:
            pairs = [draw_pair() for _ in range(int(rng.integers(2, 7)))]
            futs.extend(st.submit_batch(
                [p[0] for p in pairs], [p[1] for p in pairs],
                qos=names[int(rng.integers(len(names)))]))
        elif r < 0.80:
            clk.advance(DTS[int(rng.integers(len(DTS)))])
        elif r < 0.88:
            st.drain()
        elif r < 0.95:
            st.poll()
        elif futs:
            f = futs[int(rng.integers(len(futs)))]
            f.result()                          # implicit drain; idempotent
            assert f.done()
    st.drain()

    # future resolution: everything resolved, nothing left anywhere
    assert st.n_pending == 0 and st.n_inflight == 0
    assert not st._waiting and not st._pending and not st._deadline
    assert not st._flight
    assert all(f.done() for f in futs)

    # bit-identity vs the numpy oracle, every future, original orientation
    oracle = OracleCache(g)
    by_key: dict[tuple[int, int], list] = {}
    for f in futs:
        res = f.result()
        oracle.assert_result(res)
        by_key.setdefault((min(f.u, f.v), max(f.u, f.v)), []).append(res)
    # duplicates of a canonical pair resolved identically
    for group in by_key.values():
        for r in group[1:]:
            assert r.dist == group[0].dist
            assert np.array_equal(r.edge_ids, group[0].edge_ids)

    # no starvation: admission waits never exceed the class deadline
    # (simulated clock: deadline fires stamp the admission *at* the bound)
    for name in names:
        mw = max_wait[name]
        waits = st.qos_stats[name]["waits"]
        assert all(w >= 0 for w in waits)
        if mw is not None:
            assert all(w <= mw + 1e-9 for w in waits), (name, mw, max(waits))

    # accounting: every submission resolved through exactly one path
    # (handed_off is 0 on a lone service — the term keeps the identity
    # shared with the per-replica checks below)
    s = st.stats
    fresh = (s["submitted"] - s["trivial"] - s["cache_hits"] - s["joined"]
             - s["handed_off"])
    assert s["admitted_pairs"] == fresh
    assert sum(st.qos_stats[nm]["admitted"] for nm in names) == fresh
    assert sum(st.service.lane_served) == \
        s["trivial"] + s["cache_hits"] + s["admitted_pairs"]
    assert len(futs) == s["submitted"]

    # observability: the registry snapshot is exactly the live counters,
    # and every resolution recorded exactly one latency observation
    reg = MetricsRegistry()
    reg.register("svc", st)
    snap = reg.snapshot()["svc"]
    assert snap["stats"] == dict(s)
    for name in names:
        assert st.lat_hist[name].total == st.qos_stats[name]["submitted"]
        assert snap["latency_us"][name] == st.lat_hist[name].snapshot()
    assert sum(h.total for h in st.lat_hist.values()) == s["submitted"]


# -- tier-1 driver: deterministic, >= 50 examples, no hypothesis needed ------


@pytest.mark.parametrize("seed", range(56 * _SCALE))
def test_streaming_trace_properties(seed):
    _run_trace(seed)


# -- dynamic-update fuzz: interleaved update+query traces (§13) --------------


def _run_update_trace(seed: int, n_ops: int = 22) -> None:
    """One dynamic-graph fuzz example: the streaming trace space plus
    random mid-trace edge-update batches (``submit_update`` — inserts,
    deletes, mixed, phantom-heavy; churn thresholds drawn so both the
    incremental and full-rebuild branches serve), on a drawn relay
    backend.  Every future is checked against the per-epoch-rebuild
    numpy oracle *at the epoch it resolved under* — the §13 pinning
    contract — and duplicates of one (pair, epoch) resolved identically.
    """
    rng = np.random.default_rng(50_000 + seed)
    backend = ("segment", "csr", "hybrid")[int(rng.integers(3))]
    g, n, idx = _built(int(rng.integers(N_GRAPH_SEEDS)), backend)
    clk = ManualClock()
    st = StreamingService(
        idx, policy=POLICIES[int(rng.integers(len(POLICIES)))],
        qos=QOS_CONFIGS[int(rng.integers(len(QOS_CONFIGS)))], clock=clk,
        async_depth=int(rng.integers(1, 3)),
        **CACHES[int(rng.integers(len(CACHES)))])
    names = [c.name for c in st.qos_classes]
    oracle = EpochOracle(g)

    futs: list = []
    recent: list[tuple[int, int]] = []

    def draw_pair():
        if recent and rng.random() < 0.35:
            u, v = recent[int(rng.integers(len(recent)))]
            return (v, u) if rng.random() < 0.5 else (u, v)
        u, v = int(rng.integers(n)), int(rng.integers(n))
        recent.append((u, v))
        return u, v

    def draw_update():
        from repro.core.graph import edge_set
        ins, dels = [], []
        present = [tuple(int(x) for x in e) for e in edge_set(st.index.graph)]
        for _ in range(int(rng.integers(1, 3))):
            if rng.random() < 0.5 and present:
                dels.append(present[int(rng.integers(len(present)))])
            else:
                a, b = int(rng.integers(n)), int(rng.integers(n))
                if a != b:
                    ins.append((a, b))       # may be present: phantom no-op
        return ins, dels

    for _ in range(n_ops):
        r = rng.random()
        if r < 0.35:
            u, v = draw_pair()
            futs.append(st.submit(u, v,
                                  qos=names[int(rng.integers(len(names)))]))
        elif r < 0.50:
            pairs = [draw_pair() for _ in range(int(rng.integers(2, 6)))]
            futs.extend(st.submit_batch(
                [p[0] for p in pairs], [p[1] for p in pairs],
                qos=names[int(rng.integers(len(names)))]))
        elif r < 0.65:                       # the update op
            ins, dels = draw_update()
            churn = (0.0, 0.6, 1.1)[int(rng.integers(3))]
            new = st.submit_update(inserts=ins, deletes=dels,
                                   churn_threshold=churn)
            oracle.advance(new.graph, inserts=ins, deletes=dels)
            assert st.index.epoch == oracle.epoch
        elif r < 0.80:
            clk.advance(DTS[int(rng.integers(len(DTS)))])
        elif r < 0.88:
            st.drain()
        elif r < 0.95:
            st.poll()
        elif futs:
            futs[int(rng.integers(len(futs)))].result()
    st.drain()

    assert st.n_pending == 0 and st.n_inflight == 0
    assert not st._waiting and not st._pending and not st._flight
    assert st.stats["updates"] == oracle.epoch

    by_key: dict[tuple[int, int, int], list] = {}
    for f in futs:
        oracle.assert_future(f)              # per-epoch bit-identity
        by_key.setdefault((min(f.u, f.v), max(f.u, f.v), f.epoch),
                          []).append(f.result())
    # duplicates of one (pair, epoch) resolved identically
    for group in by_key.values():
        for r in group[1:]:
            assert r.dist == group[0].dist
            assert np.array_equal(r.edge_ids, group[0].edge_ids)

    # the accounting identity survives epoch churn
    s = st.stats
    assert s["admitted_pairs"] == (s["submitted"] - s["trivial"]
                                   - s["cache_hits"] - s["joined"]
                                   - s["handed_off"])
    st.close()


@pytest.mark.parametrize("seed", range(18 * _SCALE))
def test_update_trace_properties(seed):
    _run_update_trace(seed)


# -- replica-tier fuzz: the same invariants through a ReplicaRouter ----------


def _run_router_trace(seed: int, n_ops: int = 24) -> None:
    """One replica-tier fuzz example: the streaming trace space plus
    random mid-trace ``drain_replica``/``restore_replica`` (rolling
    restarts), on 3 replicas with lockstep ``ManualClock``s.  Checks
    oracle bit-identity, duplicate consistency, per-replica accounting
    (including ``handed_off``), and the per-class wait bounds — handed-off
    pairs keep their deadlines on the adopter."""
    rng = np.random.default_rng(10_000 + seed)
    g, n, idx = _built(int(rng.integers(N_GRAPH_SEEDS)))
    qos = QOS_CONFIGS[int(rng.integers(len(QOS_CONFIGS)))]
    n_rep = 3
    clks = [ManualClock() for _ in range(n_rep)]
    router = ReplicaRouter(
        idx, n_replicas=n_rep, clocks=clks,
        policy=POLICIES[int(rng.integers(len(POLICIES)))],
        qos=qos, async_depth=int(rng.integers(1, 3)),
        **CACHES[int(rng.integers(len(CACHES)))])
    names = [c.name for c in router.replicas[0].qos_classes]
    max_wait = {c.name: c.max_wait for c in router.replicas[0].qos_classes}

    futs: list = []
    recent: list[tuple[int, int]] = []

    def draw_pair():
        if recent and rng.random() < 0.3:
            u, v = recent[int(rng.integers(len(recent)))]
            return (v, u) if rng.random() < 0.5 else (u, v)
        u, v = int(rng.integers(n)), int(rng.integers(n))
        recent.append((u, v))
        return u, v

    for _ in range(n_ops):
        r = rng.random()
        if r < 0.40:
            u, v = draw_pair()
            futs.append(router.submit(
                u, v, qos=names[int(rng.integers(len(names)))]))
        elif r < 0.55:
            pairs = [draw_pair() for _ in range(int(rng.integers(2, 7)))]
            futs.extend(router.submit_batch(
                [p[0] for p in pairs], [p[1] for p in pairs],
                qos=names[int(rng.integers(len(names)))]))
        elif r < 0.72:
            dt = DTS[int(rng.integers(len(DTS)))]
            for c in clks:                          # lockstep time base
                c.advance(dt)
        elif r < 0.80:
            router.drain()
        elif r < 0.86:
            router.poll()
        elif r < 0.96:                              # rolling restart step
            live = router.live_replicas()
            down = [i for i in range(n_rep) if i not in live]
            if down and rng.random() < 0.5:
                router.restore_replica(down[int(rng.integers(len(down)))])
            elif len(live) > 1:
                router.drain_replica(live[int(rng.integers(len(live)))])
        elif futs:
            f = futs[int(rng.integers(len(futs)))]
            f.result()
            assert f.done()
    router.drain()

    for rep in router.replicas:
        assert rep.n_pending == 0 and rep.n_inflight == 0
        assert not rep._waiting and not rep._pending and not rep._flight
    assert all(f.done() for f in futs)

    oracle = OracleCache(g)
    by_key: dict[tuple[int, int], list] = {}
    for f in futs:
        res = f.result()
        oracle.assert_result(res)
        by_key.setdefault((min(f.u, f.v), max(f.u, f.v)), []).append(res)
    for group in by_key.values():
        for r in group[1:]:
            assert r.dist == group[0].dist
            assert np.array_equal(r.edge_ids, group[0].edge_ids)

    for rep in router.replicas:
        s = rep.stats
        fresh = (s["submitted"] - s["trivial"] - s["cache_hits"]
                 - s["joined"] - s["handed_off"])
        assert s["admitted_pairs"] == fresh, dict(s)
        for name in names:
            mw = max_wait[name]
            waits = rep.qos_stats[name]["waits"]
            assert all(w >= 0 for w in waits)
            if mw is not None:
                assert all(w <= mw + 1e-9 for w in waits), \
                    (name, mw, max(waits))
    # every routed future resolved (and recorded its latency) exactly
    # once tier-wide, wherever handoffs re-homed it
    assert router.stats["routed"] == len(futs)
    assert sum(h.total for rep in router.replicas
               for h in rep.lat_hist.values()) == len(futs)
    router.close()


@pytest.mark.parametrize("seed", range(14 * _SCALE))
def test_replica_router_trace_properties(seed):
    _run_router_trace(seed)


# -- hypothesis driver: explores/shrinks the same space ----------------------

try:
    from hypothesis import given, settings, strategies as hyp_st
    _HAVE_HYPOTHESIS = True
except ImportError:                             # container without the extra:
    _HAVE_HYPOTHESIS = False                    # the sweep above still runs


if _HAVE_HYPOTHESIS:

    @given(seed=hyp_st.integers(min_value=0, max_value=2**31 - 1),
           n_ops=hyp_st.integers(min_value=1, max_value=40))
    @settings(max_examples=25 * _SCALE, deadline=None)
    def test_streaming_trace_properties_hypothesis(seed, n_ops):
        _run_trace(seed, n_ops=n_ops)
