"""qbslint — repo-invariant static analysis for the QbS reproduction.

The paper's exactness guarantee survives only while every layer of this
repo preserves a handful of invariants that plain pytest cannot see
until they are already broken at runtime: all ``shard_map`` goes
through ``repro.compat`` (ROADMAP standing constraint), serving time
flows only through the injectable clock (DESIGN.md §8), cache inserts
go only through ``ServingService.cache_put``, and ``StreamingService``
state is ``_lock``-guarded across timer threads.  qbslint turns each of
those conventions into a machine-checked rule over the stdlib ``ast``:

=======  ==============================================================
QBS001   ``shard_map`` imported/used outside ``src/repro/compat.py``
QBS002   wall-clock (``time.time``/``monotonic``/``sleep``,
         ``threading.Timer``) in ``serving/`` outside ``clock.py``
QBS003   host-sync calls (``.item()``, ``int()``/``float()`` on
         non-literal args, ``np.asarray``, ``block_until_ready``,
         ``jax.device_get``) inside a jitted function body
QBS004   ``jax.jit(...)`` constructed inside a loop or per-call
         function body (silent recompile churn on the hot path)
QBS005   mutation of a declared guarded field
         (``_QBS_GUARDED_FIELDS``) outside ``with self._lock``
QBS006   ``ResultCache`` writes bypassing ``ServingService.cache_put``
=======  ==============================================================

Run it as ``python -m tools.qbslint src`` (exit 0 = clean).  Suppress a
deliberate violation inline with ``# qbslint: disable=QBS003`` on the
flagged line, or file-wide with ``# qbslint: disable-file=QBS001`` on
any line; a method whose contract is "caller holds the lock" is marked
``# qbslint: locked`` on its ``def`` line (the runtime sanitizer,
``repro.serving.debug``, verifies those markers don't lie).

The rule catalogue with rationale lives in DESIGN.md §9.
"""
from .core import Finding, LintError, lint_file, lint_paths, lint_source
from .rules import ALL_RULES

__all__ = [
    "ALL_RULES",
    "Finding",
    "LintError",
    "lint_file",
    "lint_paths",
    "lint_source",
]
