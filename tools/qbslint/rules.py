"""The nine QbS repo-invariant rules (see DESIGN.md §9 for rationale).

Every rule is a pure function of one parsed module.  Shared machinery:
``_Aliases`` resolves local names through the file's imports (``import
numpy as np`` makes ``np.asarray`` resolve to ``numpy.asarray``), and
``_dotted`` renders ``a.b.c`` attribute chains.  Rules are deliberately
first-order — no cross-file inference, no type inference — because the
invariants they encode are *syntactic by design*: the repo routes
``shard_map`` through one module, time through one clock, cache inserts
through one method, so the correct program never needs the flagged
constructs outside their home files.
"""
from __future__ import annotations

import ast
from typing import Iterable

from .core import Finding, Module


def _dotted(node: ast.AST) -> str | None:
    """Render a Name/Attribute chain as ``a.b.c`` (None otherwise)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Aliases:
    """Local name -> fully qualified module/attr, from the file's imports."""

    def __init__(self, tree: ast.Module):
        self.map: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.map[a.asname] = a.name
                    else:
                        root = a.name.split(".")[0]
                        self.map.setdefault(root, root)
            elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                    and node.module:
                for a in node.names:
                    self.map[a.asname or a.name] = f"{node.module}.{a.name}"

    def resolve(self, node: ast.AST) -> str | None:
        d = _dotted(node)
        if d is None:
            return None
        head, _, rest = d.partition(".")
        full = self.map.get(head, head)
        return f"{full}.{rest}" if rest else full


class Rule:
    id = ""
    summary = ""

    def applies(self, path: str) -> bool:
        return True

    def check(self, mod: Module) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, mod: Module, node: ast.AST, message: str) -> Finding:
        return Finding(path=mod.path, line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0), rule=self.id,
                       message=message)


# ---------------------------------------------------------------------------
# QBS001 — shard_map only via repro.compat
# ---------------------------------------------------------------------------


class ShardMapViaCompat(Rule):
    id = "QBS001"
    summary = ("jax shard_map imported/used outside compat.py — route it "
               "through repro.compat.shard_map (owns check_rep=False on "
               "the 0.4.x experimental API)")
    _TARGETS = {"jax.shard_map", "jax.experimental.shard_map"}
    _MSG = ("direct shard_map use; import it from repro.compat instead "
            "(ROADMAP standing constraint: the shim owns the 0.4.x "
            "check_rep/API-drift handling)")

    def applies(self, path: str) -> bool:
        return not path.endswith("compat.py")

    def check(self, mod: Module) -> Iterable[Finding]:
        aliases = _Aliases(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "jax.experimental.shard_map" or \
                            a.name.startswith("jax.experimental.shard_map."):
                        yield self.finding(mod, node, self._MSG)
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                m = node.module or ""
                names = {a.name for a in node.names}
                if m == "jax.experimental.shard_map" or \
                        (m in ("jax", "jax.experimental")
                         and "shard_map" in names):
                    yield self.finding(mod, node, self._MSG)
            elif isinstance(node, ast.Attribute):
                if aliases.resolve(node) in self._TARGETS:
                    yield self.finding(mod, node, self._MSG)


# ---------------------------------------------------------------------------
# QBS002 — serving time flows only through the injectable clock
# ---------------------------------------------------------------------------


class WallClockInServing(Rule):
    id = "QBS002"
    summary = ("wall-clock call in serving/ outside clock.py — all serving "
               "time goes through the injectable clock (DESIGN.md §8)")
    _BANNED = {"time.time", "time.monotonic", "time.sleep",
               "threading.Timer"}
    _EXEMPT_FILES = {"clock.py"}

    def applies(self, path: str) -> bool:
        return ("/serving/" in f"/{path}"
                and path.rsplit("/", 1)[-1] not in self._EXEMPT_FILES)

    def _msg(self, what: str) -> str:
        return (f"{what} in serving code; use the injected clock "
                f"(serving.clock) so deadlines stay testable in simulated "
                f"time")

    def check(self, mod: Module) -> Iterable[Finding]:
        aliases = _Aliases(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ImportFrom) and node.level == 0:
                m = node.module or ""
                for a in node.names:
                    if f"{m}.{a.name}" in self._BANNED:
                        yield self.finding(mod, node,
                                           self._msg(f"{m}.{a.name}"))
            elif isinstance(node, ast.Attribute):
                full = aliases.resolve(node)
                if full in self._BANNED:
                    yield self.finding(mod, node, self._msg(full))


# ---------------------------------------------------------------------------
# QBS003 — no host syncs inside jitted bodies
# ---------------------------------------------------------------------------


def _is_jit(aliases: _Aliases, node: ast.AST) -> bool:
    return aliases.resolve(node) == "jax.jit"


def _jit_decorated(aliases: _Aliases, fn: ast.AST) -> bool:
    """Is ``fn`` decorated with jax.jit / partial(jax.jit, ...)?"""
    for d in getattr(fn, "decorator_list", []):
        if _is_jit(aliases, d):
            return True
        if isinstance(d, ast.Call):
            if _is_jit(aliases, d.func):
                return True
            if aliases.resolve(d.func) in ("functools.partial", "partial") \
                    and d.args and _is_jit(aliases, d.args[0]):
                return True
    return False


class HostSyncInJit(Rule):
    id = "QBS003"
    summary = ("host-sync call inside a jitted function body (.item(), "
               "int()/float() on arrays, np.asarray, block_until_ready, "
               "device_get) — breaks async dispatch / fails under tracing")

    def check(self, mod: Module) -> Iterable[Finding]:
        aliases = _Aliases(mod.tree)
        contexts: list[ast.AST] = []
        defs_by_name: dict[str, list[ast.AST]] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs_by_name.setdefault(node.name, []).append(node)
                if _jit_decorated(aliases, node):
                    contexts.append(node)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and _is_jit(aliases, node.func) \
                    and node.args:
                wrapped = node.args[0]
                if isinstance(wrapped, ast.Lambda):
                    contexts.append(wrapped)
                elif isinstance(wrapped, ast.Name):
                    contexts.extend(defs_by_name.get(wrapped.id, []))

        seen: set[tuple[int, int]] = set()
        for ctx in contexts:
            body = ctx.body if isinstance(ctx.body, list) else [ctx.body]
            for stmt in body:
                for f in self._scan(mod, aliases, stmt):
                    key = (f.line, f.col)
                    if key not in seen:
                        seen.add(key)
                        yield f

    def _scan(self, mod: Module, aliases: _Aliases,
              root: ast.AST) -> Iterable[Finding]:
        for node in ast.walk(root):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr == "item" \
                    and not node.args:
                yield self.finding(mod, node, "'.item()' forces a host "
                                   "sync inside a jitted body")
            elif isinstance(fn, ast.Attribute) \
                    and fn.attr == "block_until_ready":
                yield self.finding(mod, node, "'block_until_ready' inside "
                                   "a jitted body")
            else:
                full = aliases.resolve(fn)
                if full == "jax.device_get":
                    yield self.finding(mod, node, "'jax.device_get' inside "
                                       "a jitted body")
                elif full in ("numpy.asarray", "numpy.array"):
                    yield self.finding(
                        mod, node,
                        f"'{full}' materializes on host inside a jitted "
                        f"body; use jnp")
                elif isinstance(fn, ast.Name) and fn.id in ("int", "float") \
                        and node.args \
                        and not all(isinstance(a, ast.Constant)
                                    for a in node.args):
                    yield self.finding(
                        mod, node,
                        f"'{fn.id}()' on a traced value host-syncs (or "
                        f"raises) inside a jitted body; use jnp casts")


# ---------------------------------------------------------------------------
# QBS004 — jit construction off the setup path
# ---------------------------------------------------------------------------


class JitInHotPath(Rule):
    id = "QBS004"
    summary = ("jax.jit(...) constructed inside a loop or per-call function "
               "body — every construction starts a fresh compile cache "
               "(silent recompile churn on the serving hot path)")
    # "main" is a once-per-process entry point: constructing the jit
    # there (before any loop) is setup, not per-call churn
    _ALLOWED_NAMES = {"__init__", "__post_init__", "__new__",
                      "__init_subclass__", "__set_name__", "main"}
    _ALLOWED_PREFIXES = ("make_", "_make_", "build", "_build",
                         "lower_", "_lower")
    _CACHE_DECOS = {"functools.lru_cache", "functools.cache",
                    "functools.cached_property"}

    def check(self, mod: Module) -> Iterable[Finding]:
        aliases = _Aliases(mod.tree)
        out: list[Finding] = []

        def allowed(fn: ast.AST) -> bool:
            name = fn.name
            if name in self._ALLOWED_NAMES or \
                    name.startswith(self._ALLOWED_PREFIXES):
                return True
            for d in fn.decorator_list:
                base = d.func if isinstance(d, ast.Call) else d
                if aliases.resolve(base) in self._CACHE_DECOS:
                    return True
            return False

        def visit(node: ast.AST, func_frames: tuple, in_loop: bool) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for d in node.decorator_list:
                    visit(d, func_frames, in_loop)
                frames = func_frames + (allowed(node),)
                for child in node.body:
                    visit(child, frames, False)
                return
            if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
                for child in ast.iter_child_nodes(node):
                    visit(child, func_frames, True)
                return
            if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                 ast.GeneratorExp)):
                for child in ast.iter_child_nodes(node):
                    visit(child, func_frames, True)
                return
            if isinstance(node, ast.Call) and _is_jit(aliases, node.func):
                if in_loop:
                    out.append(self.finding(
                        mod, node, "jax.jit(...) constructed inside a loop; "
                        "hoist it to a make_*/build* factory or __init__"))
                elif func_frames and not func_frames[-1]:
                    out.append(self.finding(
                        mod, node, "jax.jit(...) constructed in a per-call "
                        "body; hoist it to a make_*/build* factory, "
                        "__init__, or an lru_cache'd helper"))
            for child in ast.iter_child_nodes(node):
                visit(child, func_frames, in_loop)

        visit(mod.tree, (), False)
        return out


# ---------------------------------------------------------------------------
# QBS005 — lock discipline over declared guarded fields
# ---------------------------------------------------------------------------


def _guard_root(expr: ast.AST) -> str | None:
    """For ``self.X``/``self.X[...]``/``self.X[...].Y...`` return ``X``."""
    prev = None
    while isinstance(expr, (ast.Subscript, ast.Attribute)):
        prev = expr
        expr = expr.value
    if isinstance(expr, ast.Name) and expr.id == "self" \
            and isinstance(prev, ast.Attribute):
        return prev.attr
    return None


def _literal_strings(node: ast.AST) -> set[str] | None:
    """String constants of a tuple/list/set literal, unwrapping
    ``frozenset({...})`` / ``set([...])`` / ``tuple((...))`` calls."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("frozenset", "set", "tuple") \
            and len(node.args) == 1:
        node = node.args[0]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        elems = node.elts
        if all(isinstance(e, ast.Constant) and isinstance(e.value, str)
               for e in elems):
            return {e.value for e in elems}
    return None


class LockDiscipline(Rule):
    id = "QBS005"
    summary = ("mutation of a _QBS_GUARDED_FIELDS field outside a "
               "'with self._lock' block (timer threads race the driver)")
    _MUTATORS = {"append", "appendleft", "extend", "extendleft", "insert",
                 "pop", "popleft", "popitem", "remove", "discard", "clear",
                 "update", "add", "setdefault", "sort", "reverse", "rotate"}
    _HEAP_FNS = {"heapq.heappush", "heapq.heappop", "heapq.heapreplace",
                 "heapq.heappushpop", "heapq.heapify"}

    def check(self, mod: Module) -> Iterable[Finding]:
        aliases = _Aliases(mod.tree)
        for cls in ast.walk(mod.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            fields = self._guarded_fields(cls)
            if not fields:
                continue
            for item in cls.body:
                if not isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if item.name == "__init__" or mod.is_locked_def(item):
                    continue
                yield from self._scan_body(mod, aliases, item.body, fields,
                                           locked=False)

    def _guarded_fields(self, cls: ast.ClassDef) -> set[str] | None:
        for item in cls.body:
            targets = []
            if isinstance(item, ast.Assign):
                targets = item.targets
            elif isinstance(item, ast.AnnAssign) and item.value is not None:
                targets = [item.target]
            for t in targets:
                if isinstance(t, ast.Name) and t.id == "_QBS_GUARDED_FIELDS":
                    return _literal_strings(item.value)
        return None

    def _is_lock_ctx(self, withitem: ast.withitem) -> bool:
        return _dotted(withitem.context_expr) == "self._lock"

    def _scan_body(self, mod: Module, aliases: _Aliases, stmts: list,
                   fields: set[str], locked: bool) -> Iterable[Finding]:
        for stmt in stmts:
            yield from self._scan_stmt(mod, aliases, stmt, fields, locked)

    def _scan_stmt(self, mod: Module, aliases: _Aliases, node: ast.AST,
                   fields: set[str], locked: bool) -> Iterable[Finding]:
        if isinstance(node, (ast.With, ast.AsyncWith)):
            now_locked = locked or any(self._is_lock_ctx(i)
                                       for i in node.items)
            if not locked:
                for i in node.items:
                    yield from self._scan_calls(mod, aliases,
                                                i.context_expr, fields)
            yield from self._scan_body(mod, aliases, node.body, fields,
                                       now_locked)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a closure may run on another thread after the lock is
            # released — conservatively treat its body as unlocked
            yield from self._scan_body(mod, aliases, node.body, fields,
                                       locked=False)
            return
        if not locked:
            # statement-level target mutations
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in self._flat_targets(targets):
                    root = _guard_root(t)
                    if root in fields:
                        yield self.finding(mod, t, self._msg(root, "write"))
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    root = _guard_root(t)
                    if root in fields:
                        yield self.finding(mod, t,
                                           self._msg(root, "delete"))
            # mutating calls anywhere in this statement's expressions
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.expr):
                    yield from self._scan_calls(mod, aliases, child, fields)
        # nested statements (If/For/Try bodies, handlers, ...)
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, ast.expr):
                yield from self._scan_stmt(mod, aliases, child, fields,
                                           locked)

    def _msg(self, field: str, how: str) -> str:
        return (f"{how} of guarded field 'self.{field}' outside "
                f"'with self._lock' (mark the method '# qbslint: locked' "
                f"if its contract is caller-holds-lock)")

    def _scan_calls(self, mod: Module, aliases: _Aliases, expr: ast.AST,
                    fields: set[str]) -> Iterable[Finding]:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr in self._MUTATORS:
                root = _guard_root(fn.value)
                if root in fields:
                    yield self.finding(
                        mod, node, self._msg(root, f"'.{fn.attr}()' call"))
            elif aliases.resolve(fn) in self._HEAP_FNS and node.args:
                root = _guard_root(node.args[0])
                if root in fields:
                    yield self.finding(
                        mod, node, self._msg(root, "heapq mutation"))

    @staticmethod
    def _flat_targets(targets: list) -> Iterable[ast.AST]:
        for t in targets:
            if isinstance(t, (ast.Tuple, ast.List)):
                yield from LockDiscipline._flat_targets(t.elts)
            elif isinstance(t, ast.Starred):
                yield t.value
            else:
                yield t


# ---------------------------------------------------------------------------
# QBS006 — all cache inserts via ServingService.cache_put
# ---------------------------------------------------------------------------


class CacheInsertBypass(Rule):
    id = "QBS006"
    summary = ("ResultCache write bypassing ServingService.cache_put — "
               "the admission policy (reuse prediction, shadow set) only "
               "sees inserts routed through cache_put")

    _INTERNALS = {"_store", "_protected"}

    def check(self, mod: Module) -> Iterable[Finding]:
        yield from self._visit(mod, mod.tree, class_stack=(), func_stack=())

    @staticmethod
    def _chain_has_cache(node: ast.AST) -> bool:
        d = _dotted(node)
        if d is None:
            return False
        return any(seg == "cache" or seg.endswith("_cache")
                   for seg in d.split("."))

    def _visit(self, mod: Module, node: ast.AST, class_stack: tuple,
               func_stack: tuple) -> Iterable[Finding]:
        if isinstance(node, ast.ClassDef):
            class_stack = class_stack + (node.name,)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            func_stack = func_stack + (node.name,)

        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "put" \
                and self._chain_has_cache(node.func.value) \
                and "cache_put" not in func_stack:
            yield self.finding(
                mod, node, "direct cache .put(); route the insert through "
                "ServingService.cache_put so the admission policy applies")
        elif isinstance(node, ast.Attribute) \
                and node.attr in self._INTERNALS \
                and "ResultCache" not in class_stack \
                and self._chain_has_cache(node.value):
            yield self.finding(
                mod, node, f"touching ResultCache internal '.{node.attr}' "
                "outside the ResultCache class; use get()/cache_put()")

        for child in ast.iter_child_nodes(node):
            yield from self._visit(mod, child, class_stack, func_stack)


# ---------------------------------------------------------------------------
# QBS007 — packed tables never widen to >= 32 bits in host code
# ---------------------------------------------------------------------------


def _jit_spans(aliases: _Aliases, tree: ast.Module) -> list[tuple[int, int]]:
    """Line spans of every jit context in the module (same collection rule
    as QBS003: jit-decorated defs, ``jax.jit(fn)`` on a named def, and
    ``jax.jit(lambda ...)``)."""
    contexts: list[ast.AST] = []
    defs_by_name: dict[str, list[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(node.name, []).append(node)
            if _jit_decorated(aliases, node):
                contexts.append(node)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jit(aliases, node.func) \
                and node.args:
            wrapped = node.args[0]
            if isinstance(wrapped, ast.Lambda):
                contexts.append(wrapped)
            elif isinstance(wrapped, ast.Name):
                contexts.extend(defs_by_name.get(wrapped.id, []))
    return [(c.lineno, getattr(c, "end_lineno", None) or c.lineno)
            for c in contexts]


class PackedWidenOnHost(Rule):
    id = "QBS007"
    summary = ("host-side widening of a packed label/cache table to >= 32 "
               "bits — packed uint8/uint16 arrays only widen in registers "
               "inside jit bodies (DESIGN.md §10); a resident int32 copy "
               "forfeits the 4x label-bandwidth win")
    # the packed-table field names (core.packing.PackedLabels and the
    # QbSIndex attributes that alias them)
    _PACKED_NAMES = {"label_dist", "meta_w", "meta_dist",
                     "lm_dist", "_lm_dist"}
    _WIDE = {"numpy.int32", "numpy.int64",
             "jax.numpy.int32", "jax.numpy.int64"}
    _WIDE_STRS = {"int32", "int64", "i4", "i8"}

    @classmethod
    def _is_packed_expr(cls, node: ast.AST) -> bool:
        while isinstance(node, ast.Subscript):
            node = node.value
        d = _dotted(node)
        if d is None:
            return False
        segs = d.split(".")
        return segs[-1] in cls._PACKED_NAMES \
            or any("packed" in s for s in segs)

    def _is_wide_dtype(self, aliases: _Aliases, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return node.value in self._WIDE_STRS
        return aliases.resolve(node) in self._WIDE

    def check(self, mod: Module) -> Iterable[Finding]:
        aliases = _Aliases(mod.tree)
        spans = _jit_spans(aliases, mod.tree)
        in_serving = "/serving/" in f"/{mod.path}"

        def in_jit(node: ast.AST) -> bool:
            line = getattr(node, "lineno", 0)
            return any(lo <= line <= hi for lo, hi in spans)

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "astype" and node.args \
                    and self._is_wide_dtype(aliases, node.args[0]) \
                    and self._is_packed_expr(node.func.value) \
                    and not in_jit(node):
                yield self.finding(
                    mod, node, "packed table widened to >= int32 in host "
                    "code; gather the packed rows and widen inside the jit "
                    "body (core.packing.widen_dist) so the int32 copy "
                    "lives in registers, not HBM")
            elif in_serving and isinstance(node, ast.Attribute) \
                    and aliases.resolve(node) == "numpy.int64" \
                    and not in_jit(node):
                yield self.finding(
                    mod, node, "np.int64 on the serving path; the serving "
                    "host tier is int32-audited (edge ids, cache values) — "
                    "if 64 bits are genuinely required, say why and add "
                    "'# qbslint: disable=QBS007'")


# ---------------------------------------------------------------------------
# QBS008 — sharded tables never gathered whole to host
# ---------------------------------------------------------------------------


class NoReplicatedGather(Rule):
    id = "QBS008"
    summary = ("host gather (jax.device_get / np.asarray) of a sharded "
               "table in serving/ or the sharded core — full-table "
               "materialization silently rebuilds the replicated copy the "
               "vertex-sharded index exists to avoid (DESIGN.md §11); "
               "declared host boundaries mark the def "
               "'# qbslint: host-boundary'")
    _GATHERS = {"jax.device_get", "numpy.asarray", "numpy.array",
                "jax.numpy.asarray", "jax.numpy.array"}
    _FILES = {"distributed.py", "sharded.py"}

    def applies(self, path: str) -> bool:
        return ("/serving/" in f"/{path}"
                or path.rsplit("/", 1)[-1] in self._FILES)

    @staticmethod
    def _is_sharded_expr(node: ast.AST) -> bool:
        """Does the (Subscript-stripped) receiver chain name a sharded
        table?  Convention (core.distributed / core.sharded): mesh-resident
        arrays carry an ``_sh`` suffix or a ``sharded`` segment."""
        while isinstance(node, ast.Subscript):
            node = node.value
        d = _dotted(node)
        if d is None:
            return False
        segs = d.split(".")
        return segs[-1].endswith("_sh") or any("sharded" in s for s in segs)

    def check(self, mod: Module) -> Iterable[Finding]:
        aliases = _Aliases(mod.tree)
        spans = [(n.lineno, getattr(n, "end_lineno", None) or n.lineno)
                 for n in ast.walk(mod.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and mod.is_host_boundary_def(n)]

        def in_boundary(node: ast.AST) -> bool:
            line = getattr(node, "lineno", 0)
            return any(lo <= line <= hi for lo, hi in spans)

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and node.args \
                    and aliases.resolve(node.func) in self._GATHERS \
                    and self._is_sharded_expr(node.args[0]) \
                    and not in_boundary(node):
                yield self.finding(
                    mod, node, "host gather of a sharded table ('*_sh' / "
                    "'sharded' receiver) outside a declared host boundary; "
                    "serve from the shards, or — if this def IS the "
                    "checkpoint/debug boundary — mark it "
                    "'# qbslint: host-boundary'")


# ---------------------------------------------------------------------------
# QBS009 — graph/label tables mutate only through epoch-advance entry points
# ---------------------------------------------------------------------------


class TableMutationOutsideEpoch(Rule):
    id = "QBS009"
    summary = ("write to a Graph/label-table/index attribute outside a "
               "construction or epoch-advance entry point — dynamic "
               "updates route through apply_update/install_index so every "
               "table swap advances the epoch and in-flight chunks stay "
               "pinnable to theirs (DESIGN.md §13)")
    # the versioned state: rebinding any of these (or writing into one
    # in place) changes what an index — or a service holding one —
    # answers for, which only an epoch advance may do
    _TABLES = {"graph", "scheme", "packed", "labels", "index",
               "label_dist", "meta_w", "meta_dist", "lm_dist",
               "_lm_dist", "_lm_dist_host", "src", "dst", "indptr"}
    # construction plus the §13 epoch-advance entry points
    _ALLOWED_NAMES = {"__init__", "__post_init__", "__new__",
                      "apply_update", "submit_update", "install_index",
                      "apply_edge_updates"}
    _ALLOWED_PREFIXES = ("build", "_build", "make_", "_make_", "from_")

    def _allowed(self, name: str) -> bool:
        return name in self._ALLOWED_NAMES \
            or name.startswith(self._ALLOWED_PREFIXES)

    @staticmethod
    def _strip(node: ast.AST) -> ast.AST:
        while isinstance(node, ast.Subscript):
            node = node.value
        return node

    def check(self, mod: Module) -> Iterable[Finding]:
        yield from self._visit(mod, mod.tree, allowed=False)

    def _visit(self, mod: Module, node: ast.AST,
               allowed: bool) -> Iterable[Finding]:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            allowed = self._allowed(node.name)
        elif not allowed and isinstance(
                node, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                       ast.Delete)):
            targets = (node.targets if isinstance(node,
                                                  (ast.Assign, ast.Delete))
                       else [node.target])
            for t in LockDiscipline._flat_targets(targets):
                t = self._strip(t)
                if isinstance(t, ast.Attribute) and t.attr in self._TABLES:
                    how = ("delete of" if isinstance(node, ast.Delete)
                           else "write to")
                    yield self.finding(
                        mod, t, f"{how} table attribute '.{t.attr}' "
                        f"outside an epoch-advance entry point; build a "
                        f"new index via apply_update and swap it in with "
                        f"install_index so the epoch advances with the "
                        f"tables")
        for child in ast.iter_child_nodes(node):
            yield from self._visit(mod, child, allowed)


ALL_RULES = (ShardMapViaCompat(), WallClockInServing(), HostSyncInJit(),
             JitInHotPath(), LockDiscipline(), CacheInsertBypass(),
             PackedWidenOnHost(), NoReplicatedGather(),
             TableMutationOutsideEpoch())
RULES_BY_ID = {r.id: r for r in ALL_RULES}
