"""CLI: ``python -m tools.qbslint [paths...]``.

Exit codes: 0 clean, 1 findings, 2 usage/parse errors.  ``--format
json`` emits a machine-readable findings list (the CI static job
uploads it as an artifact); default is ``path:line:col: RULE message``.
"""
from __future__ import annotations

import argparse
import json
import sys

from .core import lint_paths
from .rules import ALL_RULES, RULES_BY_ID


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.qbslint",
        description="QbS repo-invariant static analysis (rules QBS001-006)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to lint (default: src)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--output", default=None,
                    help="also write findings to this file")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.id}  {rule.summary}")
        return 0

    rules = None
    if args.rules:
        ids = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in ids if r not in RULES_BY_ID]
        if unknown:
            print(f"unknown rule id(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2
        rules = [RULES_BY_ID[r] for r in ids]

    findings, errors = lint_paths(args.paths or ["src"], rules)

    if args.format == "json":
        text = json.dumps(
            {"findings": [vars(f) for f in findings], "errors": errors},
            indent=1)
    else:
        lines = [f.render() for f in findings] + errors
        n = len(findings)
        lines.append(f"qbslint: {n} finding{'s' if n != 1 else ''}, "
                     f"{len(errors)} error{'s' if len(errors) != 1 else ''}")
        text = "\n".join(lines)
    print(text)
    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")
    if errors:
        return 2
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
