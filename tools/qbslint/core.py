"""Lint driver: file discovery, suppression parsing, rule dispatch.

Rules are pure functions of one parsed module (``ast`` tree + source
text + path); the driver owns everything path- and comment-shaped so a
rule never re-tokenizes.  Suppressions:

* ``# qbslint: disable=QBS001`` (or ``disable=QBS001,QBS005``) on a
  line suppresses those rules' findings anchored to that line;
  ``disable`` with no ``=`` suppresses every rule on the line.
* ``# qbslint: disable-file=QBS001`` anywhere suppresses the rule for
  the whole file.
* ``# qbslint: locked`` on a ``def`` line declares the method's
  contract is "caller holds the lock" (consumed by QBS005).
* ``# qbslint: host-boundary`` on a ``def`` line declares the function
  an explicit host boundary for sharded tables — full-table
  materialization is its *job* (consumed by QBS008).
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Sequence

_PRAGMA = re.compile(
    r"#\s*qbslint:\s*(?P<kind>disable-file|host-boundary|disable|locked)"
    r"(?:\s*=\s*(?P<rules>[A-Z0-9, ]+))?")


class LintError(Exception):
    """A file could not be linted (syntax error, unreadable)."""


@dataclass(frozen=True, order=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclass
class Suppressions:
    """Parsed qbslint pragmas of one file."""

    by_line: dict[int, set[str] | None] = field(default_factory=dict)
    file_wide: set[str] = field(default_factory=set)
    locked_lines: set[int] = field(default_factory=set)
    host_boundary_lines: set[int] = field(default_factory=set)

    def allows(self, finding: Finding) -> bool:
        if finding.rule in self.file_wide:
            return False
        rules = self.by_line.get(finding.line, ...)
        if rules is ...:
            return True
        return not (rules is None or finding.rule in rules)


@dataclass
class Module:
    """One parsed source file as the rules see it."""

    path: str            # posix path string used for rule scoping
    tree: ast.Module
    source: str
    suppressions: Suppressions

    def is_locked_def(self, node: ast.AST) -> bool:
        """True when the ``def`` carries a ``# qbslint: locked`` marker."""
        return getattr(node, "lineno", -1) in self.suppressions.locked_lines

    def is_host_boundary_def(self, node: ast.AST) -> bool:
        """True when the ``def`` carries ``# qbslint: host-boundary``."""
        return (getattr(node, "lineno", -1)
                in self.suppressions.host_boundary_lines)


def _parse_suppressions(source: str) -> Suppressions:
    sup = Suppressions()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string) for t in tokens
                    if t.type == tokenize.COMMENT]
    except (tokenize.TokenError, IndentationError, SyntaxError):
        comments = [(i + 1, line) for i, line in enumerate(source.splitlines())
                    if "#" in line]
    for lineno, text in comments:
        m = _PRAGMA.search(text)
        if not m:
            continue
        kind = m.group("kind")
        rules = m.group("rules")
        ids = ({r.strip() for r in rules.split(",") if r.strip()}
               if rules else None)
        if kind == "locked":
            sup.locked_lines.add(lineno)
        elif kind == "host-boundary":
            sup.host_boundary_lines.add(lineno)
        elif kind == "disable-file":
            sup.file_wide |= ids or set()
        else:  # disable
            existing = sup.by_line.get(lineno, set())
            if ids is None or existing is None:
                sup.by_line[lineno] = None     # all rules
            else:
                sup.by_line[lineno] = existing | ids
    return sup


def parse_module(path: str, source: str) -> Module:
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        raise LintError(f"{path}:{e.lineno or 0}:0: syntax error: {e.msg}")
    return Module(path=Path(path).as_posix(), tree=tree, source=source,
                  suppressions=_parse_suppressions(source))


def lint_source(path: str, source: str, rules: Sequence | None = None
                ) -> list[Finding]:
    from .rules import ALL_RULES
    mod = parse_module(path, source)
    out: list[Finding] = []
    for rule in (rules if rules is not None else ALL_RULES):
        if not rule.applies(mod.path):
            continue
        out.extend(f for f in rule.check(mod) if mod.suppressions.allows(f))
    return sorted(out)


def lint_file(path: str | Path, rules: Sequence | None = None
              ) -> list[Finding]:
    p = Path(path)
    try:
        source = p.read_text(encoding="utf-8")
    except (OSError, UnicodeDecodeError) as e:
        raise LintError(f"{p}: unreadable: {e}")
    return lint_source(str(p), source, rules)


def iter_py_files(paths: Iterable[str | Path]) -> Iterable[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_file():
            if p.suffix == ".py":
                yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part.startswith(".") or part == "__pycache__"
                           for part in f.parts):
                    yield f
        else:
            raise LintError(f"{p}: no such file or directory")


def lint_paths(paths: Iterable[str | Path], rules: Sequence | None = None
               ) -> tuple[list[Finding], list[str]]:
    """Lint every ``.py`` under ``paths``.  Returns (findings, errors)."""
    findings: list[Finding] = []
    errors: list[str] = []
    for f in iter_py_files(paths):
        try:
            findings.extend(lint_file(f, rules))
        except LintError as e:
            errors.append(str(e))
    return findings, errors
